"""Quickstart: the ANTAREX-JAX separation of concerns in ~40 lines.

The domain expert picks a model (functional code, untouched); the HPC expert
weaves extra-functional aspects; the runtime trains with monitoring and
checkpointing.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.core.strategies.monitoring import ExamonMonitor
from repro.core.strategies.parallelization import AccumAspect, RematAspect
from repro.core.strategies.precision import ChangePrecision
from repro.core.weaver import weave
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    # 1. functional code: a (reduced) yi-6b — never edited by what follows
    program = Program.from_arch("yi-6b", kind="train", reduced=True)

    # 2. extra-functional concerns, woven as aspects (paper §2)
    woven = weave(program, [
        ChangePrecision("*", "half"),       # §2.2 precision tuning
        RematAspect("none"),                # parallelization knobs
        AccumAspect(1),
        ExamonMonitor("quickstart"),        # §2.6 monitoring
    ])
    print(woven.report.table())             # paper Tables 1-2 metrics

    # 3. run: monitored, checkpointed, fault-tolerant
    pipeline = TokenPipeline(PipelineConfig(
        vocab=program.cfg.vocab, seq_len=32, global_batch=8))
    trainer = Trainer(woven, pipeline,
                      TrainerConfig(steps=30, log_every=10))
    history = trainer.run()
    print(f"loss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
