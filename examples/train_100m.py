"""End-to-end driver (deliverable b): train a ~100M-parameter dense LM for a
few hundred steps with the full woven stack — monitoring, checkpointing,
preemption safety, libVC variants.

Default flags run a CPU-sized slice; the full run is
    PYTHONPATH=src python examples/train_100m.py --steps 300 --batch 16 --seq 256
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SHAPES, ModelConfig
from repro.core.program import Program
from repro.core.strategies.monitoring import ExamonMonitor
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.launch.weave import default_weave
from repro.models.registry import build_model
from repro.runtime.trainer import Trainer, TrainerConfig

CFG_100M = ModelConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=768, n_heads=12,
    kv_heads=4, head_dim=64, d_ff=2048, vocab=32768, tie_embeddings=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/antarex_100m")
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"params: {cfg.param_count()/1e6:.0f}M")
    program = Program(model=build_model(cfg), cfg=cfg, kind="train")
    woven = default_weave(program, SHAPES["train_4k"], {},
                          overrides={"accum_steps": 1, "remat": "none"},
                          extra_aspects=[ExamonMonitor("train100m")])
    pipeline = TokenPipeline(PipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        noise=0.02))
    trainer = Trainer(woven, pipeline, TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        log_every=10))
    history = trainer.run()
    print(f"loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
          f"({len(history)} steps, ~{history[-1]['step_time']*1e3:.0f} ms/step)")


if __name__ == "__main__":
    main()
