"""UC1 (paper §4): computer-accelerated drug discovery.

A MeasureOverlap-style docking kernel is auto-parallelized, explored with
LAT across (parallelism x pocket size), and the resulting knowledge base
drives mARGOt at runtime as ligand batches stream through.

    PYTHONPATH=src python examples/drug_discovery.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.autotune.dse import Lat
from repro.autotune.margot import LE, Goal, KnowledgeBase, Margot, State


def measure_overlap(ligand, pocket, chunks: int):
    pc = pocket.reshape(chunks, -1, 3)
    d = jax.vmap(lambda c: jnp.min(
        jnp.sum((ligand[:, None] - c[None]) ** 2, -1), 1))(pc)
    return jnp.sum(jnp.sqrt(jnp.min(d, 0)))


def main():
    rng = np.random.default_rng(0)
    ligands = jnp.asarray(rng.normal(0, 1, (64, 96, 3)), jnp.float32)
    pocket = jnp.asarray(rng.normal(0, 4, (8192, 3)), jnp.float32)
    fns = {}

    def time_for(chunks):
        if chunks not in fns:
            fns[chunks] = jax.jit(lambda l: measure_overlap(l, pocket, chunks))
        fn = fns[chunks]
        jax.block_until_ready(fn(ligands[0]))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(ligands[0]))
        return (time.perf_counter() - t0) / chunks  # ideal-parallel model

    # design-time DSE (paper Fig. 13)
    lat = Lat("uc1").add_var("chunks", [1, 2, 4, 8, 16])
    lat.add_metric("time", lambda chunks: time_for(chunks))
    lat.set_num_tests(3)
    lat.tune()
    kb = KnowledgeBase.from_dse(lat.results, ["chunks"], ["time"])

    # runtime autotuning: keep per-ligand latency under budget, minimize
    # resources (chunks = nodes occupied)
    budget_s = 2 * min(r["metrics"]["time"][0] for r in lat.results)
    margot = Margot(kb, [State("sla", "time", maximize=False,
                               constraints=[Goal("lat", "time", LE, budget_s)])])
    done = 0
    t0 = time.perf_counter()
    for ligand in ligands:
        op = margot.update()
        score = jax.block_until_ready(
            fns[op.knobs["chunks"]](ligand))
        margot.observe("time", (time.perf_counter() - t0) / (done + 1))
        done += 1
    print(f"docked {done} ligands with chunks={margot.current.knobs['chunks']} "
          f"(latency budget {budget_s*1e3:.2f} ms/ligand)")


if __name__ == "__main__":
    main()
