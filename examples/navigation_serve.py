"""UC2 (paper §5): self-adaptive navigation serving.

The server answers routing-style requests with a (reduced) LM; memoization
caches repeated routes (paper §2.4) and mARGOt trades decode quality
(tokens generated = NQI analogue) against latency under load.

    PYTHONPATH=src python examples/navigation_serve.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.autotune.margot import GE, LE, Goal, KnowledgeBase, Margot, OperatingPoint, State
from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.core.strategies.memoization import MemoizeStep
from repro.launch.weave import default_weave
from repro.runtime.server import Server, ServerConfig


def main():
    program = Program.from_arch("gemma-2b", kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {},
                          extra_aspects=[MemoizeStep(tsize=256)])

    # knowledge: decode budget -> quality (NQI analogue) & latency
    kb = KnowledgeBase([
        OperatingPoint({"decode_tokens": n},
                       {"quality": (min(10.0, 4.0 + n), 0.2),
                        "latency": (0.02 * n + 0.05, 0.01)})
        for n in (1, 2, 4, 6)
    ])
    margot = Margot(kb, [State("qos", "quality", True, [
        Goal("lat", "latency", LE, 0.4)])])

    server = Server(woven, ServerConfig(max_cache_len=32, decode_tokens=4),
                    margot=margot)
    rng = np.random.default_rng(0)
    routes = [rng.integers(0, program.cfg.vocab, (1, 12), dtype=np.int32)
              for _ in range(6)]
    for i in range(12):  # repeated routes -> memo hits
        op = margot.update()
        out = server.serve(routes[i % len(routes)],
                           decode_tokens=op.knobs["decode_tokens"])
        margot.observe("latency", server.latencies[-1])
    print(f"served {server.served}; memo hit rate "
          f"{server.memo.hit_rate:.0%}; knob={margot.current.knobs}")


if __name__ == "__main__":
    main()
