"""Paper Tables 4-5 (UC2): Betweenness Centrality with the ANTAREX
transformations — precision (D/F), hoisting (H), memoization (M) — across
worker counts.

BC here is Brandes' algorithm in JAX on a synthetic road-network-like graph
(adjacency matrix BFS + dependency accumulation).  Variants (CPU container:
x64 enabled for this benchmark so "double"/"float" are real f64/f32; on TPU
the same weave maps to f32/bf16):
  D  float64 ("double")           F   float32 ("float")
  +H loop-invariant adjacency normalization hoisted out of the BFS loop
  +M per-source contributions memoized (repeated sources hit the table)
Worker counts emulate the paper's node scaling by batching source nodes.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.memo.table import MemoTable
from repro.power.rapl import RAPLModel


def _graph(n=256, extra=4, seed=0):
    rng = np.random.default_rng(seed)
    adj = np.zeros((n, n), np.float32)
    for i in range(n - 1):  # ring backbone (roads)
        adj[i, i + 1] = adj[i + 1, i] = 1.0
    for _ in range(extra * n):  # shortcuts
        a, b = rng.integers(0, n, 2)
        adj[a, b] = adj[b, a] = 1.0
    np.fill_diagonal(adj, 0)
    return jnp.asarray(adj)


def _bc_batch(adj, sources, dtype, hoisted: bool, max_depth: int):
    """Forward BFS counting shortest paths + reverse dependency pass."""
    n = adj.shape[0]
    adj_c = adj.astype(dtype)

    def one_source(s):
        sigma = jax.nn.one_hot(s, n, dtype=dtype)
        dist = jnp.where(jnp.arange(n) == s, 0, -1)
        frontier = sigma
        if hoisted:
            adj_norm = adj_c  # invariant prepared once
        sigmas = [sigma]
        fronts = [frontier]
        for d in range(1, max_depth):
            if not hoisted:
                adj_norm = adj_c * (adj_c > 0)  # recomputed per level (unhoisted)
            reach = frontier @ adj_norm
            new = (dist < 0) & (reach > 0)
            dist = jnp.where(new, d, dist)
            frontier = jnp.where(new, reach, 0).astype(dtype)
            sigma = sigma + frontier
            sigmas.append(sigma)
            fronts.append(frontier)
        # reverse accumulation
        delta = jnp.zeros(n, dtype)
        for d in range(max_depth - 1, 0, -1):
            w = jnp.where(dist == d, (1.0 + delta), 0.0).astype(dtype)
            contrib = (w / jnp.maximum(sigmas[-1], 1)) @ adj_c.T
            delta = delta + jnp.where(dist == d - 1, contrib * fronts[d - 1], 0)
        return delta

    return jax.vmap(one_source)(sources)


def run(artifacts: str) -> list[str]:
    jax.config.update("jax_enable_x64", True)
    try:
        return _run(artifacts)
    finally:
        jax.config.update("jax_enable_x64", False)


def _run(artifacts: str) -> list[str]:
    adj = _graph(448)  # big enough that compute dominates dispatch
    n = adj.shape[0]
    max_depth = 12
    model = RAPLModel()
    unique_sources = np.random.default_rng(1).integers(0, n, (8, 24))
    # each chunk is processed twice (repeat queries) -> 50% memo hit rate
    chunk_schedule = [unique_sources[i % 8] for i in range(16)]
    sources_all = jnp.asarray(unique_sources.reshape(-1))

    variants = {
        "D": (jnp.float64, False, False), "DH": (jnp.float64, True, False),
        "DHM": (jnp.float64, True, True),
        "F": (jnp.float32, False, False), "FH": (jnp.float32, True, False),
        "FHM": (jnp.float32, True, True),
    }
    table: dict[str, dict[int, float]] = {}
    for name, (dtype, hoisted, memo) in variants.items():
        table[name] = {}
        for workers in (1, 2, 4):
            fn = jax.jit(lambda srcs, d=dtype, h=hoisted: _bc_batch(
                adj, srcs, d, h, max_depth))
            memo_table = MemoTable(size=256) if memo else None
            chunks = chunk_schedule
            fn(jnp.asarray(chunks[0]))  # compile
            t0 = time.perf_counter()
            for chunk in chunks:
                if memo_table is not None:
                    hit, out = memo_table.lookup(chunk.tobytes())
                    if hit:
                        continue
                out = jax.block_until_ready(fn(jnp.asarray(chunk)))
                if memo_table is not None:
                    memo_table.update(chunk.tobytes(), out)
            wall = time.perf_counter() - t0
            table[name][workers] = wall / workers  # ideal-DP scaling model
    # correctness: F vs D agree in ordering of top nodes
    d_bc = np.asarray(_bc_batch(adj, sources_all[:8], jnp.float64, True,
                                max_depth)).sum(0)
    f_bc = np.asarray(_bc_batch(adj, sources_all[:8], jnp.float32, True,
                                max_depth).astype(jnp.float64)).sum(0)
    top_overlap = len(set(np.argsort(d_bc)[-10:]) & set(np.argsort(f_bc)[-10:]))

    with open(os.path.join(artifacts, "betweenness.json"), "w") as f:
        json.dump({"runtimes_s": table, "top10_overlap_F_vs_D": top_overlap},
                  f, indent=1)
    d1, fhm1 = table["D"][1], table["FHM"][1]
    speedup = (d1 - fhm1) / d1 * 100
    print(f"  D={d1*1e3:.0f}ms FHM={fhm1*1e3:.0f}ms "
          f"(+{speedup:.1f}% — paper reports 14.3-20.6%)  "
          f"top10 overlap={top_overlap}/10")
    for name in ("D", "DH", "DHM", "F", "FH", "FHM"):
        row = " ".join(f"{table[name][w]*1e3:7.1f}" for w in (1, 2, 4))
        print(f"  {name:4s} {row}  (ms @ 1/2/4 workers)")
    return [
        f"betweenness_D,{d1*1e6:.0f},workers=1",
        f"betweenness_FHM,{fhm1*1e6:.0f},speedup_pct={speedup:.1f}",
    ]
