"""Paged KV-cache pool bench (the PR 4 perf data point).

One batched serving decode step over a *mixed-length* request batch —
64 / 512 / 4096 tokens in one batch — comparing the paged pool layout
(block-table flash_decode over shared pages) against the dense stacked
layout `stack_request_caches` builds (every request padded to max_len):

  HBM allocation    paged pool = live pages only (sum of per-request
                    ceil(len/page_size) pages) vs dense stacked =
                    batch x max_len — the capacity win that lets short
                    requests ride along with long ones for free
  streamed bytes    per-step KV traffic from the `decode_schedule` /
                    `paged_decode_schedule` oracles: the paged kernel
                    streams sum_i ceil(live_i/block_kv) blocks — scaling
                    with the *sum of live lengths*, never batch x max_len
                    (the dense-XLA sweep's cost)
  latency           paged flash_decode vs dense-stacked flash_decode vs
                    the dense-XLA full-cache sweep (interpret-mode Pallas
                    off-TPU)
  parity            paged output is bit-identical to the dense stacked
                    kernel at the same effective block

Merges a `paged_decode` section into artifacts/bench/BENCH_kernels.json;
runnable standalone via `benchmarks/run.py --only paged_decode`.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.decode import (
    decode_schedule,
    page_block_kv,
    paged_decode_schedule,
)
from repro.kernels.flash_attention.kernel import cdiv
from repro.kernels.flash_attention.ops import flash_decode
from repro.nn.attention import xla_attention
from repro.runtime.pages import build_linear_pool

LENGTHS = (64, 512, 4096)  # one batch, wildly mixed request lengths
MAX_LEN = 4096
PAGE_SIZE = 256
BLOCK_KV = 256


def _time(fn, reps=2):
    out = jax.block_until_ready(fn())  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps, out


def run(artifacts: str, *, quick: bool = False) -> list[str]:
    rows: list[str] = []
    B = len(LENGTHS)
    H, K, D = (4, 2, 64) if quick else (8, 2, 64)
    reps = 1 if quick else 2
    kv_unit = K * D * 2 * 4  # K+V bytes per cache slot, fp32

    ks = jax.random.split(jax.random.PRNGKey(13), 1 + 2 * B)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k_list = [jax.random.normal(ks[1 + i], (L, K, D), jnp.float32)
              for i, L in enumerate(LENGTHS)]
    v_list = [jax.random.normal(ks[1 + B + i], (L, K, D), jnp.float32)
              for i, L in enumerate(LENGTHS)]
    index = jnp.asarray([L - 1 for L in LENGTHS], jnp.int32)

    # dense stacked layout: every request zero-padded to max_len
    k_dense = jnp.stack([
        jnp.pad(k, ((0, MAX_LEN - k.shape[0]), (0, 0), (0, 0)))
        for k in k_list
    ])
    v_dense = jnp.stack([
        jnp.pad(v, ((0, MAX_LEN - v.shape[0]), (0, 0), (0, 0)))
        for v in v_list
    ])

    # paged layout: shared pool, only live pages allocated
    pk, pv, tables, pool = build_linear_pool(k_list, v_list, PAGE_SIZE,
                                             max_len=MAX_LEN)
    bkv = page_block_kv(BLOCK_KV, PAGE_SIZE)

    # -- HBM allocation: live pages vs batch x max_len ------------------------
    hbm_stacked = B * MAX_LEN * kv_unit
    hbm_paged = pool.live_pages * PAGE_SIZE * kv_unit

    # -- per-step streamed KV bytes (oracle-exact) ----------------------------
    scheds = [decode_schedule(MAX_LEN, L - 1, bkv) for L in LENGTHS]
    paged_scheds = [
        paged_decode_schedule(MAX_LEN, L - 1, bkv, PAGE_SIZE,
                              np.asarray(tables[i]))
        for i, L in enumerate(LENGTHS)
    ]
    assert [len(s) for s in scheds] == [len(s) for s in paged_scheds]
    streamed_paged = sum(len(s) for s in paged_scheds) * bkv * kv_unit
    streamed_dense_xla = B * MAX_LEN * kv_unit
    sum_live = sum(LENGTHS)
    # the acceptance bound: paged traffic is the block-rounded sum of live
    # lengths — never the dense batch x max_len sweep
    assert streamed_paged == sum(
        cdiv(L, bkv) * bkv for L in LENGTHS) * kv_unit
    assert streamed_paged < streamed_dense_xla

    # -- latency + parity -----------------------------------------------------
    t_paged, out_paged = _time(
        lambda: flash_decode(q, pk, pv, index, tables=tables, kv_len=MAX_LEN,
                             block_kv=bkv), reps)
    t_stacked, out_stacked = _time(
        lambda: flash_decode(q, k_dense, v_dense, index, block_kv=bkv), reps)
    ar = jnp.arange(MAX_LEN, dtype=jnp.int32)
    mask = (ar[None] <= index[:, None])[:, None, None, None]

    def dense_xla():
        return xla_attention(q, k_dense, v_dense, mask)

    t_xla, out_xla = _time(dense_xla, reps)
    parity_err = float(jnp.max(jnp.abs(out_paged - out_stacked)))
    xla_err = float(jnp.max(jnp.abs(out_paged - out_xla)))

    section = {
        "mixed": {
            "lengths": list(LENGTHS),
            "max_len": MAX_LEN,
            "batch": B,
            "page_size": PAGE_SIZE,
            "block_kv": bkv,
            "hbm_stacked_bytes": hbm_stacked,
            "hbm_paged_bytes": hbm_paged,
            "hbm_ratio": hbm_paged / hbm_stacked,
            "live_pages": pool.live_pages,
            "pool_pages": pool.num_pages,
            "streamed_bytes_paged": streamed_paged,
            "streamed_bytes_dense_xla": streamed_dense_xla,
            "streamed_ratio": streamed_paged / streamed_dense_xla,
            "sum_live_ratio": sum_live / (B * MAX_LEN),
            "paged_decode_s": t_paged,
            "stacked_decode_s": t_stacked,
            "dense_xla_s": t_xla,
            "parity_err_vs_stacked_kernel": parity_err,
            "parity_err_vs_xla": xla_err,
        },
        "per_request_blocks": {
            f"len{L}": {
                "live_blocks": len(scheds[i]),
                "dense_blocks": cdiv(MAX_LEN, bkv),
                "pages": cdiv(L, PAGE_SIZE),
                "dense_pages_equiv": cdiv(MAX_LEN, PAGE_SIZE),
            }
            for i, L in enumerate(LENGTHS)
        },
    }

    rows.append(
        f"paged_decode_mixed,{t_paged*1e6:.0f},"
        f"hbm_ratio={hbm_paged/hbm_stacked:.3f};"
        f"streamed_ratio={streamed_paged/streamed_dense_xla:.3f};"
        f"err={parity_err:.1e}"
    )
    print(f"  paged_decode[{'/'.join(map(str, LENGTHS))}]: pool "
          f"{hbm_paged/2**20:.1f}MiB vs stacked {hbm_stacked/2**20:.1f}MiB "
          f"({hbm_paged/hbm_stacked:.1%}), streamed "
          f"{streamed_paged/streamed_dense_xla:.1%} of the dense sweep "
          f"(sum-live {sum_live/(B*MAX_LEN):.1%}), parity {parity_err:.1e}, "
          f"paged {t_paged*1e3:.1f}ms vs stacked {t_stacked*1e3:.1f}ms vs "
          f"XLA {t_xla*1e3:.1f}ms")

    from benchmarks.kernels import merge_bench_sections

    merge_bench_sections(artifacts, {"paged_decode": section})
    return rows
