"""Multi-replica fleet bench: the kill-a-replica-mid-wave sweep.

Measures the PR 9 acceptance claims on a shared-system-prompt workload
(every prompt opens with the same page-aligned system prompt, so
prefix-affinity routing has something to key on):

  clean      injection off: every request ok, tokens bit-identical to a
             single-server fault-free `serve_continuous` baseline, ZERO
             fleet events — the fleet layer adds routing, nothing else;
             prefix_hits land on >= 2 replicas (wave-size spill warms a
             second replica with the hot prefix).
  kill       one replica killed mid-wave (`replica_loss` join point at
             the second dispatch): the victim's completed requests are
             kept, its incomplete ones re-dispatch to survivors after
             the heartbeat monitor declares death, a hot spare swaps in
             — 100% recovery, survivor bit-parity, and the re-dispatched
             requests' tokens still match the baseline bit-for-bit.
  drain      one replica SIGTERM-drained mid-wave: its in-flight cohort
             finishes, the waiting queue hands off to peers — 100%
             completion with full bit-parity.

Merges a `fleet` section into artifacts/bench/BENCH_kernels.json;
runnable standalone via `benchmarks/run.py --only fleet`.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.core.strategies.resilience import FaultInjector
from repro.launch.weave import default_weave
from repro.runtime.fleet import ServingFleet
from repro.runtime.server import Server, ServerConfig


def _parity(outs, base) -> float:
    ok = sum(1 for a, b in zip(outs, base) if np.array_equal(a, b))
    return ok / len(base) if base else 1.0


def run(artifacts: str, *, quick: bool = False) -> list[str]:
    rows: list[str] = []
    replicas = 2 if quick else 3
    spares = 1
    n_req = 8 if quick else 12
    wave_size = 3
    decode_tokens = 5
    max_cache_len = 24

    program = Program.from_arch("yi-6b", kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    cfg = ServerConfig(max_cache_len=max_cache_len,
                       decode_tokens=decode_tokens,
                       max_batch=2, page_size=8)

    def factory() -> Server:
        return Server(woven, cfg)

    # shared-system-prompt workload: one page (8 tokens) of shared
    # prefix, distinct 3-token tails
    rng = np.random.default_rng(31)
    sys_prompt = rng.integers(1, program.cfg.vocab, 8)
    prompts = [np.concatenate([
        sys_prompt, rng.integers(1, program.cfg.vocab, 3)]).astype(np.int64)
        for _ in range(n_req)]

    # single-server fault-free baseline: the bit-parity reference
    t0 = time.perf_counter()
    base = factory().serve_continuous(prompts, decode_tokens=decode_tokens)
    t_base = time.perf_counter() - t0

    # -- clean: routing only, no injection --------------------------------
    fleet = ServingFleet(factory, replicas=replicas, spares=spares,
                         wave_size=wave_size)
    t0 = time.perf_counter()
    outs = fleet.serve(prompts, decode_tokens=decode_tokens)
    t_clean = time.perf_counter() - t0
    st = fleet.last_fleet_stats
    clean = {
        "outcomes": dict(st["outcomes"]),
        "parity": _parity(outs, base),
        "fleet_events": len(st["events"]),
        "injected_events": len(st["injected_events"]),
        "affinity_hits": int(st["affinity_hits"]),
        "replicas_with_prefix_hits": list(st["replicas_with_prefix_hits"]),
        "rounds": int(st["rounds"]),
        "latency_s": float(t_clean),
        "baseline_latency_s": float(t_base),
    }
    assert clean["fleet_events"] == 0 and clean["injected_events"] == 0, (
        "injection off must report zero fleet events")

    # -- kill: one replica lost mid-wave ----------------------------------
    inj = FaultInjector.single("replica_loss", "raise", at=1)
    fleet_k = ServingFleet(factory, replicas=replicas, spares=spares,
                           wave_size=wave_size, injector=inj)
    t0 = time.perf_counter()
    outs_k = fleet_k.serve(prompts, decode_tokens=decode_tokens)
    t_kill = time.perf_counter() - t0
    st_k = fleet_k.last_fleet_stats
    loss = next((e for e in st_k["events"] if e["kind"] == "replica_loss"),
                {})
    red = [o for o in fleet_k.last_outcomes if o["attempts"] > 0]
    red_parity = (sum(1 for o in red if np.array_equal(
        outs_k[o["rid"]], base[o["rid"]])) / len(red)) if red else 0.0
    kill = {
        "outcomes": dict(st_k["outcomes"]),
        "recovery": st_k["outcomes"].get("ok", 0) / n_req,
        "survivor_parity": _parity(outs_k, base),
        "redispatched": int(st_k["redispatched"]),
        "redispatch_token_parity": float(red_parity),
        "kept_on_victim": int(loss.get("kept", 0)),
        "events": [e["kind"] for e in st_k["events"]],
        "rounds": int(st_k["rounds"]),
        "latency_s": float(t_kill),
    }

    # -- drain: one replica SIGTERM-drained mid-wave ----------------------
    fleet_d = ServingFleet(factory, replicas=replicas, spares=spares,
                           wave_size=wave_size + 1)
    fleet_d.request_drain(0)
    t0 = time.perf_counter()
    outs_d = fleet_d.serve(prompts, decode_tokens=decode_tokens)
    t_drain = time.perf_counter() - t0
    st_d = fleet_d.last_fleet_stats
    dev = next((e for e in st_d["events"] if e["kind"] == "drain"), {})
    drain = {
        "outcomes": dict(st_d["outcomes"]),
        "recovery": st_d["outcomes"].get("ok", 0) / n_req,
        "parity": _parity(outs_d, base),
        "finished_inflight": int(dev.get("finished", 0)),
        "handoff": int(dev.get("handoff", 0)),
        "events": [e["kind"] for e in st_d["events"]],
        "latency_s": float(t_drain),
    }

    section = {
        "config": {"replicas": replicas, "spares": spares,
                   "requests": n_req, "wave_size": wave_size,
                   "decode_tokens": decode_tokens,
                   "shared_prefix_tokens": 8},
        "clean": clean,
        "kill": kill,
        "drain": drain,
    }

    rows.append(
        f"fleet,{(t_clean + t_kill + t_drain)*1e6:.0f},"
        f"recovery={kill['recovery']:.2f};parity={kill['survivor_parity']:.2f};"
        f"redispatched={kill['redispatched']};"
        f"affinity_replicas={len(clean['replicas_with_prefix_hits'])}"
    )
    print(f"  fleet[{replicas}r+{spares}s, {n_req} req]: clean parity "
          f"{clean['parity']:.0%} ({clean['fleet_events']} events), kill "
          f"recovery {kill['recovery']:.0%} / parity "
          f"{kill['survivor_parity']:.0%} ({kill['redispatched']} "
          f"re-dispatched, {kill['kept_on_victim']} kept), drain parity "
          f"{drain['parity']:.0%} ({drain['handoff']} handed off)")

    from benchmarks.kernels import merge_bench_sections

    merge_bench_sections(artifacts, {"fleet": section})
    return rows
