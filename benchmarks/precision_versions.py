"""Paper §2.2 / Fig. 3 (HalfPrecisionOpenCL): generate precision-mix
versions of the same kernel region, evaluate each at runtime for time and
error vs the fp32 oracle — the data the autotuner consumes."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.program import Program
from repro.core.strategies.precision import MixedPrecisionVersions
from repro.core.weaver import weave
from repro.nn.module import init_params


def run(artifacts: str) -> list[str]:
    program = Program.from_arch("yi-6b", reduced=True)
    aspect = MixedPrecisionVersions(
        ["*attn*", "*ffn*", "*embed*"], ["double", "float", "half"],
        max_versions=31,  # the paper generated 31 OpenCL versions
    )
    woven = weave(program, [aspect])
    model = program.model
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0,
                                program.cfg.vocab)

    def logits_for(state):
        params = init_params(model, jax.random.PRNGKey(1), state.policies)
        fwd = jax.jit(lambda p, t: model(p, {"tokens": t},
                                         ctx=state.make_ctx(), mode="dense")[0])
        out = fwd(params, tokens)  # compile
        t0 = time.perf_counter()
        for _ in range(3):
            out = fwd(params, tokens)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / 3
        return np.asarray(out, np.float32), dt

    # oracle: everything "double" (fp32 on TPU terms)
    oracle_state = woven.state.copy()
    oracle_state.policies.override("*", "double")
    ref, ref_dt = logits_for(oracle_state)

    results = []
    for name in list(woven.variants)[:31]:
        out, dt = logits_for(woven.variants[name])
        err = float(np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9))
        results.append({"version": name, "time_us": dt * 1e6,
                        "rel_error": err, "speedup_vs_double": ref_dt / dt})
    results.sort(key=lambda r: r["time_us"])
    with open(os.path.join(artifacts, "precision_versions.json"), "w") as f:
        json.dump(results, f, indent=1)
    for r in results[:5]:
        print(f"  {r['version']:24s} {r['time_us']:9.0f}us err={r['rel_error']:.4f}")
    best = results[0]
    return [
        f"precision_versions,{best['time_us']:.1f},"
        f"n={len(results)};best={best['version']};err={best['rel_error']:.4f}",
    ]
