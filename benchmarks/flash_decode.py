"""Decode-path kernel bench (the PR 3 perf data point).

Compares one serving decode step — a single new token against a long
cache — between the pruned flash_decode kernel (ring cache of W slots,
scalar-prefetched index) and the dense-XLA baseline the old `_decode` ran
(full attention over the entire max_len-padded cache):

  streamed blocks   `decode_schedule` counts: exactly ceil(W/block_kv)
                    live blocks per token vs ceil(max_len/block_kv) for the
                    dense sweep — the O(max_len) -> O(W) conversion
  latency           wall time of flash_decode over the W-slot ring cache vs
                    xla_attention over the full padded cache (interpret-mode
                    Pallas off-TPU), at a batch of serving requests with
                    per-request indices

Sweeps W in {128, 512, 2048} at max_len = 8192.  Merges a `flash_decode`
section into artifacts/bench/BENCH_kernels.json and is runnable standalone
via `benchmarks/run.py --only flash_decode`.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.decode import decode_schedule
from repro.kernels.flash_attention.kernel import cdiv
from repro.kernels.flash_attention.ops import flash_decode
from repro.nn.attention import xla_attention

MAX_LEN = 8192
WINDOWS = (128, 512, 2048)


def _time(fn, reps=2):
    out = jax.block_until_ready(fn())  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps, out


def _ring_from_full(k_full, idx: int, W: int):
    """Pack the last W positions of a linear cache into ring layout
    (slot = pos % W) — what a served request's cache looks like at idx."""
    positions = np.arange(idx - W + 1, idx + 1)
    slots = positions % W
    ring = np.zeros((k_full.shape[0], W, *k_full.shape[2:]), k_full.dtype)
    ring[:, slots] = np.asarray(k_full[:, positions])
    return jnp.asarray(ring)


def run(artifacts: str, *, quick: bool = False) -> list[str]:
    rows: list[str] = []
    B, H, K, D = (2, 4, 2, 64) if quick else (4, 8, 2, 64)
    idx = MAX_LEN - 1  # deep into the stream: every request has wrapped
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k_full = jax.random.normal(ks[1], (B, MAX_LEN, K, D), jnp.float32)
    v_full = jax.random.normal(ks[2], (B, MAX_LEN, K, D), jnp.float32)

    # the dense-XLA baseline mask over the full padded cache (what the old
    # _decode paid per token): all max_len slots stream, window masks them
    ar = jnp.arange(MAX_LEN, dtype=jnp.int32)

    section: dict[str, dict] = {}
    for W in WINDOWS:
        bkv = min(512, W)
        sched = decode_schedule(W, idx, bkv)
        pruned_blocks = len(sched)
        dense_blocks = cdiv(MAX_LEN, bkv)
        assert pruned_blocks == cdiv(min(W, idx + 1), bkv), (W, sched)

        ring_k = _ring_from_full(k_full, idx, W)
        ring_v = _ring_from_full(v_full, idx, W)
        index = jnp.full((B,), idx, jnp.int32)

        t_kernel, out_kernel = _time(
            lambda: flash_decode(q, ring_k, ring_v, index, block_kv=bkv)
        )

        mask = ((ar[None] <= idx) & (ar[None] > idx - W))[:, None, None, None]

        def dense_xla():
            return xla_attention(q, k_full, v_full, mask)

        t_xla, out_xla = _time(dense_xla)
        err = float(jnp.max(jnp.abs(out_kernel - out_xla)))

        section[f"W{W}"] = {
            "window": W,
            "max_len": MAX_LEN,
            "block_kv": bkv,
            "streamed_blocks_pruned": pruned_blocks,
            "streamed_blocks_dense": dense_blocks,
            "hbm_traffic_ratio": pruned_blocks / dense_blocks,
            "flash_decode_s": t_kernel,
            "dense_xla_s": t_xla,
            "parity_err": err,
            "batch": B,
        }
        rows.append(
            f"flash_decode_W{W},{t_kernel*1e6:.0f},"
            f"hbm_ratio={pruned_blocks/dense_blocks:.3f};err={err:.1e}"
        )
        print(f"  flash_decode[W={W}]: {pruned_blocks}/{dense_blocks} blocks "
              f"streamed ({pruned_blocks/dense_blocks:.1%} of the dense "
              f"max_len sweep), err {err:.1e}, kernel {t_kernel*1e3:.1f}ms "
              f"vs dense-XLA {t_xla*1e3:.1f}ms")

    # per-token traffic across the whole stream: O(W), not O(max_len)
    section["o_w_scaling"] = {
        f"W{W}": {
            "worst_blocks_per_token": max(
                len(decode_schedule(W, i, min(512, W)))
                for i in range(0, MAX_LEN, 509)
            ),
            "dense_blocks_per_token": cdiv(MAX_LEN, min(512, W)),
        }
        for W in WINDOWS
    }

    # merge into the shared kernel-layer report (standalone runs create it)
    from benchmarks.kernels import merge_bench_sections

    merge_bench_sections(artifacts, {"flash_decode": section})
    return rows
