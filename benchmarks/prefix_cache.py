"""Prefix-sharing page-pool bench (the PR 5 perf data point).

N requests sharing one long system prompt — the
millions-of-users-one-template serving shape — served end-to-end through
`Server.serve_continuous` twice: once with the refcounted prefix-sharing
pool, once with sharing disabled (every request stores its own prompt
copy).  Three claims, all asserted here and in CI:

  pool pages      with sharing, peak distinct pages =
                  pages(prefix) + sum_i pages(suffix_i [+ growth]) — the
                  shared system prompt is stored ONCE; unshared peak =
                  sum_i pages(prefix + suffix_i [+ growth]).  The gap is
                  (N - 1) x pages(prefix) and widens with fan-out.
  prefill HBM     admission writes K/V straight into pool pages (the
                  paged-prefill path through Attention): the per-admission
                  transient is one layer's live-prompt K/V view, never
                  the all-layer dense max_len cache the packing path used
                  to build — and with a shared prefix only the *non-shared
                  suffix* is even computed.
  bit-parity      shared and unshared serving return identical tokens:
                  shared pages hold exactly the bytes an exclusive prefill
                  would have written, and the block-table kernel streams
                  them identically (the indirection lives in the table).

Merges a `prefix_cache` section into artifacts/bench/BENCH_kernels.json;
runnable standalone via `benchmarks/run.py --only prefix_cache`.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.kernels.flash_attention.kernel import cdiv
from repro.launch.weave import default_weave
from repro.runtime.server import Server, ServerConfig


def run(artifacts: str, *, quick: bool = False) -> list[str]:
    rows: list[str] = []
    # geometry: a prefix spanning several pages + short per-request suffixes
    ps = 8 if quick else 16
    n_req = 3 if quick else 4
    prefix_len = 4 * ps           # page-aligned system prompt
    suffix_len = 3
    decode_tokens = 4
    max_cache_len = prefix_len + suffix_len + decode_tokens + ps

    program = Program.from_arch("yi-6b", kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    srv = Server(woven, ServerConfig(max_cache_len=max_cache_len,
                                     decode_tokens=decode_tokens))
    cfg = program.cfg

    rng = np.random.default_rng(7)
    prefix = (rng.integers(1, cfg.vocab, prefix_len)).astype(np.int32)
    prompts = [
        np.concatenate([prefix,
                        rng.integers(1, cfg.vocab, suffix_len).astype(np.int32)])
        for _ in range(n_req)
    ]
    finals = [min(len(p) + decode_tokens - 1, max_cache_len) for p in prompts]

    t0 = time.perf_counter()
    out_shared = srv.serve_continuous(prompts, page_size=ps)
    t_shared = time.perf_counter() - t0
    stats_shared = dict(srv.last_pool_stats)

    t0 = time.perf_counter()
    out_unshared = srv.serve_continuous(prompts, page_size=ps,
                                        prefix_sharing=False)
    t_unshared = time.perf_counter() - t0
    stats_unshared = dict(srv.last_pool_stats)

    # -- bit-parity: shared pages hold exactly the unshared bytes -------------
    parity = all(
        np.array_equal(a, b) for a, b in zip(out_shared, out_unshared)
    )
    assert parity, "prefix-shared serving diverged from unshared"

    # -- pool-page economics (the acceptance formula) -------------------------
    # peak pages with sharing: the prefix once + each request's private
    # pages at its fully-grown final length
    prefix_pages = prefix_len // ps
    pages_shared_expect = prefix_pages + sum(
        cdiv(f, ps) - prefix_pages for f in finals
    )
    pages_unshared_expect = sum(cdiv(f, ps) for f in finals)
    assert stats_shared["peak_live_pages"] == pages_shared_expect, (
        stats_shared, pages_shared_expect)
    assert stats_unshared["peak_live_pages"] == pages_unshared_expect, (
        stats_unshared, pages_unshared_expect)
    # the logical (mapped) view is identical — sharing is invisible above
    # the block table
    assert stats_shared["peak_mapped_pages"] == pages_unshared_expect
    assert stats_shared["prefix_hits"] >= (n_req - 1) * prefix_pages
    assert stats_unshared["prefix_hits"] == 0
    # manager-reported pool bytes (dtype-aware, sidecars included) agree
    # with the page economics above
    assert stats_shared["peak_pool_hbm_bytes"] == (
        stats_shared["peak_live_pages"] * stats_shared["page_hbm_bytes"])
    assert stats_unshared["peak_pool_hbm_bytes"] == (
        stats_unshared["peak_live_pages"] * stats_unshared["page_hbm_bytes"])

    # -- prefill-transient: direct-to-pool vs the old dense packing -----------
    # the dense path is *measurably* gone: every admission above went
    # through the paged-prefill step (probe is a 1-token unpadded cache),
    # the max_len-padding prefill step was never dispatched
    dense_prefill_calls = sum(srv.prefill_vc.dispatch_counts.values())
    assert dense_prefill_calls == 0, srv.prefill_vc.dispatch_counts
    assert sum(srv.paged_prefill_vc.dispatch_counts.values()) > 0
    # K+V scalars materialized outside the pool per admission: the old
    # packing path returned a max_len-padded dense cache for EVERY layer
    # at once; the paged path holds one layer's live-prompt view at a time
    # (the suffix it computes plus, on a prefix hit, the table-gathered
    # logical KV) — O(live tokens), never O(layers x max_len), and only
    # the non-shared suffix is *computed*
    kv_slot = 2 * cfg.kv_heads * cfg.resolved_head_dim  # one layer's slot
    dense_transient = max_cache_len * kv_slot * cfg.num_layers
    paged_first = len(prompts[0]) * kv_slot    # full prompt, one layer
    paged_rest = len(prompts[0]) * kv_slot     # prefix hit: gather + suffix
    paged_computed = suffix_len * kv_slot      # ...but only this computed

    section = {
        "config": {
            "arch": cfg.name,
            "n_requests": n_req,
            "prefix_len": prefix_len,
            "suffix_len": suffix_len,
            "decode_tokens": decode_tokens,
            "page_size": ps,
            "max_cache_len": max_cache_len,
        },
        "pages": {
            "prefix_pages": prefix_pages,
            "peak_shared": stats_shared["peak_live_pages"],
            "peak_unshared": stats_unshared["peak_live_pages"],
            "formula_shared": pages_shared_expect,
            "formula_unshared": pages_unshared_expect,
            "page_ratio": (stats_shared["peak_live_pages"]
                           / stats_unshared["peak_live_pages"]),
            "prefix_hits": stats_shared["prefix_hits"],
            "cow_splits": stats_shared["cow_splits"],
        },
        # dtype-aware pool bytes straight from PagedCacheManager.stats()
        # (payload dtype + any quantization scale sidecars) — the manager
        # is the single source of truth, never recomputed here
        "pool_hbm": {
            "peak_shared_bytes": stats_shared["peak_pool_hbm_bytes"],
            "peak_unshared_bytes": stats_unshared["peak_pool_hbm_bytes"],
            "page_bytes": stats_shared["page_hbm_bytes"],
            "cache_dtype": stats_shared["cache_dtype"],
        },
        "prefill_transient_kv": {
            "dense_max_len_path": dense_transient,
            "paged_first_admission": paged_first,
            "paged_prefix_hit": paged_rest,
            "paged_prefix_hit_computed": paged_computed,
            "dense_prefill_dispatches": dense_prefill_calls,
            "dense_transient_eliminated": dense_prefill_calls == 0,
        },
        "parity": {"tokens_equal": bool(parity)},
        "latency_s": {"shared": t_shared, "unshared": t_unshared},
    }

    ratio = section["pages"]["page_ratio"]
    rows.append(
        f"prefix_cache,{t_shared*1e6:.0f},"
        f"page_ratio={ratio:.3f};prefix_hits={stats_shared['prefix_hits']};"
        f"parity={int(parity)}"
    )
    print(f"  prefix_cache[{n_req}x({prefix_len}+{suffix_len})]: pool "
          f"{stats_shared['peak_live_pages']} pages shared vs "
          f"{stats_unshared['peak_live_pages']} unshared ({ratio:.1%}), "
          f"{stats_shared['prefix_hits']} prefix hits, "
          f"{stats_shared['cow_splits']} CoW splits, parity exact, "
          f"prefill transient {paged_rest}/{dense_transient} kv values "
          f"(one-layer live prompt vs all-layer dense max_len, "
          f"{paged_computed} computed)")

    from benchmarks.kernels import merge_bench_sections

    merge_bench_sections(artifacts, {"prefix_cache": section})
    return rows
