"""Paper Figs. 17-19 (UC2 navigation): mARGOt vs the commercial baseline
autotuner on a simulated navigation workload, plus the NQI sweep.

Model (from the paper's setup): a month of driving (40 h) produces routing
requests; remote routing gives quality but costs data + server compute.
NQI saturates with remote-routing frequency at a traffic-dependent point.
Baseline: only respects the 20 MB data cap, always maximizes frequency.
mARGOt: maximizes NQI subject to the data cap AND minimizes cost once the
NQI goal is met — reproducing the paper's 14% resource saving at NQI 6.8.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.autotune.margot import (
    GE, LE, Goal, KnowledgeBase, Margot, OperatingPoint, State,
)

DATA_CAP_MB = 20.0
MONTHLY_HOURS = 40.0
FREQS = [1, 2, 4, 6, 8, 12, 16, 24, 32]  # remote routings/hour knob


def _nqi(freq: float, traffic: float) -> float:
    """Quality index: saturates at a traffic-dependent frequency (Fig. 19)."""
    sat = 8.0 + 8.0 * traffic  # medium traffic -> saturation ~12/h
    return 10.0 * (1.0 - np.exp(-freq / sat * 2.2))


def _data_mb(freq: float) -> float:
    return 0.05 * freq * MONTHLY_HOURS + 2.0  # per-request transfer + overhead


def _cost(freq: float) -> float:
    return freq * MONTHLY_HOURS  # server routing requests / month


def _kb(traffic: float) -> KnowledgeBase:
    ops = []
    for f in FREQS:
        ops.append(OperatingPoint(
            {"freq": f},
            {"nqi": (_nqi(f, traffic), 0.2), "data_mb": (_data_mb(f), 0.5),
             "cost": (_cost(f), 5.0)},
        ))
    return KnowledgeBase(ops)


def run(artifacts: str) -> list[str]:
    rng = np.random.default_rng(0)
    traffic_trace = np.clip(rng.normal(0.5, 0.2, 200), 0.05, 1.0)

    # --- baseline: max frequency under the data cap only (paper Fig. 18 red)
    def baseline_choice():
        ok = [f for f in FREQS if _data_mb(f) <= DATA_CAP_MB]
        return max(ok)

    # --- mARGOt: NQI >= 6.8 constraint, minimize cost (Fig. 18 green)
    state = State("quality_at_cost", "cost", maximize=False, constraints=[
        Goal("nqi_floor", "nqi", GE, 6.8),
        Goal("data_cap", "data_mb", LE, DATA_CAP_MB),
    ])

    base_cost = base_nqi = m_cost = m_nqi = 0.0
    margot = Margot(_kb(0.5), [state])
    for traffic in traffic_trace:
        bf = baseline_choice()
        base_cost += _cost(bf)
        base_nqi += _nqi(bf, traffic)
        margot.kb = _kb(traffic)  # proactive: current traffic estimate
        op = margot.update()
        mf = op.knobs["freq"]
        m_cost += _cost(mf)
        m_nqi += _nqi(mf, traffic)
        margot.observe("nqi", _nqi(mf, traffic))
    n = len(traffic_trace)
    saving = (base_cost - m_cost) / base_cost * 100

    # --- Fig. 19: NQI target sweep -> cost
    # Fig. 19 isolates quality-vs-compute (no data cap in the sweep)
    sweep = []
    for target in np.arange(6.0, 9.01, 0.5):
        st = State("s", "cost", False, [Goal("g", "nqi", GE, float(target))])
        mm = Margot(_kb(0.5), [st])
        op = mm.update()
        sweep.append({"nqi_target": float(target), "freq": op.knobs["freq"],
                      "cost_per_month": op.mean("cost")})
    with open(os.path.join(artifacts, "navigation.json"), "w") as f:
        json.dump({
            "baseline": {"cost": base_cost / n, "nqi": base_nqi / n},
            "margot": {"cost": m_cost / n, "nqi": m_nqi / n},
            "saving_pct": saving, "nqi_sweep": sweep,
        }, f, indent=1)
    print(f"  baseline: nqi={base_nqi/n:.2f} cost={base_cost/n:.0f}; "
          f"mARGOt: nqi={m_nqi/n:.2f} cost={m_cost/n:.0f} "
          f"-> saving {saving:.1f}% (paper: ~14%)")
    c80 = next(s for s in sweep if s["nqi_target"] == 8.0)["cost_per_month"]
    c70 = next(s for s in sweep if s["nqi_target"] == 7.0)["cost_per_month"]
    drop = (c80 - c70) / c80 * 100
    print(f"  NQI 8.0 -> 7.0 lowers cost by {drop:.0f}% (paper: ~12%)")
    return [
        f"navigation_margot,{m_cost/n:.0f},saving_pct={saving:.1f};"
        f"nqi={m_nqi/n:.2f}",
        f"navigation_nqi_sweep,{c70:.0f},drop_8_to_7_pct={drop:.0f}",
    ]
