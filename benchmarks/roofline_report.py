"""§Roofline report generator: reads artifacts/dryrun/*.json (written by
launch/dryrun.py) and renders the per-(arch x shape x mesh) table consumed
by EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(suffix_filter: str | None = "") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        suffix = parts[2].split("_", 2)[-1] if False else ""
        with open(path) as f:
            rec = json.load(f)
        rec["_file"] = name
        rec["_is_variant"] = not (name.endswith("pod_16x16")
                                  or name.endswith("multipod_2x16x16"))
        out.append(rec)
    return out


def table(records: list[dict], mesh: str = "pod_16x16",
          variants: bool = False) -> str:
    hdr = (f"| arch | shape | accum | compute s | memory s | collective s | "
           f"bound | useful | roofline frac | HBM fit |\n"
           f"|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for rec in records:
        if rec.get("mesh") != mesh or rec.get("_is_variant", False) != variants:
            continue
        roof = rec.get("roofline", rec)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec.get('accum_steps', 1)} | "
            f"{roof['compute_s']:.3f} | {roof['memory_s']:.3f} | "
            f"{roof['collective_s']:.3f} | {roof['bottleneck']} | "
            f"{roof['useful_ratio']:.2f} | {roof['roofline_fraction']:.3f} | "
            f"{'yes' if rec.get('hbm_fits_v5e') else 'NO'} |"
        )
    return "\n".join(lines)


def run(artifacts: str) -> list[str]:
    records = load_records()
    base = [r for r in records if not r["_is_variant"]]
    if not base:
        print("  (no dry-run artifacts; run python -m repro.launch.dryrun --all)")
        return ["roofline_report,0,cells=0"]
    pod = [r for r in base if r["mesh"] == "pod_16x16"]
    multi = [r for r in base if r["mesh"] == "multipod_2x16x16"]
    fracs = [(r["roofline"]["roofline_fraction"], r["arch"], r["shape"])
             for r in pod if "roofline" in r]
    fracs.sort()
    print(f"  {len(pod)} pod cells, {len(multi)} multipod cells")
    if fracs:
        print(f"  worst roofline fraction: {fracs[0][1]} x {fracs[0][2]} "
              f"= {fracs[0][0]:.3f}")
        print(f"  best : {fracs[-1][1]} x {fracs[-1][2]} = {fracs[-1][0]:.3f}")
    md = (f"## Single-pod (16x16) baseline\n\n{table(records)}\n\n"
          f"## Multi-pod (2x16x16)\n\n{table(records, 'multipod_2x16x16')}\n")
    with open(os.path.join(artifacts, "roofline_table.md"), "w") as f:
        f.write(md)
    fits = sum(1 for r in pod if r.get("hbm_fits_v5e"))
    return [
        f"roofline_report,{len(pod)},fits_pod={fits}/{len(pod)};"
        f"multipod_cells={len(multi)}",
    ]
