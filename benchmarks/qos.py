"""QoS-adaptive serving bench (the PR 10 data point).

Drives an open-loop arrival ramp (logical-clock `arrival_waves`) through
`serve_continuous` three ways against one fixed SLO pair:

  governed      the `QoSGovernor` picks the operating point
                (max_batch x prefill_chunk) online via mARGOt, re-planning
                every wave as the load feature shifts;
  static b=1    max_batch=1, one-shot prefill — queue-wait TTFT blowup
                under the ramp;
  static b=N    full batch, one-shot prefill — admission waves stall
                active decodes (inter-token gap spikes).

Latency SLOs are scored on a *modeled* wave-cost clock reconstructed from
the stream's "wave" events (fixed coefficients `c0 + c_tok * tokens
processed`, applied identically to every config), so TTFT / inter-token
attainment is bit-reproducible in CI rather than a wall-clock race.
Three claims, asserted here and in CI:

  adaptive      the governor actually moves: >= 2 distinct operating
                points selected across the ramp (low-load vs high-load);
  attainment    governed SLO attainment >= the best static configuration
                (it trades batch against admission chunking per wave,
                which no fixed point can);
  parity        every config emits bit-identical tokens — QoS knobs move
                scheduling, never the argmax chain.

Merges a `qos` section into artifacts/bench/BENCH_kernels.json; runnable
standalone via `benchmarks/run.py --only qos`.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.launch.weave import default_weave
from repro.runtime.server import Server, ServerConfig

# modeled wave-cost clock: one wave costs C0 + C_TOK * (decode tokens
# emitted + prefill tokens admitted).  The same constants feed the
# governor's analytic model (s0/s_tok), so its Goals and this scorer
# agree on what a second is.
C0 = 2e-3
C_TOK = 2e-4


def _server(*, max_cache_len: int, decode_tokens: int) -> Server:
    program = Program.from_arch("yi-6b", kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    return Server(woven, ServerConfig(max_cache_len=max_cache_len,
                                      decode_tokens=decode_tokens))


def _modeled_clock(events: list[dict]) -> tuple[dict[int, float],
                                                dict[int, float]]:
    """Cumulative modeled time at the start/end of every wave."""
    cost: dict[int, float] = {}
    for ev in events:
        if ev["event"] == "wave":
            cost[ev["wave"]] = C0 + C_TOK * (ev["emitted"]
                                             + ev["prefill_tokens"])
    max_w = max(cost, default=0)
    t_start: dict[int, float] = {}
    t_end: dict[int, float] = {}
    acc = 0.0
    for w in range(max_w + 1):
        t_start[w] = acc
        # a wave with no "wave" event (pure bookkeeping) still costs C0
        acc += cost.get(w, C0)
        t_end[w] = acc
    return t_start, t_end


def _score(events: list[dict], arrival_waves: list[int],
           slo_ttft: float, slo_tok: float) -> dict:
    """SLO attainment of one serve on the modeled clock."""
    t_start, t_end = _modeled_clock(events)
    tok_waves: dict[int, list[int]] = {}
    for ev in events:
        if ev["event"] == "token":
            tok_waves.setdefault(ev["rid"], []).append(ev["wave"])
    n = len(arrival_waves)
    met = 0
    ttfts, gaps = [], []
    for r in range(n):
        waves = sorted(tok_waves.get(r, []))
        if not waves:
            continue  # emitted nothing: an SLO miss
        arrive = t_start.get(arrival_waves[r], 0.0)
        ttft = t_end[waves[0]] - arrive
        gap = max((t_end[b] - t_end[a]
                   for a, b in zip(waves, waves[1:])), default=0.0)
        ttfts.append(ttft)
        gaps.append(gap)
        met += int(ttft <= slo_ttft and gap <= slo_tok)
    return {
        "attainment": met / n,
        "ttft_max_s": max(ttfts, default=None),
        "gap_max_s": max(gaps, default=None),
        "waves": max((ev["wave"] for ev in events
                      if ev["event"] == "wave"), default=0) + 1,
    }


def run(artifacts: str, *, quick: bool = False) -> list[str]:
    rows: list[str] = []
    ps = 4
    decode_tokens = 5 if quick else 6
    n_req = 6 if quick else 10
    # prompts long enough that a one-shot admission genuinely stalls the
    # wave (~10ms on the modeled clock vs the ~8ms inter-token SLO), and
    # a batch-1 queue genuinely blows the TTFT SLO by the ramp's tail
    prompt_lens = [24 + 4 * (i % 4) for i in range(n_req)]
    max_cache_len = max(prompt_lens) + decode_tokens + 2
    arrival_waves = [0, 0, 1, 2, 3, 4, 5, 6, 8, 10][:n_req]
    full_batch = n_req
    slo_ttft = 60e-3
    slo_tok = 8e-3

    srv = _server(max_cache_len=max_cache_len, decode_tokens=decode_tokens)
    cfg = srv.woven.program.cfg
    rng = np.random.default_rng(29)
    prompts = [rng.integers(1, cfg.vocab, L).astype(np.int32)
               for L in prompt_lens]

    def serve(**kw):
        events: list[dict] = []
        out = srv.serve_continuous(prompts, page_size=ps,
                                   arrival_waves=arrival_waves,
                                   on_event=events.append, **kw)
        return out, events

    t0 = time.perf_counter()
    gov_out, gov_ev = serve(
        max_batch=full_batch,
        qos={"reselect_every": 1,
             "max_batch": (1, 2, 4, full_batch),
             "prefill_chunk": (0, 8),
             "typical_prompt": int(np.mean(prompt_lens)),
             "s0": C0, "s_tok": C_TOK,
             # the bench is scored on the modeled clock, so planning is
             # purely proactive (model + load feature): wall-clock jit
             # noise must not steer a CI-asserted OP choice.  The
             # reactive Margot.observe loop is covered by tests/test_qos.
             "reactive": False,
             "slo_ttft_s": slo_ttft, "slo_tok_s": slo_tok})
    t_gov = time.perf_counter() - t0
    qstats = srv.last_qos_stats
    assert qstats is not None

    b1_out, b1_ev = serve(max_batch=1)
    bn_out, bn_ev = serve(max_batch=full_batch)

    gov = _score(gov_ev, arrival_waves, slo_ttft, slo_tok)
    b1 = _score(b1_ev, arrival_waves, slo_ttft, slo_tok)
    bn = _score(bn_ev, arrival_waves, slo_ttft, slo_tok)

    parity = all(
        a.shape == b.shape == c.shape
        and np.array_equal(a, b) and np.array_equal(a, c)
        for a, b, c in zip(gov_out, b1_out, bn_out))

    best_static = max(b1["attainment"], bn["attainment"])
    # the bench's own acceptance criteria (CI re-asserts from the JSON)
    assert parity, "QoS knobs must never change emitted tokens"
    assert qstats["distinct_ops"] >= 2, qstats["op_history"]
    assert qstats["switches"] >= 1, qstats
    assert gov["attainment"] >= best_static, (gov, b1, bn)

    section = {
        "ramp": {
            "requests": n_req,
            "arrival_waves": list(arrival_waves),
            "prompt_lens": list(prompt_lens),
            "decode_tokens": decode_tokens,
            "slo_ttft_s": slo_ttft,
            "slo_tok_s": slo_tok,
            "clock": {"c0": C0, "c_tok": C_TOK},
        },
        "governed": {
            **gov,
            "switches": int(qstats["switches"]),
            "distinct_ops": int(qstats["distinct_ops"]),
            "op_history": qstats["op_history"],
            "objective": qstats["objective"],
            "energy_j": float(qstats["energy_j"]),
            "latency_s": float(t_gov),
        },
        "static": {
            "max_batch_1": b1,
            "full_batch": bn,
        },
        "parity": {"tokens_equal": bool(parity)},
    }

    rows.append(
        f"qos,{t_gov*1e6:.0f},"
        f"attain={gov['attainment']:.2f};best_static={best_static:.2f};"
        f"ops={qstats['distinct_ops']};switches={qstats['switches']}"
    )
    print(f"  qos[{n_req} req ramp]: governed attainment "
          f"{gov['attainment']:.0%} (static b=1 {b1['attainment']:.0%}, "
          f"b={full_batch} {bn['attainment']:.0%}), "
          f"{qstats['distinct_ops']} distinct OPs over "
          f"{qstats['switches']} switch(es), parity ok")

    from benchmarks.kernels import merge_bench_sections

    merge_bench_sections(artifacts, {"qos": section})
    return rows
