"""Paper Figs. 13-14 (UC1 drug discovery): LAT design-space exploration of a
MeasureOverlap-style kernel (sum of ligand-vs-pocket pairwise distances)
over parallelism degree x pocket size, measuring time + modeled energy."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.autotune.dse import Lat
from repro.autotune.margot import KnowledgeBase
from repro.power.rapl import RAPLModel


def _measure_overlap(ligand, pocket, chunk: int):
    """Sum over ligand atoms of min distance to pocket atoms, chunked over
    the pocket (the parallelism knob = number of chunks processed as one
    vmapped batch = OpenMP threads analogue)."""
    chunks = pocket.reshape(chunk, -1, 3)

    def per_chunk(pc):
        d = jnp.sum((ligand[:, None, :] - pc[None, :, :]) ** 2, -1)
        return jnp.min(d, axis=1)

    dmin = jnp.min(jax.vmap(per_chunk)(chunks), axis=0)
    return jnp.sum(jnp.sqrt(dmin))


def run(artifacts: str) -> list[str]:
    model = RAPLModel()
    rng = np.random.default_rng(0)
    ligand = jnp.asarray(rng.normal(0, 1, (128, 3)), jnp.float32)
    pockets = {n: jnp.asarray(rng.normal(0, 4, (n, 3)), jnp.float32)
               for n in (5000, 7000, 10000, 12000, 50000)}  # paper's sizes

    fns = {}

    def time_metric(num_pocket_atoms, threads):
        key = (num_pocket_atoms, threads)
        if key not in fns:
            fns[key] = jax.jit(lambda l, p: _measure_overlap(l, p, threads))
        fn = fns[key]
        pocket = pockets[num_pocket_atoms][: num_pocket_atoms - num_pocket_atoms % threads]
        jax.block_until_ready(fn(ligand, pocket))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(ligand, pocket))
        wall = time.perf_counter() - t0
        return wall / threads  # ideal-parallel model (single CPU device)

    def energy_metric(num_pocket_atoms, threads):
        t = time_metric(num_pocket_atoms, threads)
        return model.energy(utilization=0.7, freq=1.0, seconds=t) * threads

    lat = (Lat("uc1_exploration")
           .add_var("num_pocket_atoms", list(pockets))
           .add_var_range("threads", 0, 5, 1, lambda x: 2 ** x))
    lat.add_metric("time", time_metric)
    lat.add_metric("energy", energy_metric)
    lat.set_num_tests(2)
    results = lat.tune()
    lat.to_csv(os.path.join(artifacts, "docking_dse.csv"))

    # Fig. 14: speedup/energy-improvement vs threads at the largest pocket
    biggest = max(pockets)
    base = next(r for r in results if r["knobs"] == {"num_pocket_atoms": biggest,
                                                     "threads": 1})
    curve = []
    for th in (1, 2, 4, 8, 16):
        r = next(x for x in results if x["knobs"] == {
            "num_pocket_atoms": biggest, "threads": th})
        curve.append({
            "threads": th,
            "speedup": base["metrics"]["time"][0] / r["metrics"]["time"][0],
            "energy_improvement": base["metrics"]["energy"][0]
            / r["metrics"]["energy"][0],
        })
    kb = KnowledgeBase.from_dse(results, ["num_pocket_atoms", "threads"],
                                ["time", "energy"])
    with open(os.path.join(artifacts, "docking_curve.json"), "w") as f:
        json.dump(curve, f, indent=1)
    for c in curve:
        print(f"  threads={c['threads']:2d} speedup={c['speedup']:5.2f} "
              f"energy_x={c['energy_improvement']:5.2f}")
    best = curve[-1]
    return [
        f"docking_dse,{base['metrics']['time'][0]*1e6:.0f},"
        f"kb_points={len(kb)};speedup@16={best['speedup']:.2f}",
    ]
