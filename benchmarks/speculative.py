"""Speculative-decoding bench (the PR 6 perf data point).

Plain greedy `serve_continuous` vs the draft/verify loop at equal output,
self-drafting (the target proposes for itself — acceptance 1, the
mechanism's upper bound).  Three claims, all asserted here and in CI:

  token parity    speculative greedy == plain greedy bit for bit: every
                  emitted token is a target argmax — the draft only
                  changes how many target steps the output costs.
  target steps    at acceptance 1, the n-1 plain decode steps collapse to
                  ceil((n-1)/(draft_len+1)) widened verify steps — the
                  >= 1.5x step-reduction acceptance criterion, measured
                  by counting actual target-model dispatches.
  streamed bytes  one widened verify step streams the *union* of the
                  per-token live KV intervals once; draft_len+1 sequential
                  single-token steps each re-stream their whole prefix.
                  The `decode_schedule` q_span oracle (exactly what the
                  kernel's clamp-and-elide walk DMAs) quantifies the gap.

A cross-model round (the registry's draft pairing) records the acceptance
a *foreign* draft actually achieves — correctness never depends on it.

Merges a `speculative` section into artifacts/bench/BENCH_kernels.json;
runnable standalone via `benchmarks/run.py --only speculative`.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.kernels.flash_attention.decode import decode_schedule
from repro.launch.weave import default_weave
from repro.runtime.server import Server, ServerConfig


def _server(arch: str, *, max_cache_len: int, decode_tokens: int) -> Server:
    program = Program.from_arch(arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    return Server(woven, ServerConfig(max_cache_len=max_cache_len,
                                      decode_tokens=decode_tokens))


def run(artifacts: str, *, quick: bool = False) -> list[str]:
    rows: list[str] = []
    ps = 8
    n_req = 2 if quick else 3
    decode_tokens = 8                 # 7 plain decode steps per request
    draft_len = 3                     # -> ceil(7/4) = 2 verify steps
    max_cache_len = 24

    srv = _server("yi-6b", max_cache_len=max_cache_len,
                  decode_tokens=decode_tokens)
    cfg = srv.woven.program.cfg
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab, 4 + i).astype(np.int32)
               for i in range(n_req)]

    # count actual target-model step launches (the decode variant cache
    # tallies dispatches; the speculative serve reports its own step stats)
    base_decode = sum(srv.decode_vc.dispatch_counts.values())
    t0 = time.perf_counter()
    out_plain = srv.serve_continuous(prompts, page_size=ps)
    t_plain = time.perf_counter() - t0
    plain_steps = sum(srv.decode_vc.dispatch_counts.values()) - base_decode

    t0 = time.perf_counter()
    out_spec = srv.serve_continuous(prompts, page_size=ps,
                                    draft_len=draft_len)
    t_spec = time.perf_counter() - t0
    stats = dict(srv.last_spec_stats)

    parity = all(np.array_equal(a, b) for a, b in zip(out_plain, out_spec))
    assert parity, "speculative greedy diverged from plain greedy"
    spec_steps = stats["target_steps"]
    assert spec_steps < plain_steps, (spec_steps, plain_steps)
    step_ratio = plain_steps / spec_steps
    assert step_ratio >= 1.5, (plain_steps, spec_steps)
    assert stats["acceptance"] == 1.0  # self-draft: every proposal matches

    # -- streamed-KV oracle: one widened step vs k+1 single-token steps -----
    # at a representative round (the longest prompt's first verify), the
    # widened step streams the union interval once; sequential decode
    # re-streams the whole live prefix per token
    bkv = 8
    idx = int(max(len(p) for p in prompts))
    span = draft_len + 1
    verify_blocks = len(decode_schedule(max_cache_len, idx, bkv,
                                        q_span=span))
    sequential_blocks = sum(
        len(decode_schedule(max_cache_len, idx + s, bkv))
        for s in range(span))
    assert verify_blocks < sequential_blocks

    # -- cross-model draft: the registry pairing's observed acceptance -----
    cross_acceptance = None
    if not quick:
        from repro.models.registry import draft_for

        dsrv = _server(draft_for("yi-6b"), max_cache_len=max_cache_len,
                       decode_tokens=decode_tokens)
        out_cross = srv.serve_continuous(prompts, page_size=ps,
                                         draft_len=draft_len, draft=dsrv)
        assert all(np.array_equal(a, b)
                   for a, b in zip(out_plain, out_cross))
        cross_acceptance = srv.last_spec_stats["acceptance"]

    section = {
        "config": {
            "arch": cfg.name,
            "n_requests": n_req,
            "decode_tokens": decode_tokens,
            "draft_len": draft_len,
            "page_size": ps,
            "max_cache_len": max_cache_len,
        },
        "parity": {"tokens_equal": bool(parity)},
        "steps": {
            "plain": int(plain_steps),
            "speculative": int(spec_steps),
            "verify": int(stats["verify_steps"]),
            "fallback_decode": int(stats["decode_steps"]),
            "ratio": float(step_ratio),
        },
        "acceptance": {
            "self_draft": float(stats["acceptance"]),
            "cross_model": (float(cross_acceptance)
                            if cross_acceptance is not None else None),
        },
        "tokens_per_verify": float(stats["mean_tokens_per_verify"]),
        "streamed": {
            "block_kv": bkv,
            "index": idx,
            "q_span": span,
            "verify_blocks": int(verify_blocks),
            "sequential_blocks": int(sequential_blocks),
            "ratio": verify_blocks / sequential_blocks,
        },
        "latency_s": {"plain": t_plain, "speculative": t_spec},
    }

    rows.append(
        f"speculative,{t_spec*1e6:.0f},"
        f"step_ratio={step_ratio:.2f};"
        f"tokens_per_verify={stats['mean_tokens_per_verify']:.2f};"
        f"parity={int(parity)}"
    )
    cross = (f", cross-model acceptance {cross_acceptance:.0%}"
             if cross_acceptance is not None else "")
    print(f"  speculative[{n_req}req x {decode_tokens}tok, k={draft_len}]: "
          f"{plain_steps} plain target steps -> {spec_steps} "
          f"({step_ratio:.1f}x fewer), "
          f"{stats['mean_tokens_per_verify']:.2f} tokens/verify, "
          f"verify streams {verify_blocks}/{sequential_blocks} KV blocks "
          f"of {span} sequential steps, parity exact{cross}")

    from benchmarks.kernels import merge_bench_sections

    merge_bench_sections(artifacts, {"speculative": section})
    return rows
