"""Benchmark harness — one entry per paper table/figure (deliverable d).

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus the richer
per-benchmark artifacts under artifacts/bench/.

  weaving            Tables 1-2   static/dynamic weaving metrics
  precision_versions §2.2 Fig 3   N precision-mix versions + error/time
  betweenness        Tables 4-5   BC runtimes F/FH/FHM/D/DH/DHM x shards
  docking_dse        Figs 13-14   LAT exploration (parallelism x pocket)
  navigation         Figs 17-19   mARGOt vs baseline QoS + NQI sweep
  kernels            (kernels)    Pallas vs oracle + analytic VMEM/AI
  roofline_report    §Roofline    table from dry-run artifacts
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def main() -> None:
    os.makedirs(ARTIFACTS, exist_ok=True)
    from benchmarks import (
        betweenness,
        docking_dse,
        kernels,
        navigation_autotune,
        precision_versions,
        roofline_report,
        weaving,
    )

    rows: list[str] = ["name,us_per_call,derived"]
    for mod in (weaving, precision_versions, kernels, betweenness,
                docking_dse, navigation_autotune, roofline_report):
        print(f"== {mod.__name__} ==", flush=True)
        rows.extend(mod.run(ARTIFACTS))
    print("\n".join(rows))
    with open(os.path.join(ARTIFACTS, "summary.csv"), "w") as f:
        f.write("\n".join(rows) + "\n")


if __name__ == "__main__":
    main()
