"""Benchmark harness — one entry per paper table/figure (deliverable d).

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus the richer
per-benchmark artifacts under artifacts/bench/ (including the kernel layer's
BENCH_kernels.json: pruned-vs-dense grid + tuned-vs-default blocks).

  weaving            Tables 1-2   static/dynamic weaving metrics
  precision_versions §2.2 Fig 3   N precision-mix versions + error/time
  betweenness        Tables 4-5   BC runtimes F/FH/FHM/D/DH/DHM x shards
  docking_dse        Figs 13-14   LAT exploration (parallelism x pocket)
  navigation         Figs 17-19   mARGOt vs baseline QoS + NQI sweep
  kernels            (kernels)    Pallas pruning/tuning + analytic VMEM/AI
  flash_bwd          (kernels)    fused pruned bwd vs reference VJP
  flash_decode       (kernels)    pruned decode kernel vs dense-XLA cache sweep
  paged_decode       (kernels)    paged pool vs dense-stacked mixed-length batch
  prefix_cache       (kernels)    shared-prefix pool pages + direct-to-pool prefill
  speculative        (kernels)    draft/verify loop vs plain greedy + streamed-KV oracle
  quantized_cache    (kernels)    int8/fp8 pool HBM + logits error + dtype DSE
  robustness         (serving)    single-fault sweep: recovery/parity/audit/goodput
  fleet              (serving)    multi-replica kill/drain sweep: recovery/parity/affinity
  qos                (serving)    governed vs static SLO attainment under load ramp
  roofline_report    §Roofline    table from dry-run artifacts

Flags:
  --quick       CI smoke mode: smaller shapes, fast module subset
  --only NAMES  comma-separated module subset (e.g. --only kernels,weaving)
"""

from __future__ import annotations

import argparse
import inspect
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

QUICK_MODULES = ("weaving", "kernels", "flash_bwd", "flash_decode",
                 "paged_decode", "prefix_cache", "speculative",
                 "quantized_cache", "robustness", "fleet", "qos")


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: small shapes, fast module subset")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names to run")
    args = ap.parse_args(argv)

    os.makedirs(ARTIFACTS, exist_ok=True)
    from benchmarks import (
        betweenness,
        docking_dse,
        flash_bwd,
        flash_decode,
        fleet,
        kernels,
        navigation_autotune,
        paged_decode,
        precision_versions,
        prefix_cache,
        qos,
        quantized_cache,
        robustness,
        roofline_report,
        speculative,
        weaving,
    )

    modules = [weaving, precision_versions, kernels, flash_bwd, flash_decode,
               paged_decode, prefix_cache, speculative, quantized_cache,
               robustness, fleet, qos, betweenness, docking_dse,
               navigation_autotune,
               roofline_report]
    if args.only:
        names = {n.strip() for n in args.only.split(",")}
        modules = [m for m in modules
                   if m.__name__.split(".")[-1] in names
                   or m.__name__.split(".")[-1].split("_")[0] in names]
        if not modules:
            valid = ", ".join(m.__name__.split(".")[-1] for m in
                              (weaving, precision_versions, kernels,
                               flash_bwd, flash_decode, paged_decode,
                               prefix_cache, speculative, quantized_cache,
                               robustness, fleet, qos, betweenness,
                               docking_dse,
                               navigation_autotune, roofline_report))
            ap.error(f"--only {args.only!r} matches no benchmark; "
                     f"valid names: {valid}")
    elif args.quick:
        modules = [m for m in modules
                   if m.__name__.split(".")[-1] in QUICK_MODULES]

    rows: list[str] = ["name,us_per_call,derived"]
    for mod in modules:
        print(f"== {mod.__name__} ==", flush=True)
        kwargs = {}
        if args.quick and "quick" in inspect.signature(mod.run).parameters:
            kwargs["quick"] = True
        rows.extend(mod.run(ARTIFACTS, **kwargs))
    print("\n".join(rows))
    with open(os.path.join(ARTIFACTS, "summary.csv"), "w") as f:
        f.write("\n".join(rows) + "\n")


if __name__ == "__main__":
    main()
