"""Fault-tolerant serving bench (the PR 8 robustness data point).

Runs the acceptance-criteria fault sweep as a *measurement*: one scheduled
fault per serve at every serving join point x fault kind, against a
fault-free baseline of the same prompts.  Three claims, asserted here and
in CI:

  recovery      no injected single fault escapes `serve_continuous` as a
                raw exception — 100% of the sweep's serves complete and
                return per-request results.
  parity        every surviving (status "ok") request's tokens are
                bit-identical to the fault-free serve; victims hold a
                clean prefix of their baseline output plus a structured
                outcome (rejected / quarantined / deadline_exceeded /
                failed).
  audited       the PoolAuditor invariant barriers (refcount
                conservation, free/referenced disjointness, table
                liveness, scale-sidecar sentinels) run after every
                post-fault retirement/rollback and never trip.

Goodput under faults is recorded as emitted-token fraction vs the clean
serve, per fault kind.  Merges a `robustness` section into
artifacts/bench/BENCH_kernels.json; runnable standalone via
`benchmarks/run.py --only robustness`.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.core.strategies.resilience import (
    FAULT_KINDS,
    JOIN_POINTS,
    FaultInjector,
)
from repro.launch.weave import default_weave
from repro.runtime.server import Server, ServerConfig


def _server(arch: str, *, max_cache_len: int, decode_tokens: int) -> Server:
    program = Program.from_arch(arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    return Server(woven, ServerConfig(max_cache_len=max_cache_len,
                                      decode_tokens=decode_tokens,
                                      pool_audit=True))


def run(artifacts: str, *, quick: bool = False) -> list[str]:
    rows: list[str] = []
    ps = 8
    decode_tokens = 6
    max_cache_len = 24
    draft_len = 2

    srv = _server("yi-6b", max_cache_len=max_cache_len,
                  decode_tokens=decode_tokens)
    srv.draft = _server("gemma-2b", max_cache_len=max_cache_len,
                        decode_tokens=decode_tokens)
    cfg = srv.woven.program.cfg
    rng = np.random.default_rng(23)
    prompts = [rng.integers(1, cfg.vocab, 4 + i).astype(np.int32)
               for i in range(3)]

    t0 = time.perf_counter()
    baseline = srv.serve_continuous(prompts, page_size=ps,
                                    draft_len=draft_len)
    t_clean = time.perf_counter() - t0
    clean_fs = srv.last_fault_stats
    assert clean_fs["events"] == 0 and not clean_fs["actions"], (
        "injection off must report zero fault events")
    clean_tokens = sum(int(b.size) for b in baseline)

    # one scheduled fault per serve, swept over the full matrix; `at=1`
    # lands past the first visit of every point (admissions fire per
    # request, steps per round) so recovery paths — not trivial first-visit
    # rejections — are what gets measured
    points = JOIN_POINTS if not quick else ("admit", "decode_step",
                                            "verify_step", "retire")
    kinds = FAULT_KINDS if not quick else ("raise", "nan_logits")
    escapes = 0
    parity_ok = 0
    cells = 0
    audits = 0
    goodput_by_kind: dict[str, list[float]] = {k: [] for k in kinds}
    victims = 0
    structured = 0
    t0 = time.perf_counter()
    for point in points:
        for kind in kinds:
            cells += 1
            inj = FaultInjector.single(point, kind, at=1)
            try:
                out = srv.serve_continuous(prompts, page_size=ps,
                                           draft_len=draft_len,
                                           fault_injector=inj)
            except Exception:  # any escape fails recovery (and CI)
                escapes += 1
                continue
            fs = srv.last_fault_stats
            audits += fs["audits"]
            cell_parity = True
            for o, b, r in zip(out, baseline, srv.last_outcomes):
                if r["status"] == "ok":
                    if o.shape != b.shape or not np.array_equal(o, b):
                        cell_parity = False
                else:
                    victims += 1
                    structured += int(r["reason"] is not None)
                    if not np.array_equal(o, b[:o.size]):
                        cell_parity = False
            parity_ok += int(cell_parity)
            goodput_by_kind[kind].append(
                sum(int(o.size) for o in out) / clean_tokens)
    t_sweep = time.perf_counter() - t0

    recovery = (cells - escapes) / cells
    parity = parity_ok / cells
    goodput = {k: (float(np.mean(v)) if v else None)
               for k, v in goodput_by_kind.items()}

    section = {
        "sweep": {
            "join_points": list(points),
            "fault_kinds": list(kinds),
            "serves": cells,
            "recovery_rate": float(recovery),
            "survivor_parity_rate": float(parity),
            "victims": int(victims),
            "structured_outcomes": int(structured),
            "pool_audits": int(audits),
        },
        "goodput_vs_clean": goodput,
        "clean": {
            "tokens": int(clean_tokens),
            "fault_events": int(clean_fs["events"]),
            "latency_s": float(t_clean),
        },
        "sweep_latency_s": float(t_sweep),
    }

    rows.append(
        f"robustness,{t_sweep*1e6:.0f},"
        f"recovery={recovery:.2f};parity={parity:.2f};"
        f"victims={victims};audits={audits}"
    )
    print(f"  robustness[{cells} fault serves, {len(points)}pt x "
          f"{len(kinds)}kind]: recovery {recovery:.0%}, survivor parity "
          f"{parity:.0%}, {victims} victims all structured, "
          f"{audits} pool audits clean")

    from benchmarks.kernels import merge_bench_sections

    merge_bench_sections(artifacts, {"robustness": section})
    return rows
