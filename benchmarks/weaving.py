"""Paper Tables 1-2: static + dynamic weaving metrics for the strategy suite.

Static: LOC of each aspect (LARA SLoC analogue) vs woven artifacts added
(variants, knobs, wrappers).  Dynamic: selects, attributes analysed, actions,
inserts — straight from the Weaver's counters.
"""

from __future__ import annotations

import inspect
import json
import os
import time

from repro.core.program import Program
from repro.core.strategies.kernels import BlockSizeAspect, KernelAspect
from repro.core.strategies.memoization import MemoizeStep
from repro.core.strategies.monitoring import ExamonMonitor
from repro.core.strategies.parallelization import AccumAspect, AutoShard, RematAspect
from repro.core.strategies.precision import (
    ChangePrecision, CreateLowPrecVersion, MixedPrecisionVersions,
)
from repro.core.strategies.versioning import Multiversion, SpecializeCall
from repro.core.weaver import Weaver


def run(artifacts: str) -> list[str]:
    program = Program.from_arch("yi-6b", reduced=True)
    aspects = [
        ChangePrecision("*", "half"),
        CreateLowPrecVersion("*", "half", "_f"),
        MixedPrecisionVersions(["*attn*", "*ffn*"], ["float", "half"],
                               max_versions=4),
        Multiversion("version"),
        SpecializeCall("spec", {"accum_steps": 4}),
        MemoizeStep(tsize=128),
        ExamonMonitor("bench", tap_patterns=("*attn*",)),
        AutoShard({"data": 16, "model": 16}),
        RematAspect("full", expose_knob=True),
        AccumAspect(4, expose_knob=True),
        KernelAspect("*attn*", "attention", "pallas", expose_knob=True,
                     impls=("xla", "pallas")),
        BlockSizeAspect(flash_block_q=512, flash_block_kv=512),
    ]
    weaver = Weaver(program)
    t0 = time.perf_counter()
    woven = weaver.weave(aspects)
    weave_us = (time.perf_counter() - t0) * 1e6

    table = []
    for m, aspect in zip(woven.report.per_aspect, aspects):
        loc = len(inspect.getsource(type(aspect)).splitlines())
        table.append({
            "aspect": m.name, "aspect_loc": loc, "selects": m.selects,
            "attributes": m.attributes, "actions": m.actions,
            "inserts": m.inserts,
        })
    totals = woven.report.totals()
    summary = {
        "per_aspect": table,
        "totals": {"selects": totals.selects, "attributes": totals.attributes,
                   "actions": totals.actions, "inserts": totals.inserts},
        "variants": len(woven.variants),
        "knobs": len(woven.knobs),
        "weave_us": weave_us,
    }
    with open(os.path.join(artifacts, "weaving_metrics.json"), "w") as f:
        json.dump(summary, f, indent=1)
    print(woven.report.table())
    print(f"variants={len(woven.variants)} knobs={len(woven.knobs)}")
    # paper's headline: analysis work exceeds transformation work
    assert totals.attributes >= totals.inserts
    return [
        f"weaving_total,{weave_us:.1f},selects={totals.selects};"
        f"attrs={totals.attributes};actions={totals.actions};"
        f"inserts={totals.inserts}",
    ]
