"""Quantized paged KV-cache bench (the PR 7 perf data point).

The pool stores K/V at a narrow dtype (int8 first; fp8 where the jax
build has it) with fp32 per-page-per-head scale sidecars, dequantized
inside the flash_decode inner loop.  Three claims, asserted here and
in CI:

  pool HBM        on the mixed 64/512/4096 serving batch, the int8 pool
                  allocates <= 0.55x the fp16 pool's bytes (>= 1.8x
                  capacity) — measured through `PagedCacheManager.stats()`
                  (payload + sidecars), never recomputed by hand
  logits error    the quantized paged flash_decode output deviates from
                  the fp pool by at most ERR_BOUND max-abs — the mARGOt
                  error-model ground truth the serving path exposes
  dtype DSE       `tune_quantized_cache` persists the full
                  cache_dtype x page_size x block_kv_dec operating-point
                  set with the measured error column; tightening the
                  accuracy budget via `select_cache_knobs` (no
                  re-measurement) forces the fp fallback arm, re-loosening
                  restores the quantized pick

Merges a `quantized_cache` section into artifacts/bench/BENCH_kernels.json;
runnable standalone via `benchmarks/run.py --only quantized_cache`.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.autotune.kernel_tuner import (
    KernelTuner,
    quantized_cache_signature,
    select_cache_knobs,
    tune_quantized_cache,
)
from repro.kernels.flash_attention.decode import page_block_kv
from repro.kernels.flash_attention.ops import CACHE_QMAX, flash_decode
from repro.runtime.pages import (
    PagedCacheManager,
    build_linear_pool,
    quantize_linear_pool,
)

LENGTHS = (64, 512, 4096)  # one batch, wildly mixed request lengths
MAX_LEN = 4096
PAGE_SIZE = 256
BLOCK_KV = 256
ERR_BOUND = 0.05  # the accuracy goal CI holds the measured error to


def _time(fn, reps=2):
    out = jax.block_until_ready(fn())  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps, out


def _pool_stats(k_list, v_list, cache_dtype):
    """Admit the mixed batch into a manager and return its stats():
    the dtype-aware byte accounting the bench (and CI) consume."""
    need = sum((l + PAGE_SIZE - 1) // PAGE_SIZE
               for l in (k.shape[0] for k in k_list))
    mgr = PagedCacheManager(need, PAGE_SIZE, max_len=MAX_LEN,
                            cache_dtype=cache_dtype)
    for i, (k, v) in enumerate(zip(k_list, v_list)):
        L = k.shape[0]
        pad = ((0, MAX_LEN - L), (0, 0), (0, 0))
        cache = {"layers": {
            "k": jnp.pad(k, pad)[None],
            "v": jnp.pad(v, pad)[None],
            "index": jnp.full((1,), L, jnp.int32),
        }}
        mgr.admit(i, cache, final_len=L)
    return mgr.stats()


def run(artifacts: str, *, quick: bool = False) -> list[str]:
    rows: list[str] = []
    B = len(LENGTHS)
    H, K, D = (4, 2, 64) if quick else (8, 2, 64)
    reps = 1 if quick else 2

    ks = jax.random.split(jax.random.PRNGKey(29), 1 + 2 * B)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k_list = [jax.random.normal(ks[1 + i], (L, K, D),
                                jnp.float16) for i, L in enumerate(LENGTHS)]
    v_list = [jax.random.normal(ks[1 + B + i], (L, K, D),
                                jnp.float16) for i, L in enumerate(LENGTHS)]
    index = jnp.asarray([L - 1 for L in LENGTHS], jnp.int32)

    # -- pool HBM: int8 (+ scale sidecars) vs the fp16 pool, both reported
    # by PagedCacheManager.stats() — the single source of byte truth
    stats_fp = _pool_stats(k_list, v_list, None)
    stats_q = _pool_stats(k_list, v_list, "int8")
    assert stats_fp["cache_dtype"] is None
    assert stats_q["cache_dtype"] == "int8"
    assert stats_q["live_pages"] == stats_fp["live_pages"]
    hbm_fp = stats_fp["pool_hbm_bytes"]
    hbm_q = stats_q["pool_hbm_bytes"]
    hbm_ratio = hbm_q / hbm_fp
    # the acceptance bounds: int8 + fp32 sidecars stays under 0.55x fp16,
    # i.e. >= 1.8x more tokens per HBM byte
    assert hbm_ratio <= 0.55, (hbm_q, hbm_fp)
    assert hbm_fp / hbm_q >= 1.8

    # -- logits error + latency: paged flash_decode over the same mixed
    # batch, quantized pool (in-kernel dequant) vs the fp pool
    pk, pv, tables, _pool = build_linear_pool(k_list, v_list, PAGE_SIZE,
                                              max_len=MAX_LEN)
    bkv = page_block_kv(BLOCK_KV, PAGE_SIZE)
    qpk, qpv, ksc, vsc = quantize_linear_pool(pk, pv, "int8")

    t_fp, out_fp = _time(
        lambda: flash_decode(q, pk, pv, index, tables=tables,
                             kv_len=MAX_LEN, block_kv=bkv), reps)
    t_q, out_q = _time(
        lambda: flash_decode(q, qpk, qpv, index, tables=tables,
                             kv_len=MAX_LEN, block_kv=bkv,
                             k_scale=ksc, v_scale=vsc), reps)
    max_logit_err = float(jnp.max(jnp.abs(
        out_q.astype(jnp.float32) - out_fp.astype(jnp.float32))))
    assert max_logit_err <= ERR_BOUND, max_logit_err

    # -- dtype x geometry DSE: persist all rows (with the error column),
    # then re-select under a tightened accuracy budget without re-measuring
    T_dse = 128 if quick else 256
    tuner = KernelTuner(os.path.join(artifacts,
                                     "TUNER_quantized_cache.json"))
    sig = quantized_cache_signature(2, T_dse, H, K, D, "float32")
    tuned = tune_quantized_cache(sig, error_budget=ERR_BOUND, tuner=tuner)
    entry = tuner.cache.get(tuner._key(sig))
    dse_rows = len(entry["ops"])
    errs = {}
    for op in entry["ops"]:
        name = str(op["knobs"]["cache_dtype"])
        err = op["metrics"]["max_logit_err"][0]
        errs[name] = max(errs.get(name, 0.0), err)
    tight = select_cache_knobs(sig, error_budget=1e-9, tuner=tuner)
    assert tight["cache_dtype"] not in CACHE_QMAX, tight  # fp fallback
    reselected = select_cache_knobs(sig, error_budget=ERR_BOUND, tuner=tuner)
    assert reselected["cache_dtype"] == tuned["cache_dtype"]

    section = {
        "config": {
            "lengths": list(LENGTHS),
            "max_len": MAX_LEN,
            "batch": B,
            "heads": [H, K],
            "head_dim": D,
            "page_size": PAGE_SIZE,
            "block_kv": bkv,
            "fp_dtype": "float16",
            "cache_dtype": "int8",
        },
        # top-level numbers CI holds the acceptance bounds to
        "hbm_ratio": hbm_ratio,
        "max_logit_err": max_logit_err,
        "err_bound": ERR_BOUND,
        "pool_hbm": {
            "fp16_bytes": hbm_fp,
            "int8_bytes": hbm_q,
            "reduction_x": hbm_fp / hbm_q,
            "fp16_page_bytes": stats_fp["page_hbm_bytes"],
            "int8_page_bytes": stats_q["page_hbm_bytes"],
            "live_pages": stats_q["live_pages"],
        },
        "latency_s": {"fp16_pool": t_fp, "int8_pool": t_q},
        "dse": {
            "signature": sig.key(),
            "rows": dse_rows,
            "tuned": dict(tuned),
            "max_err_by_dtype": errs,
            "tightened_budget_pick": dict(tight),
            "reselected_pick": dict(reselected),
            "error_budget": ERR_BOUND,
            "device": entry.get("device"),
        },
    }

    rows.append(
        f"quantized_cache_mixed,{t_q*1e6:.0f},"
        f"hbm_ratio={hbm_ratio:.3f};err={max_logit_err:.1e};"
        f"tuned_dtype={tuned['cache_dtype']}"
    )
    print(f"  quantized_cache[{'/'.join(map(str, LENGTHS))}]: pool "
          f"{hbm_q/2**20:.2f}MiB int8 vs {hbm_fp/2**20:.2f}MiB fp16 "
          f"({hbm_ratio:.1%}, {hbm_fp/hbm_q:.2f}x capacity), max logit err "
          f"{max_logit_err:.1e} (bound {ERR_BOUND}), int8 {t_q*1e3:.1f}ms "
          f"vs fp {t_fp*1e3:.1f}ms, DSE {dse_rows} rows -> "
          f"{tuned['cache_dtype']} (tightened -> {tight['cache_dtype']})")

    from benchmarks.kernels import merge_bench_sections

    merge_bench_sections(artifacts, {"quantized_cache": section})
    return rows
