"""Pallas kernel benches: interpret-mode correctness deltas + wall time of
the XLA fast paths + analytic VMEM/arithmetic-intensity table (the TPU-side
profile is structural; see DESIGN.md §7)."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import vmem_bytes
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.ref import rglru_assoc, rglru_scan
from repro.kernels.rwkv6.ref import wkv_chunked, wkv_scan


def _time(fn, *args, reps=3):
    out = jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps, out


def run(artifacts: str) -> list[str]:
    rows = []
    report = {}

    # flash attention: XLA blocked path wall time + kernel analytic profile
    B, S, H, K, D = 2, 1024, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.bfloat16)
    ref_fn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    t_ref, ref_out = _time(ref_fn, q, k, v)
    out = flash_attention(q, k, v, causal=True, block_q=256, block_kv=256,
                          interpret=True)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref_out.astype(jnp.float32))))
    for bq, bkv in ((256, 256), (512, 512), (512, 1024)):
        vb = vmem_bytes(bq, bkv, 128)
        flops = 4 * bq * bkv * 128
        ai = flops / vb
        report[f"flash_{bq}x{bkv}"] = {
            "vmem_bytes": vb, "fits_16MB_vmem": vb < 16 * 2**20,
            "arithmetic_intensity": ai,
        }
    rows.append(f"flash_attention_ref,{t_ref*1e6:.0f},interp_err={err:.4f}")
    print(f"  flash: ref {t_ref*1e3:.1f}ms, interpret err {err:.4f}; "
          f"VMEM 512x512 = {vmem_bytes(512,512,128)/2**20:.1f}MiB")

    # wkv: chunked (roofline path) vs sequential scan wall time
    B, S, Hh, C = 2, 512, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r_, k_, v_ = (jax.random.normal(ks[i], (B, S, Hh, C)) for i in range(3))
    w_ = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, Hh, C))))
    u_ = jax.random.normal(ks[4], (Hh, C))
    s0 = jnp.zeros((B, Hh, C, C))
    t_scan, y_scan = _time(jax.jit(lambda *a: wkv_scan(*a)[0]), r_, k_, v_, w_, u_, s0)
    t_chunk, y_chunk = _time(jax.jit(lambda *a: wkv_chunked(*a)[0]), r_, k_, v_, w_, u_, s0)
    err = float(jnp.max(jnp.abs(y_scan.astype(jnp.float32)
                                - y_chunk.astype(jnp.float32))))
    rows.append(f"wkv_chunked,{t_chunk*1e6:.0f},"
                f"speedup_vs_scan={t_scan/t_chunk:.2f};err={err:.4f}")
    print(f"  wkv: scan {t_scan*1e3:.1f}ms chunked {t_chunk*1e3:.1f}ms "
          f"({t_scan/t_chunk:.1f}x) err={err:.1e}")

    # rglru: associative scan vs sequential
    B, S, Dd = 4, 2048, 256
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a_ = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, Dd)))
    b_ = jax.random.normal(ks[1], (B, S, Dd))
    h0 = jax.random.normal(ks[2], (B, Dd))
    t_seq, _ = _time(jax.jit(lambda *x: rglru_scan(*x)[0]), a_, b_, h0)
    t_assoc, _ = _time(jax.jit(lambda *x: rglru_assoc(*x)[0]), a_, b_, h0)
    rows.append(f"rglru_assoc,{t_assoc*1e6:.0f},"
                f"speedup_vs_scan={t_seq/t_assoc:.2f}")
    print(f"  rglru: scan {t_seq*1e3:.1f}ms assoc {t_assoc*1e3:.1f}ms "
          f"({t_seq/t_assoc:.1f}x)")

    with open(os.path.join(artifacts, "kernels.json"), "w") as f:
        json.dump(report, f, indent=1)
    return rows
