"""Pallas kernel benches: interpret-mode correctness deltas + wall time of
the XLA fast paths + analytic VMEM/arithmetic-intensity table, plus the
grid-pruning and DSE-tuning comparisons (BENCH_kernels.json):

  pruned_vs_dense   streamed-KV-block counts from the kernel's own schedule
                    (asserted: the pruned schedule never streams a fully
                    masked block) + interpret-mode parity of both paths
  tuned_vs_default  KernelTuner DSE over the fwd+bwd block knobs
                    (block_q, block_kv, block_q_bwd, block_kv_bwd) vs the
                    512x512 default, timing a full fwd+grad step per point
                    (sampled), with the exploration trajectory

The fused-backward comparison (pruned bwd vs reference VJP) lives in the
sibling `flash_bwd` bench, which merges its section into the same
BENCH_kernels.json.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.autotune.kernel_tuner import KernelTuner, flash_signature
from repro.kernels.flash_attention.kernel import (
    block_fully_masked,
    cdiv,
    kv_schedule,
    vmem_bytes,
)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.ref import rglru_assoc, rglru_scan
from repro.kernels.rwkv6.ref import wkv_chunked, wkv_scan


def merge_bench_sections(artifacts: str, sections: dict) -> None:
    """Read-modify-write named sections of the shared BENCH_kernels.json so
    the kernels and flash_bwd benches can each own their part of the file
    (and `--only` runs of either never drop the other's data)."""
    path = os.path.join(artifacts, "BENCH_kernels.json")
    try:
        with open(path) as f:
            bench = json.load(f)
    except (OSError, ValueError):
        bench = {}
    bench.update(sections)
    with open(path, "w") as f:
        json.dump(bench, f, indent=1)


def _time(fn, *args, reps=3):
    out = jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps, out


def _schedule_stats(S, T, bq, bkv, *, causal, window):
    """Streamed-block counts for pruned vs dense + the no-dead-streams check."""
    nq, nk = cdiv(S, bq), cdiv(T, bkv)
    pruned = kv_schedule(S, T, bq, bkv, causal=causal, window=window,
                         pruned=True)
    dense_blocks = nq * nk
    pruned_blocks = sum(len(row) for row in pruned)
    dead_streams = sum(
        1 for iq, row in enumerate(pruned) for ik in row
        if block_fully_masked(iq, ik, bq, bkv, kv_len=T, causal=causal,
                              window=window)
    )
    return {
        "streamed_blocks_dense": dense_blocks,
        "streamed_blocks_pruned": pruned_blocks,
        "hbm_traffic_ratio": pruned_blocks / dense_blocks,
        "fully_masked_blocks_streamed": dead_streams,
    }


def _bench_grid_pruning(report, rows, *, quick: bool):
    S = 512 if quick else 1024
    B, H, K, D = 1, 4, 2, 64
    bq = bkv = 128
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))

    cases = {"causal": (True, None), "window": (True, max(128, S // 8))}
    out = {}
    for name, (causal, window) in cases.items():
        stats = _schedule_stats(S, S, bq, bkv, causal=causal, window=window)
        assert stats["fully_masked_blocks_streamed"] == 0, (
            f"pruned schedule streams dead blocks for {name}: {stats}"
        )
        t_p, o_p = _time(
            lambda *a: flash_attention(*a, causal=causal, window=window,
                                       block_q=bq, block_kv=bkv, pruned=True,
                                       interpret=True),
            q, k, v, reps=1,
        )
        t_d, o_d = _time(
            lambda *a: flash_attention(*a, causal=causal, window=window,
                                       block_q=bq, block_kv=bkv, pruned=False,
                                       interpret=True),
            q, k, v, reps=1,
        )
        ref = attention_ref(q, k, v, causal=causal, window=window)
        err_p = float(jnp.max(jnp.abs(o_p - ref)))
        err_d = float(jnp.max(jnp.abs(o_d - ref)))
        out[name] = dict(
            stats,
            pruned_s=t_p, dense_s=t_d,
            parity_err_pruned=err_p, parity_err_dense=err_d,
        )
        rows.append(
            f"flash_pruned_{name},{t_p*1e6:.0f},"
            f"hbm_ratio={stats['hbm_traffic_ratio']:.3f};err={err_p:.1e}"
        )
        print(f"  pruning[{name}]: {stats['streamed_blocks_pruned']}/"
              f"{stats['streamed_blocks_dense']} KV blocks streamed "
              f"({stats['hbm_traffic_ratio']:.0%}), parity err {err_p:.1e}")
    # the O(S*W) claim at a bigger S, schedule-only (no execution needed)
    S_big, W = 8192, 1024
    out["window_scaling_8k"] = _schedule_stats(
        S_big, S_big, 512, 512, causal=True, window=W
    )
    report["pruned_vs_dense"] = out


def _bench_tuner(report, rows, artifacts, *, quick: bool):
    S = 256 if quick else 512
    B, H, K, D = 1, 4, 2, 64
    sig = flash_signature((B, S, H, D), K, "float32", causal=True)
    cache_path = os.path.join(artifacts, "kernel_tuner_cache.json")
    tuner = KernelTuner(cache_path)
    t0 = time.perf_counter()
    # the 4-knob (fwd + bwd blocks) space is sampled: each point now times a
    # full fwd+grad step, so the exhaustive grid is a TPU-only luxury
    sample = 8 if quick else 16
    best = tuner.get(sig, sample=sample)
    if "block_q_bwd" not in best:  # stale fwd-only entry (pre-bwd cache)
        best = tuner.tune(sig, sample=sample)
    tune_s = time.perf_counter() - t0
    kb = tuner.knowledge_base(sig)
    entry = tuner.cache.get(sig.key())

    b0 = min(512, S)
    default = {"block_q": b0, "block_kv": b0,
               "block_q_bwd": b0, "block_kv_bwd": b0}
    trajectory = sorted(
        (
            {"knobs": row["knobs"],
             "latency_s": row["metrics"]["latency_s"][0],
             "vmem_bytes": row["metrics"]["vmem_bytes"][0]}
            for row in entry["ops"]
        ),
        key=lambda r: r["latency_s"],
    )
    by_knobs = {tuple(sorted(r["knobs"].items())): r["latency_s"]
                for r in trajectory}
    t_best = by_knobs[tuple(sorted(best.items()))]
    t_default = by_knobs.get(tuple(sorted(default.items())), t_best)
    report["tuned_vs_default"] = {
        "signature": sig.key(),
        "default": {"knobs": default, "latency_s": t_default},
        "tuned": {"knobs": best, "latency_s": t_best},
        "speedup": t_default / max(t_best, 1e-12),
        "dse_points": len(kb),
        "tune_wall_s": tune_s,
        "trajectory": trajectory,
    }
    rows.append(
        f"flash_tuned,{t_best*1e6:.0f},"
        f"speedup_vs_default={t_default/max(t_best,1e-12):.2f};"
        f"blocks={best['block_q']}x{best['block_kv']}"
    )
    print(f"  tuner: {len(kb)} DSE points in {tune_s:.1f}s -> "
          f"{best['block_q']}x{best['block_kv']} "
          f"({t_default/max(t_best,1e-12):.2f}x vs default)")


def run(artifacts: str, *, quick: bool = False) -> list[str]:
    rows = []
    report = {}

    # flash attention: XLA blocked path wall time + kernel analytic profile
    B, S, H, K, D = 2, 512 if quick else 1024, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, K, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, K, D), jnp.bfloat16)
    ref_fn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    t_ref, ref_out = _time(ref_fn, q, k, v)
    out = flash_attention(q, k, v, causal=True, block_q=256, block_kv=256,
                          interpret=True)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref_out.astype(jnp.float32))))
    for bq, bkv in ((256, 256), (512, 512), (512, 1024)):
        vb = vmem_bytes(bq, bkv, 128)
        flops = 4 * bq * bkv * 128
        ai = flops / vb
        report[f"flash_{bq}x{bkv}"] = {
            "vmem_bytes": vb, "fits_16MB_vmem": vb < 16 * 2**20,
            "arithmetic_intensity": ai,
        }
    rows.append(f"flash_attention_ref,{t_ref*1e6:.0f},interp_err={err:.4f}")
    print(f"  flash: ref {t_ref*1e3:.1f}ms, interpret err {err:.4f}; "
          f"VMEM 512x512 = {vmem_bytes(512,512,128)/2**20:.1f}MiB")

    # block-sparse grid pruning + DSE block tuning
    _bench_grid_pruning(report, rows, quick=quick)
    _bench_tuner(report, rows, artifacts, quick=quick)

    # wkv: chunked (roofline path) vs sequential scan wall time
    B, S, Hh, C = 2, 256 if quick else 512, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r_, k_, v_ = (jax.random.normal(ks[i], (B, S, Hh, C)) for i in range(3))
    w_ = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, Hh, C))))
    u_ = jax.random.normal(ks[4], (Hh, C))
    s0 = jnp.zeros((B, Hh, C, C))
    t_scan, y_scan = _time(jax.jit(lambda *a: wkv_scan(*a)[0]), r_, k_, v_, w_, u_, s0)
    t_chunk, y_chunk = _time(jax.jit(lambda *a: wkv_chunked(*a)[0]), r_, k_, v_, w_, u_, s0)
    err = float(jnp.max(jnp.abs(y_scan.astype(jnp.float32)
                                - y_chunk.astype(jnp.float32))))
    rows.append(f"wkv_chunked,{t_chunk*1e6:.0f},"
                f"speedup_vs_scan={t_scan/t_chunk:.2f};err={err:.4f}")
    print(f"  wkv: scan {t_scan*1e3:.1f}ms chunked {t_chunk*1e3:.1f}ms "
          f"({t_scan/t_chunk:.1f}x) err={err:.1e}")

    # rglru: associative scan vs sequential
    B, S, Dd = 4, 1024 if quick else 2048, 256
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a_ = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, Dd)))
    b_ = jax.random.normal(ks[1], (B, S, Dd))
    h0 = jax.random.normal(ks[2], (B, Dd))
    t_seq, _ = _time(jax.jit(lambda *x: rglru_scan(*x)[0]), a_, b_, h0)
    t_assoc, _ = _time(jax.jit(lambda *x: rglru_assoc(*x)[0]), a_, b_, h0)
    rows.append(f"rglru_assoc,{t_assoc*1e6:.0f},"
                f"speedup_vs_scan={t_seq/t_assoc:.2f}")
    print(f"  rglru: scan {t_seq*1e3:.1f}ms assoc {t_assoc*1e3:.1f}ms "
          f"({t_seq/t_assoc:.1f}x)")

    with open(os.path.join(artifacts, "kernels.json"), "w") as f:
        json.dump(report, f, indent=1)
    merge_bench_sections(artifacts, {
        "pruned_vs_dense": report["pruned_vs_dense"],
        "tuned_vs_default": report["tuned_vs_default"],
    })
    return rows
