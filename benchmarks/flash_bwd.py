"""Fused flash-attention backward bench (the PR 2 perf data point).

Compares training-direction attention — forward + backward via `jax.grad` —
between the fused pruned Pallas backward and the dense reference VJP:

  streamed blocks   dq pass (kv_schedule) + dk/dv pass (q_schedule) vs the
                    dense both-pass count, asserted to stream no fully
                    masked block, plus an 8k schedule-only O(S·W) point
  latency           wall time of jax.grad through flash_attention (pruned
                    fused bwd, tuner-resolved blocks) vs jax.grad through
                    attention_ref (interpret-mode Pallas off-TPU)

Merges a `flash_bwd` section into artifacts/bench/BENCH_kernels.json (the
kernel-layer perf trajectory now has fwd *and* bwd points) and is runnable
standalone via `benchmarks/run.py --only flash_bwd`.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    block_fully_masked,
    cdiv,
    kv_schedule,
    q_schedule,
)
from repro.kernels.flash_attention.ops import _resolve_blocks, flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _bwd_schedule_stats(S, T, bq, bkv, *, causal, window):
    """Streamed-block counts for the two backward passes vs dense, plus the
    no-dead-streams invariant."""
    nq, nk = cdiv(S, bq), cdiv(T, bkv)
    dq_sched = kv_schedule(S, T, bq, bkv, causal=causal, window=window,
                           pruned=True)
    dkv_sched = q_schedule(S, T, bq, bkv, causal=causal, window=window,
                           pruned=True)
    dead = sum(
        1 for iq, row in enumerate(dq_sched) for ik in row
        if block_fully_masked(iq, ik, bq, bkv, kv_len=T, causal=causal,
                              window=window)
    ) + sum(
        1 for ik, row in enumerate(dkv_sched) for iq in row
        if block_fully_masked(iq, ik, bq, bkv, kv_len=T, causal=causal,
                              window=window)
    )
    pruned_blocks = (sum(len(r) for r in dq_sched)
                     + sum(len(r) for r in dkv_sched))
    dense_blocks = 2 * nq * nk  # reference VJP touches every pair, twice
    return {
        "streamed_blocks_dq": sum(len(r) for r in dq_sched),
        "streamed_blocks_dkv": sum(len(r) for r in dkv_sched),
        "streamed_blocks_pruned": pruned_blocks,
        "streamed_blocks_dense": dense_blocks,
        "hbm_traffic_ratio": pruned_blocks / dense_blocks,
        "fully_masked_blocks_streamed": dead,
    }


def _grad_time(loss, args, reps=1):
    fn = jax.grad(loss, argnums=(0, 1, 2))
    grads = jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        grads = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps, grads


def run(artifacts: str, *, quick: bool = False) -> list[str]:
    rows: list[str] = []
    S = 256 if quick else 512
    B, H, K, D = 1, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, K, D))
    v = jax.random.normal(ks[2], (B, S, K, D))
    g = jax.random.normal(ks[3], (B, S, H, D))

    section: dict[str, dict] = {}
    cases = {"causal": (True, None), "window": (True, max(64, S // 8))}
    for name, (causal, window) in cases.items():
        bq, bkv, bqb, bkvb = _resolve_blocks(
            q, k, causal=causal, window=window,
            block_q=None, block_kv=None,
        )
        bq, bkv = min(bq, 128), min(bkv, 128)
        bqb, bkvb = min(bqb, 128), min(bkvb, 128)
        stats = _bwd_schedule_stats(S, S, bqb, bkvb, causal=causal,
                                    window=window)
        assert stats["fully_masked_blocks_streamed"] == 0, (name, stats)

        def loss_pallas(q, k, v):
            out = flash_attention(q, k, v, causal=causal, window=window,
                                  block_q=bq, block_kv=bkv,
                                  block_q_bwd=bqb, block_kv_bwd=bkvb,
                                  pruned=True, interpret=True)
            return jnp.sum(out * g)

        def loss_ref(q, k, v):
            return jnp.sum(
                attention_ref(q, k, v, causal=causal, window=window) * g
            )

        t_fused, g_fused = _grad_time(loss_pallas, (q, k, v))
        t_ref, g_ref = _grad_time(loss_ref, (q, k, v))
        err = max(
            float(jnp.max(jnp.abs(a - b))) for a, b in zip(g_fused, g_ref)
        )
        section[name] = dict(
            stats,
            blocks_bwd=[bqb, bkvb],
            fused_bwd_s=t_fused,
            reference_vjp_s=t_ref,
            grad_parity_err=err,
        )
        rows.append(
            f"flash_bwd_{name},{t_fused*1e6:.0f},"
            f"hbm_ratio={stats['hbm_traffic_ratio']:.3f};err={err:.1e}"
        )
        print(f"  flash_bwd[{name}]: {stats['streamed_blocks_pruned']}/"
              f"{stats['streamed_blocks_dense']} blocks streamed "
              f"({stats['hbm_traffic_ratio']:.0%}), grad err {err:.1e}, "
              f"fused {t_fused*1e3:.0f}ms vs ref-vjp {t_ref*1e3:.0f}ms")

    # the O(S*W) claim at scale, schedule-only (no execution needed)
    section["window_scaling_8k"] = _bwd_schedule_stats(
        8192, 8192, 512, 512, causal=True, window=1024
    )

    # merge into the shared kernel-layer report (standalone runs create it)
    from benchmarks.kernels import merge_bench_sections

    merge_bench_sections(artifacts, {"flash_bwd": section})
    return rows
