"""mARGOt autotuner, ExaMon broker, PowerCapper, memo tables, libVC, DSE."""

import time

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.autotune.dse import Lat
from repro.autotune.margot import (
    GE, LE, Goal, KnowledgeBase, Margot, OperatingPoint, State,
)
from repro.memo.table import MemoTable
from repro.monitor.examon import ExamonBroker, ExamonCollector
from repro.power.capper import PowerCapper
from repro.power.rapl import RAPLModel
from repro.versioning.libvc import LibVC


def _kb():
    return KnowledgeBase([
        OperatingPoint({"knob": "fast"}, {"throughput": (100.0, 5.0),
                                          "error": (0.05, 0.01)}),
        OperatingPoint({"knob": "accurate"}, {"throughput": (40.0, 2.0),
                                              "error": (0.01, 0.002)}),
        OperatingPoint({"knob": "balanced"}, {"throughput": (70.0, 3.0),
                                              "error": (0.025, 0.005)}),
    ])


class TestMargot:
    def test_constrained_selection(self):
        state = State("s", "throughput", True,
                      [Goal("err", "error", LE, 0.03)])
        m = Margot(_kb(), [state])
        op = m.update()
        assert op.knobs["knob"] == "balanced"  # fastest satisfying error<=0.03

    def test_relaxation_when_infeasible(self):
        state = State("s", "throughput", True,
                      [Goal("err", "error", LE, 0.001)])
        m = Margot(_kb(), [state])
        op = m.update()
        assert op.knobs["knob"] == "accurate"  # min violation

    def test_reactive_adaptation(self):
        """Observed error 3x expectations -> tuner falls back to accurate."""
        state = State("s", "throughput", True,
                      [Goal("err", "error", LE, 0.03)])
        m = Margot(_kb(), [state])
        m.update()
        for _ in range(8):
            m.observe("error", 0.075)  # balanced now really gives 0.075
        op = m.update()
        assert op.knobs["knob"] == "accurate"
        assert m.switches == 2

    def test_state_switch(self):
        s1 = State("quality", "throughput", True, [Goal("e", "error", LE, 0.03)])
        s2 = State("speed", "throughput", True, [])
        m = Margot(_kb(), [s1, s2], "quality")
        assert m.update().knobs["knob"] == "balanced"
        m.switch_state("speed")
        assert m.update().knobs["knob"] == "fast"

    def test_proactive_features(self):
        kb_small = KnowledgeBase([OperatingPoint({"knob": "a"}, {"t": (1.0, 0)})])
        kb_big = KnowledgeBase([OperatingPoint({"knob": "b"}, {"t": (1.0, 0)})])
        m = Margot(_kb(), [State("s", "t", True)], feature_kbs={
            (10.0,): kb_small, (1000.0,): kb_big})
        assert m.update(features=(12.0,)).knobs["knob"] == "a"
        assert m.update(features=(900.0,)).knobs["knob"] == "b"


class TestExamon:
    def test_pubsub_and_collector(self):
        broker = ExamonBroker()
        coll = ExamonCollector("c", "power/*").init(broker)
        coll.start()
        for i in range(10):
            broker.publish("power/node0", float(i))
        broker.publish("other/topic", 999.0)
        assert coll.count() == 10
        assert coll.get() == 9.0
        assert coll.get_mean() == pytest.approx(4.5)
        assert coll.get_max() == 9.0
        coll.end()
        broker.publish("power/node0", 123.0)
        assert coll.count() == 10  # unsubscribed

    def test_percentile(self):
        broker = ExamonBroker()
        coll = ExamonCollector("c", "t").init(broker)
        coll.start()
        for i in range(100):
            broker.publish("t", float(i))
        assert coll.get_percentile(95) == pytest.approx(95.0, abs=2)


class TestPowerCapper:
    def test_converges_under_cap(self):
        model = RAPLModel()
        capper = PowerCapper(cap_watts=300.0, model=model)
        t1 = capper.register("train", priority=10)
        t2 = capper.register("background", priority=1)
        for _ in range(60):
            for tid in (t1, t2):
                f = capper.frequency(tid)
                capper.report(tid, model.power(0.9, f))
        assert capper.total_power() <= 300.0 * 1.05
        snap = {s["name"]: s for s in capper.snapshot()}
        # application-aware: high priority keeps higher frequency
        assert snap["train"]["freq"] >= snap["background"]["freq"]

    def test_agnostic_uniform(self):
        model = RAPLModel()
        capper = PowerCapper(cap_watts=300.0, model=model, agnostic=True)
        t1 = capper.register("a", 10)
        t2 = capper.register("b", 1)
        for _ in range(60):
            for tid in (t1, t2):
                capper.report(tid, model.power(0.9, capper.frequency(tid)))
        snap = {s["name"]: s for s in capper.snapshot()}
        assert snap["a"]["freq"] == pytest.approx(snap["b"]["freq"], abs=0.051)


class TestMemoTable:
    def test_wrap_semantics(self):
        calls = []

        def f(x):
            calls.append(x)
            return x * 2

        table = MemoTable(size=16)
        g = table.wrap(f)
        assert g(3) == 6 and g(3) == 6
        assert calls == [3]
        assert table.hit_rate == 0.5

    def test_stop_run_toggle(self):
        table = MemoTable()
        g = table.wrap(lambda x: x + 1)
        g(1)
        g(1)
        table.running = False
        g(1)
        assert table.hits == 1 and table.misses == 1  # third call bypassed

    def test_approx_keys(self):
        exact = MemoTable(approx_bits=0)
        approx = MemoTable(approx_bits=18)
        a, b = np.float32(1.0), np.float32(1.0 + 1e-4)
        assert exact.key_of(a) != exact.key_of(b)
        assert approx.key_of(a) == approx.key_of(b)

    def test_eviction_and_no_replace(self):
        t = MemoTable(size=2)
        t.update("a", 1); t.update("b", 2); t.update("c", 3)
        assert len(t) == 2
        assert t.lookup("a")[0] is False  # LRU-evicted
        t2 = MemoTable(size=1, replace=False)
        t2.update("a", 1); t2.update("b", 2)
        assert t2.lookup("a") == (True, 1)

    def test_persistence(self, tmp_path):
        p = str(tmp_path / "memo.pkl")
        t = MemoTable(save_path=p)
        t.update("k", 42)
        t.save()
        t2 = MemoTable(load_path=p)
        assert t2.lookup("k") == (True, 42)

    def test_full_offline(self):
        t = MemoTable(full_offline=True)
        t.update("k", 1)
        assert t.lookup("k")[0] is False

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 50), st.integers(0, 100)),
                    min_size=1, max_size=60))
    def test_property_table_size_bounded_and_consistent(self, ops):
        t = MemoTable(size=8)
        shadow = {}
        for k, v in ops:
            t.update(k, v)
            shadow[k] = v
        assert len(t) <= 8
        for k, v in shadow.items():
            hit, got = t.lookup(k)
            if hit:
                assert got == shadow[k]


class TestLibVC:
    def test_compile_cache_and_dispatch(self):
        builds = []

        def builder(name):
            builds.append(name)
            return {"__default__": lambda x: x,
                    "double": lambda x: 2 * x}[name]

        vc = LibVC(builder)
        assert vc(None, 5) == 5
        assert vc("double", 5) == 10
        assert vc("double", 7) == 14
        assert builds == ["__default__", "double"]  # cached
        assert vc.stats()["dispatch_counts"]["double"] == 2

    def test_error_strategies(self):
        def builder(name):
            if name == "broken":
                raise RuntimeError("nope")
            return lambda x: x

        vc = LibVC(builder, error_strategy="fallback")
        assert vc("broken", 1) == 1  # fell back to default
        vc2 = LibVC(builder, error_strategy="exit")
        with pytest.raises(RuntimeError):
            vc2("broken", 1)


class TestLat:
    def test_explore_and_csv(self, tmp_path):
        lat = Lat("t").add_var("threads", [1, 2, 4]).add_var_range(
            "size", 0, 2, 1, lambda x: 10 ** x)
        lat.add_metric("time", lambda threads, size: size / threads)
        lat.set_num_tests(2)
        results = lat.tune()
        assert len(results) == 6
        p = tmp_path / "out.csv"
        lat.to_csv(str(p))
        assert p.read_text().count("\n") == 7

    def test_feeds_knowledge_base(self):
        lat = Lat("t").add_var("k", [1, 2])
        lat.add_metric("speed", lambda k: float(k))
        lat.tune()
        kb = KnowledgeBase.from_dse(lat.results, ["k"], ["speed"])
        assert len(kb) == 2
