"""Quantized paged KV cache (the PR 7 precision layer).

Covers:
  - the quantization primitives: full-grid scale resolution
    (absmax / qmax), fixed-scale clipping writes, the 0.0 free-page
    sentinel, fp knob values resolving to "keep the fp pool";
  - in-kernel dequant parity: quantized paged / dense / widened-q
    flash_decode matches the fp kernel run on the explicitly dequantized
    values (the XLA `paged_gather_kv` path included);
  - PagedCacheManager scale sidecars: rows live exactly as long as their
    page (admit/retire/rollback), copy-on-write copies the donor's scale
    row, ring pools stay fp, stats() reports dtype-aware pool bytes —
    property-tested under random admit/share/CoW/rollback/retire churn;
  - end-to-end int8 serving: shared == unshared, speculative == plain
    greedy, identical waiting prompts grouped into one re-score;
  - the weave path (cache_<dtype> precision policies -> the
    "flash_cache_dtype" extra) and the accuracy-constrained dtype DSE
    (error column persisted, tightened budget forces the fp fallback,
    on-device rows keyed separately, runtime refinement keeps working
    with the categorical dtype knob).
"""

import sys

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.ops import (
    CACHE_QMAX,
    cache_qmax,
    dequantize_kv,
    flash_decode,
    kv_scale_from_absmax,
    paged_gather_kv,
    quantize_kv_write,
    resolve_cache_dtype,
)
from repro.runtime.pages import (
    PagedCacheManager,
    build_linear_pool,
    cdiv,
    quantize_linear_pool,
)

import jax


def _server(arch, **cfg_kw):
    from repro.configs.base import SHAPES
    from repro.core.program import Program
    from repro.launch.weave import default_weave
    from repro.runtime.server import Server, ServerConfig

    program = Program.from_arch(arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    return Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4,
                                      **cfg_kw))


PROMPTS = [np.ones((5,), np.int32),
           (np.arange(1, 9) % 50).astype(np.int32),
           np.full((3,), 7, np.int32)]


class TestQuantPrimitives:
    def test_scale_spans_full_code_grid(self):
        """The recorded scale is absmax/qmax — a raw absmax scale would
        round every int8 code into {-1, 0, 1}."""
        x = jnp.asarray(np.linspace(-3.0, 3.0, 64), jnp.float32)
        scale = kv_scale_from_absmax(jnp.max(jnp.abs(x)), jnp.int8)
        q = jnp.round(jnp.clip(x / scale, -127, 127))
        assert float(jnp.max(jnp.abs(q))) == 127.0

    @pytest.mark.parametrize("name", sorted(CACHE_QMAX))
    def test_roundtrip_error_bounded_by_half_step(self, name):
        dt = resolve_cache_dtype(name)
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((16, 2, 8)), jnp.float32)
        absmax = jnp.max(jnp.abs(x), axis=(0, 2))  # per-head (K,)
        scale = kv_scale_from_absmax(absmax, dt)
        q = quantize_kv_write(x, scale[None, :], dt)
        back = dequantize_kv(q, scale[None, :])
        step = float(jnp.max(absmax)) / cache_qmax(name)
        if name == "int8":
            bound = step / 2 + 1e-6
        else:  # fp grids are relative; e5m2's 2 mantissa bits are worst
            bound = float(jnp.max(absmax)) / 8
        assert float(jnp.max(jnp.abs(back - x))) <= bound

    def test_zero_scale_sentinel_is_safe(self):
        """scale == 0.0 marks a free page: the write path divides safely
        (no NaN/inf) and the read path dequantizes the page to zeros."""
        x = jnp.ones((4, 2, 8), jnp.float32)
        q = quantize_kv_write(x, jnp.zeros((4, 2)), jnp.int8)
        assert np.isfinite(np.asarray(q, np.float32)).all()
        back = dequantize_kv(q, jnp.zeros((4, 2)))
        assert not np.asarray(back).any()

    def test_fp_names_resolve_to_none(self):
        assert resolve_cache_dtype("float16") is None
        assert resolve_cache_dtype("bfloat16") is None
        assert resolve_cache_dtype(None) is None
        assert resolve_cache_dtype("int8") == jnp.int8


def _mixed_pool(dtype="int8", lengths=(5, 19, 32), ps=8, K=2, D=32):
    rng = np.random.default_rng(11)
    ks = [jnp.asarray(rng.standard_normal((L, K, D)), jnp.float32)
          for L in lengths]
    vs = [jnp.asarray(rng.standard_normal((L, K, D)), jnp.float32)
          for L in lengths]
    max_len = max(lengths)
    pk, pv, tables, pool = build_linear_pool(ks, vs, ps, max_len=max_len)
    qpk, qpv, ksc, vsc = quantize_linear_pool(pk, pv, dtype)
    return pk, pv, qpk, qpv, ksc, vsc, tables, max_len, lengths


class TestKernelDequantParity:
    """The in-kernel dequant must match running the fp kernel on the
    explicitly dequantized pool — same values, same block walk."""

    def test_paged_matches_dequantized_pool(self):
        (pk, pv, qpk, qpv, ksc, vsc, tables, max_len,
         lengths) = _mixed_pool()
        B, H, D = len(lengths), 4, pk.shape[-1]
        q = jnp.asarray(np.random.default_rng(0).standard_normal(
            (B, 1, H, D)), jnp.float32)
        index = jnp.asarray([L - 1 for L in lengths], jnp.int32)
        dk = dequantize_kv(qpk, ksc[:, None, :])
        dv = dequantize_kv(qpv, vsc[:, None, :])
        out_q = flash_decode(q, qpk, qpv, index, tables=tables,
                             kv_len=max_len, block_kv=8,
                             k_scale=ksc, v_scale=vsc)
        out_ref = flash_decode(q, dk, dv, index, tables=tables,
                               kv_len=max_len, block_kv=8)
        np.testing.assert_allclose(np.asarray(out_q), np.asarray(out_ref),
                                   atol=1e-5, rtol=1e-5)

    def test_widened_q_matches_dequantized_pool(self):
        """The speculative verify tile (S > 1 q tokens) dequantizes the
        same way — one launch scores the whole draft block."""
        (pk, pv, qpk, qpv, ksc, vsc, tables, max_len,
         lengths) = _mixed_pool(lengths=(13, 27, 32))
        B, S, H, D = len(lengths), 3, 4, pk.shape[-1]
        q = jnp.asarray(np.random.default_rng(1).standard_normal(
            (B, S, H, D)), jnp.float32)
        index = jnp.asarray([L - S for L in lengths], jnp.int32)
        dk = dequantize_kv(qpk, ksc[:, None, :])
        dv = dequantize_kv(qpv, vsc[:, None, :])
        out_q = flash_decode(q, qpk, qpv, index, tables=tables,
                             kv_len=max_len, block_kv=8,
                             k_scale=ksc, v_scale=vsc)
        out_ref = flash_decode(q, dk, dv, index, tables=tables,
                               kv_len=max_len, block_kv=8)
        np.testing.assert_allclose(np.asarray(out_q), np.asarray(out_ref),
                                   atol=1e-5, rtol=1e-5)

    def test_dense_scale_page_matches_dequantized(self):
        """The dense (ring/linear stacked) layout carries (B, NP, K)
        scales at `scale_page` granularity."""
        B, T, H, K, D, sp = 2, 64, 4, 2, 32, 16
        rng = np.random.default_rng(5)
        k = jnp.asarray(rng.standard_normal((B, T, K, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, T, K, D)), jnp.float32)
        q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
        nk = k.reshape(B, T // sp, sp, K, D)
        nv = v.reshape(B, T // sp, sp, K, D)
        ksc = kv_scale_from_absmax(
            jnp.max(jnp.abs(nk), axis=(2, 4)), jnp.int8)  # (B, NP, K)
        vsc = kv_scale_from_absmax(jnp.max(jnp.abs(nv), axis=(2, 4)),
                                   jnp.int8)
        qk = quantize_kv_write(nk, ksc[:, :, None, :],
                               jnp.int8).reshape(B, T, K, D)
        qv = quantize_kv_write(nv, vsc[:, :, None, :],
                               jnp.int8).reshape(B, T, K, D)
        dk = dequantize_kv(qk.reshape(B, T // sp, sp, K, D),
                           ksc[:, :, None, :]).reshape(B, T, K, D)
        dv = dequantize_kv(qv.reshape(B, T // sp, sp, K, D),
                           vsc[:, :, None, :]).reshape(B, T, K, D)
        index = jnp.asarray([T - 1, T - 9], jnp.int32)
        out_q = flash_decode(q, qk, qv, index, block_kv=16,
                             k_scale=ksc, v_scale=vsc, scale_page=sp)
        out_ref = flash_decode(q, dk, dv, index, block_kv=16)
        np.testing.assert_allclose(np.asarray(out_q), np.asarray(out_ref),
                                   atol=1e-5, rtol=1e-5)

    def test_xla_gather_dequantizes(self):
        (pk, pv, qpk, qpv, ksc, vsc, tables, max_len,
         lengths) = _mixed_pool()
        gk, gv = paged_gather_kv(qpk, qpv, tables, max_len,
                                 k_scale=ksc, v_scale=vsc)
        rk, rv = paged_gather_kv(dequantize_kv(qpk, ksc[:, None, :]),
                                 dequantize_kv(qpv, vsc[:, None, :]),
                                 tables, max_len)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(rk), atol=1e-6)
        np.testing.assert_allclose(np.asarray(gv), np.asarray(rv), atol=1e-6)


# -- manager sidecars under churn ---------------------------------------------

_PS, _MAXLEN, _K, _D = 8, 32, 2, 4


def _admit_cache(rng, L):
    k = rng.standard_normal((1, _MAXLEN, _K, _D))
    v = rng.standard_normal((1, _MAXLEN, _K, _D))
    k[:, L:] = 0.0
    v[:, L:] = 0.0
    return {"layers": {"k": jnp.asarray(k, jnp.float32),
                       "v": jnp.asarray(v, jnp.float32),
                       "index": jnp.full((1,), L, jnp.int32)}}


def _assert_sidecar_invariants(mgr):
    """A page's scale rows live exactly as long as the page: free pages
    hold the 0.0 sentinel, every referenced page holds positive scales."""
    free = set(mgr.pool._free)
    for name in mgr._groups:
        pools = mgr._pools.get(name)
        if not pools or "ksc" not in pools:
            continue
        ksc = np.asarray(pools["ksc"])
        vsc = np.asarray(pools["vsc"])
        for p in range(mgr.pool.num_pages):
            if p in free:
                assert not ksc[p].any(), (p, ksc[p])
                assert not vsc[p].any(), (p, vsc[p])
            else:
                assert (ksc[p] > 0).all(), (p, ksc[p])
                assert (vsc[p] > 0).all(), (p, vsc[p])


def _run_churn(ops):
    """Drive admit / share / CoW / rollback / retire against an int8 pool,
    checking the sidecar invariant after every op."""
    mgr = PagedCacheManager(24, _PS, max_len=_MAXLEN, cache_dtype="int8")
    rng = np.random.default_rng(0)
    live: dict[int, int] = {}     # rid -> prompt length
    shared: set[int] = set()
    next_rid = 0
    for code, arg in ops:
        op = ("admit", "share", "cow", "rollback", "retire")[code % 5]
        if op == "admit":
            L = 3 + arg % (_MAXLEN - 3)
            if not mgr.can_admit(L):
                continue
            mgr.admit(next_rid, _admit_cache(rng, L), final_len=L)
            live[next_rid] = L
            next_rid += 1
        elif op == "share" and live:
            donor = sorted(live)[arg % len(live)]
            L = live[donor]
            pages = list(mgr.pool.tables[donor])[:cdiv(L, _PS)]
            toks = np.ones((L,), np.int64)
            mgr.admit_shared(next_rid, toks, final_len=L, pages=pages)
            live[next_rid] = L
            shared.add(next_rid)
            next_rid += 1
        elif op == "cow" and shared:
            rid = sorted(shared)[arg % len(shared)]
            L = mgr._meta[rid]["length"]
            # only a mid-page next slot lands in a (possibly shared) page
            if L % _PS and L < _MAXLEN and mgr.pool.free_pages:
                mgr._cow_for_write(rid)
        elif op == "rollback" and live:
            rid = sorted(live)[arg % len(live)]
            new_len = max(1, live[rid] // 2)
            mgr.rollback(rid, new_len)
            live[rid] = new_len
        elif op == "retire" and live:
            rid = sorted(live)[arg % len(live)]
            mgr.retire(rid)
            del live[rid]
            shared.discard(rid)
        _assert_sidecar_invariants(mgr)
    return mgr


class TestManagerSidecars:
    def test_rows_live_with_their_page(self):
        rng = np.random.default_rng(1)
        mgr = PagedCacheManager(8, _PS, max_len=_MAXLEN, cache_dtype="int8")
        mgr.admit("a", _admit_cache(rng, 19), final_len=19)
        pools = mgr._pools["layers"]
        assert pools["pk"].dtype == jnp.int8
        pages = list(mgr.pool.tables["a"])
        ksc = np.asarray(pools["ksc"])
        assert all((ksc[p] > 0).all() for p in pages)
        _assert_sidecar_invariants(mgr)
        mgr.retire("a")
        assert not np.asarray(mgr._pools["layers"]["ksc"]).any()

    def test_cow_copies_the_donor_scale_row(self):
        rng = np.random.default_rng(2)
        mgr = PagedCacheManager(8, _PS, max_len=_MAXLEN, cache_dtype="int8")
        mgr.admit("a", _admit_cache(rng, 13), final_len=16)
        tail = mgr.pool.tables["a"][-1]
        mgr.admit_shared("b", np.ones((13,), np.int64), final_len=16,
                         pages=list(mgr.pool.tables["a"]))
        before = np.asarray(mgr._pools["layers"]["ksc"])[tail].copy()
        mgr._cow_for_write("b")
        assert mgr.cow_splits == 1
        new_tail = mgr.pool.tables["b"][-1]
        assert new_tail != tail
        after = np.asarray(mgr._pools["layers"]["ksc"])
        np.testing.assert_array_equal(after[new_tail], before)  # copied
        np.testing.assert_array_equal(after[tail], before)      # untouched
        _assert_sidecar_invariants(mgr)

    def test_rollback_pops_truncated_scales(self):
        rng = np.random.default_rng(4)
        mgr = PagedCacheManager(8, _PS, max_len=_MAXLEN, cache_dtype="int8")
        mgr.admit("a", _admit_cache(rng, 30), final_len=30)  # 4 pages
        dropped = mgr.pool.tables["a"][1:]
        mgr.rollback("a", 7)  # back to 1 page
        ksc = np.asarray(mgr._pools["layers"]["ksc"])
        assert all(not ksc[p].any() for p in dropped)
        _assert_sidecar_invariants(mgr)

    def test_ring_groups_stay_fp(self):
        mgr = PagedCacheManager(8, _PS, max_len=_MAXLEN, window=16,
                                cache_dtype="int8")
        assert mgr._quant_dtype({"ring": True}) is None
        assert mgr._quant_dtype({"ring": False}) == jnp.int8

    def test_stats_report_dtype_aware_bytes(self):
        rng = np.random.default_rng(6)
        managers = {}
        for name, dt in (("fp", None), ("q", "int8")):
            mgr = PagedCacheManager(8, _PS, max_len=_MAXLEN, cache_dtype=dt)
            mgr.admit("a", _admit_cache(rng, 19), final_len=19)
            managers[name] = mgr.stats()
        fp, q = managers["fp"], managers["q"]
        assert fp["cache_dtype"] is None and q["cache_dtype"] == "int8"
        # int8 payload + fp32 sidecars vs the fp32 pool
        assert q["page_hbm_bytes"] == 2 * _PS * _K * _D + 2 * _K * 4
        assert fp["page_hbm_bytes"] == 2 * _PS * _K * _D * 4
        assert q["pool_hbm_bytes"] == q["live_pages"] * q["page_hbm_bytes"]
        assert q["peak_pool_hbm_bytes"] == (q["peak_live_pages"]
                                            * q["page_hbm_bytes"])

    def test_deterministic_churn(self):
        rng = np.random.default_rng(42)
        for _ in range(4):
            ops = [(int(rng.integers(0, 5)), int(rng.integers(0, 10 ** 6)))
                   for _ in range(20)]
            _run_churn(ops)

    @given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 10 ** 6)),
                    min_size=1, max_size=20))
    @settings(max_examples=20, deadline=None)
    def test_churn_property(self, ops):
        _run_churn(ops)


class TestQuantizedServing:
    def test_shared_equals_unshared_int8(self):
        """Shared pages hold exactly the bytes an exclusive admission
        would have written — fixed first-write scales, never requantized —
        so prefix sharing stays bit-invisible under quantization."""
        srv = _server("yi-6b", cache_dtype="int8")
        base = (np.arange(1, 17) % 40 + 1).astype(np.int32)
        prompts = [np.concatenate([base, np.array([21, 22], np.int32)]),
                   np.concatenate([base, np.array([31], np.int32)])]
        out_s = srv.serve_continuous(prompts, page_size=8)
        assert srv.last_pool_stats["cache_dtype"] == "int8"
        assert srv.last_pool_stats["prefix_hits"] > 0
        out_u = srv.serve_continuous(prompts, page_size=8,
                                     prefix_sharing=False)
        for a, b in zip(out_s, out_u):
            np.testing.assert_array_equal(a, b)

    def test_speculative_equals_plain_greedy_int8(self):
        """Draft, verify and plain decode all read the same quantized
        pages at the same recorded scales — rollback frees pages without
        requantizing survivors, so speculation stays bit-exact."""
        srv = _server("yi-6b", cache_dtype="int8")
        spec = srv.serve_continuous(PROMPTS, page_size=8, draft_len=2)
        assert srv.last_pool_stats["cache_dtype"] == "int8"
        plain = srv.serve_continuous(PROMPTS, page_size=8)
        for s, p in zip(spec, plain):
            np.testing.assert_array_equal(s, p)

    def test_fp_knob_value_keeps_the_fp_pool(self):
        srv = _server("yi-6b", cache_dtype="float16")
        srv.serve_continuous(PROMPTS, page_size=8)
        assert srv.last_pool_stats["cache_dtype"] is None

    def test_woven_extra_selects_the_pool_dtype(self):
        srv = _server("yi-6b")
        srv.woven.state.extra["flash_cache_dtype"] = "int8"
        srv.serve_continuous(PROMPTS, page_size=8)
        assert srv.last_pool_stats["cache_dtype"] == "int8"

    def test_identical_waiting_prompts_grouped_into_one_rescore(self):
        """Satellite: N identical waiting prompts admit off a single
        re-score — one rescore dispatch, the rest ride its logits."""
        srv = _server("yi-6b")
        A = (np.arange(1, 10) % 23 + 1).astype(np.int32)
        prompts = [A, A.copy(), A.copy()]
        out = srv.serve_continuous(prompts, page_size=8)
        rescores = sum(srv.rescore_vc.dispatch_counts.values())
        assert rescores == 1, srv.rescore_vc.dispatch_counts
        assert srv.last_pool_stats["grouped_admissions"] == 1
        ref = srv.serve_continuous(prompts, page_size=8,
                                   prefix_sharing=False)
        for a, b in zip(out, ref):
            np.testing.assert_array_equal(a, b)


class TestPrecisionWeave:
    def test_cache_policy_name_parses(self):
        from repro.nn.dtypes import DTypePolicy

        pol = DTypePolicy.make("cache_int8")
        assert pol.cache_dtype == "int8"
        assert DTypePolicy.make("half").cache_dtype is None

    def test_change_precision_weaves_cache_extra(self):
        from repro.core.program import Program
        from repro.core.strategies.precision import ChangePrecision
        from repro.core.weaver import Weaver

        program = Program.from_arch("gemma-2b", reduced=True)
        woven = Weaver(program).weave([ChangePrecision("*", "cache_int8")])
        assert woven.state.extra["flash_cache_dtype"] == "int8"
        # storage-only: no compute policy override was installed
        assert len(woven.state.policies.entries) == 1  # the "*" default

    def test_mixed_versions_include_cache_variants(self):
        from repro.core.program import Program
        from repro.core.strategies.precision import MixedPrecisionVersions
        from repro.core.weaver import Weaver

        program = Program.from_arch("gemma-2b", reduced=True)
        aspect = MixedPrecisionVersions(["*"],
                                        policies=("float", "cache_int8"))
        woven = Weaver(program).weave([aspect])
        cache_states = [
            woven.variant_state(n) for n in aspect.generated
            if woven.variant_state(n).extra.get("flash_cache_dtype")
        ]
        assert cache_states
        assert cache_states[0].extra["flash_cache_dtype"] == "int8"


def _stub_measures(err_by_dtype):
    def measure(**kn):
        return 1.0

    def error(**kn):
        return err_by_dtype.get(str(kn["cache_dtype"]), 0.0)

    return measure, error


class TestQuantizedCacheDSE:
    def _sig(self):
        from repro.autotune.kernel_tuner import quantized_cache_signature

        return quantized_cache_signature(2, 256, 4, 2, 64, "float32")

    def _tune(self, tmp_path, err=None, budget=0.05):
        from repro.autotune.kernel_tuner import (
            KernelTuner,
            tune_quantized_cache,
        )

        tuner = KernelTuner(str(tmp_path / "q.json"))
        measure, error = _stub_measures(
            err or {"int8": 0.02, "float8_e4m3fn": 0.2, "float8_e5m2": 0.2})
        sig = self._sig()
        knobs = tune_quantized_cache(sig, error_budget=budget, tuner=tuner,
                                     measure=measure, error_measure=error)
        return tuner, sig, knobs

    def test_space_has_the_dtype_knob(self):
        from repro.autotune.kernel_tuner import KERNEL_SPACES

        space = KERNEL_SPACES["quantized_cache"]
        assert "float16" in space["cache_dtype"]
        assert "int8" in space["cache_dtype"]

    def test_dse_persists_error_column_and_picks_capacity(self, tmp_path):
        tuner, sig, knobs = self._tune(tmp_path)
        # int8 halves pool bytes and fits the budget -> beats float16
        assert knobs["cache_dtype"] == "int8"
        entry = tuner.cache.get(tuner._key(sig))
        assert entry["error_budget"] == 0.05
        assert entry["device"] == "interpret"
        for row in entry["ops"]:
            assert "max_logit_err" in row["metrics"]
            assert "tokens_per_hbm_byte" in row["metrics"]

    def test_pool_bytes_model_favours_int8(self):
        from repro.autotune.kernel_tuner import quantized_pool_bytes

        sig = self._sig()
        kn = {"page_size": 128, "block_kv_dec": 128}
        b_fp = quantized_pool_bytes(sig, {**kn, "cache_dtype": "float16"})
        b_q = quantized_pool_bytes(sig, {**kn, "cache_dtype": "int8"})
        assert b_q / b_fp <= 0.55

    def test_tightened_budget_forces_fp_fallback(self, tmp_path):
        from repro.autotune.kernel_tuner import select_cache_knobs

        tuner, sig, knobs = self._tune(tmp_path)
        assert knobs["cache_dtype"] == "int8"
        tight = select_cache_knobs(sig, error_budget=1e-6, tuner=tuner)
        assert tight["cache_dtype"] == "float16"
        entry = tuner.cache.get(tuner._key(sig))
        assert entry["error_budget"] == 1e-6  # persisted with the re-pick
        back = select_cache_knobs(sig, error_budget=0.05, tuner=tuner)
        assert back["cache_dtype"] == "int8"

    def test_untuned_signature_selects_none(self, tmp_path):
        from repro.autotune.kernel_tuner import (
            KernelTuner,
            select_cache_knobs,
        )

        tuner = KernelTuner(str(tmp_path / "none.json"))
        assert select_cache_knobs(self._sig(), error_budget=0.05,
                                  tuner=tuner) is None

    def test_on_device_rows_key_separately(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNER_ON_DEVICE", "1")
        tuner, sig, knobs = self._tune(tmp_path)
        dev = str(jax.default_backend())
        assert tuner._key(sig).endswith(f"@{dev}")
        entry = tuner.cache.get(tuner._key(sig))
        assert entry["device"] == dev
        monkeypatch.delenv("REPRO_TUNER_ON_DEVICE")
        # interpret lookups never see the on-device row
        assert tuner.lookup(sig) is None

    def test_runtime_refinement_keeps_categorical_knobs(self, tmp_path):
        from repro.autotune.kernel_tuner import refine_from_runtime

        tuner, sig, knobs = self._tune(tmp_path)
        refined = refine_from_runtime(sig, {"latency_s": 2.0}, tuner=tuner)
        assert isinstance(refined["cache_dtype"], str)
        entry = tuner.cache.get(tuner._key(sig))
        assert entry["error_budget"] == 0.05  # extra columns survive
        assert "runtime" in entry

    def test_tuned_aspect_weaves_cache_dtype_extra(self, tmp_path,
                                                   monkeypatch):
        from repro.autotune.kernel_tuner import (
            KernelTuner,
            tune_quantized_cache,
        )
        from repro.core.program import Program
        from repro.core.strategies.kernels import TunedKernelAspect
        from repro.core.weaver import Weaver

        path = str(tmp_path / "weave.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        program = Program.from_arch("gemma-2b", reduced=True)
        aspect = TunedKernelAspect(2, 256, dtype="bfloat16", cache_len=256)
        sig = aspect.quantized_signature(program.cfg)
        measure, error = _stub_measures({"int8": 0.01})
        tune_quantized_cache(sig, tuner=KernelTuner(path), measure=measure,
                             error_measure=error)
        woven = Weaver(program).weave([aspect])
        assert woven.state.extra["flash_cache_dtype"] == "int8"
        assert "flash_cache_dtype" in woven.knobs
        assert woven.knobs["flash_cache_dtype"].default == "int8"
