"""Optional-hypothesis shim: property tests skip cleanly when the package is
absent (it is a dev-only dependency, see requirements-dev.txt).

Usage in test modules:

    from _hypothesis_compat import given, settings, st

With hypothesis installed these are the real objects; without it, `given`
becomes a skip marker and `settings` / `st.*` become inert placeholders so
module-level decorators still evaluate.
"""

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAS_HYPOTHESIS = False

    class _InertStrategies:
        """st.sampled_from(...) etc. evaluate to None placeholders."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _InertStrategies()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")
