"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles,
with hypothesis sweeps over shapes/dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.ops import rglru_pallas
from repro.kernels.rglru.ref import rglru_assoc, rglru_scan
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rwkv6.ops import wkv_pallas
from repro.kernels.rwkv6.ref import wkv_chunked, wkv_scan


def _qkv(key, B, S, H, K, D, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize("causal,window,softcap", [
        (True, None, None), (True, 64, None), (False, None, None),
        (True, None, 30.0), (True, 32, 20.0),
    ])
    def test_masks(self, key, causal, window, softcap):
        q, k, v = _qkv(key, 2, 256, 4, 2, 64)
        out = flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=128, block_kv=128,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=causal, window=window,
                            softcap=softcap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @settings(max_examples=12, deadline=None)
    @given(
        B=st.sampled_from([1, 2]),
        S=st.sampled_from([128, 256, 384]),
        HK=st.sampled_from([(4, 4), (4, 2), (8, 1)]),
        D=st.sampled_from([64, 128]),
        dtype=st.sampled_from(["float32", "bfloat16"]),
    )
    def test_property_sweep(self, B, S, HK, D, dtype):
        H, K = HK
        dt = jnp.float32 if dtype == "float32" else jnp.bfloat16
        q, k, v = _qkv(jax.random.PRNGKey(B * S + H + D), B, S, H, K, D, dt)
        out = flash_attention(q, k, v, causal=True, block_q=128, block_kv=128,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        tol = 2e-5 if dtype == "float32" else 2e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=tol, atol=tol,
        )

    def test_gqa_group_mapping(self, key):
        """Each q head must attend its own kv group."""
        B, S, H, K, D = 1, 128, 4, 2, 64
        q, k, v = _qkv(key, B, S, H, K, D)
        # make kv head 1 wildly different; heads 2,3 map to it
        v = v.at[:, :, 1].mul(100.0)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-3)


class TestWKV6:
    def test_chunked_vs_scan(self, key):
        B, S, H, C = 2, 100, 3, 16
        ks = jax.random.split(key, 5)
        r, k, v = (jax.random.normal(ks[i], (B, S, H, C)) for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, C))) * 0.98 + 0.01
        u = jax.random.normal(ks[4], (H, C))
        s0 = jax.random.normal(key, (B, H, C, C))
        y1, sl1 = wkv_scan(r, k, v, w, u, s0)
        y2, sl2 = wkv_chunked(r, k, v, w, u, s0, chunk=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
        np.testing.assert_allclose(np.asarray(sl1), np.asarray(sl2), atol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(
        S=st.sampled_from([32, 64, 96]),
        C=st.sampled_from([8, 16]),
        chunk=st.sampled_from([16, 32]),
        decay_scale=st.sampled_from([0.5, 3.0]),  # strong decays too
    )
    def test_pallas_property(self, S, C, chunk, decay_scale):
        B, H = 2, 2
        key = jax.random.PRNGKey(S * C + chunk)
        ks = jax.random.split(key, 5)
        r, k, v = (jax.random.normal(ks[i], (B, S, H, C)) for i in range(3))
        w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, C)) * decay_scale))
        u = jax.random.normal(ks[4], (H, C))
        s0 = jax.random.normal(key, (B, H, C, C))
        y1, sl1 = wkv_scan(r, k, v, w, u, s0)
        y2, sl2 = wkv_pallas(r, k, v, w, u, s0, chunk=chunk)
        # strong decays amplify fp32 ordering differences; scale-aware tol
        scale = float(np.max(np.abs(np.asarray(y1)))) + 1.0
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=5e-3, atol=5e-3 * scale)
        np.testing.assert_allclose(np.asarray(sl1), np.asarray(sl2),
                                   rtol=5e-3, atol=5e-3)


class TestRGLRU:
    @settings(max_examples=8, deadline=None)
    @given(
        S=st.sampled_from([17, 64, 100]),
        D=st.sampled_from([8, 24, 64]),
        chunk=st.sampled_from([16, 32]),
    )
    def test_pallas_property(self, S, D, chunk):
        B = 2
        key = jax.random.PRNGKey(S * D)
        ks = jax.random.split(key, 3)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, D)))
        b = jax.random.normal(ks[1], (B, S, D))
        h0 = jax.random.normal(ks[2], (B, D))
        y1, hl1 = rglru_scan(a, b, h0)
        y2, hl2 = rglru_pallas(a, b, h0, block_d=8, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
        np.testing.assert_allclose(np.asarray(hl1), np.asarray(hl2), atol=1e-4)

    def test_assoc_matches_scan(self, key):
        B, S, D = 3, 77, 16
        ks = jax.random.split(key, 3)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, D)))
        b = jax.random.normal(ks[1], (B, S, D))
        h0 = jax.random.normal(ks[2], (B, D))
        y1, _ = rglru_scan(a, b, h0)
        y2, _ = rglru_assoc(a, b, h0)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


class TestRMSNorm:
    @settings(max_examples=8, deadline=None)
    @given(rows=st.sampled_from([1, 7, 300]), d=st.sampled_from([64, 128, 512]),
           dtype=st.sampled_from(["float32", "bfloat16"]))
    def test_property(self, rows, d, dtype):
        dt = jnp.float32 if dtype == "float32" else jnp.bfloat16
        key = jax.random.PRNGKey(rows + d)
        x = jax.random.normal(key, (rows, d), dt)
        w = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
        out = rmsnorm(x, w, block_rows=64)
        ref = rmsnorm_ref(x, w)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)


class TestFlashAttentionGrad:
    def test_custom_vjp_matches_reference_grads(self, key):
        """flash_attention is trainable: grads match the oracle's."""
        B, S, H, K, D = 1, 128, 4, 2, 64
        q, k, v = _qkv(key, B, S, H, K, D)

        def loss_flash(q, k, v):
            out = flash_attention(q, k, v, causal=True, block_q=64,
                                  block_kv=64, interpret=True)
            return jnp.sum(out.astype(jnp.float32) ** 2)

        def loss_ref(q, k, v):
            out = attention_ref(q, k, v, causal=True)
            return jnp.sum(out.astype(jnp.float32) ** 2)

        g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-3)
