"""The decode-path Pallas kernel (PR 3): one new token against a linear or
ring KV cache, streaming only the live cache blocks.

Covers the acceptance criteria:
  - parity with `xla_attention` across ring/linear caches, GQA, softcap and
    cache-wrap (index > W) cases, fp32 and bf16;
  - `decode_schedule` exactness: exactly ceil(min(W, index+1)/block_kv)
    blocks stream per token, never a dead block;
  - the O(W) streamed-block bound (decode traffic independent of max_len);
  - batched multi-request serving: `Server.serve_batch` output equals
    per-request `serve`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.decode import (
    decode_schedule,
    decode_steps_for,
    flash_decode_fwd,
    vmem_bytes_dec,
)
from repro.kernels.flash_attention.kernel import cdiv
from repro.kernels.flash_attention.ops import flash_decode
from repro.nn.attention import (
    Attention,
    _mask_dense,
    init_cache,
    init_ring_cache,
    xla_attention,
)
from repro.nn.dtypes import PolicyResolver
from repro.nn.module import Ctx, init_params


def _qkv_cache(key, B, H, K, D, T, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, K, D), dtype)
    v = jax.random.normal(ks[2], (B, T, K, D), dtype)
    return q, k, v


def _ref_decode(q, k, v, idx, mask_kind, window, softcap=None):
    """xla_attention with the linear-cache decode mask (slot s = pos s)."""
    B = q.shape[0]
    T = k.shape[1]
    ar = jnp.arange(T, dtype=jnp.int32)
    kv_pos = jnp.where(ar[None] <= idx[:, None], ar[None], -1)
    kv_pos = jnp.broadcast_to(kv_pos, (B, T))
    mask = _mask_dense(idx[:, None], kv_pos, mask_kind, window)[:, None, None]
    return xla_attention(q, k, v, mask, softcap=softcap)


class TestKernelParity:
    """flash_decode == xla_attention over the same masked cache."""

    @pytest.mark.parametrize("name,HK,T,idx,window,softcap,bkv", [
        ("causal", (4, 2), 128, [0, 63, 127], None, None, 32),
        ("gqa8", (8, 1), 96, [5, 40, 95], None, None, 32),
        ("mha", (4, 4), 64, [10, 30, 63], None, None, 16),
        ("window", (4, 2), 128, [3, 64, 127], 48, None, 32),
        ("softcap", (4, 2), 96, [7, 50, 95], None, 30.0, 32),
        ("ragged_cache", (4, 2), 100, [0, 37, 99], 24, None, 32),
        ("block_gt_cache", (2, 2), 48, [0, 20, 47], None, None, 512),
    ])
    def test_parity_fp32(self, key, name, HK, T, idx, window, softcap, bkv):
        H, K = HK
        q, k, v = _qkv_cache(key, len(idx), H, K, 64, T)
        idx = jnp.asarray(idx, jnp.int32)
        out = flash_decode(q, k, v, idx, window=window, softcap=softcap,
                           block_kv=bkv, interpret=True)
        ref = _ref_decode(q, k, v, idx, "sliding" if window else "causal",
                          window, softcap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_parity_bf16(self, key):
        q, k, v = _qkv_cache(key, 2, 4, 2, 64, 128, jnp.bfloat16)
        idx = jnp.asarray([17, 127], jnp.int32)
        out = flash_decode(q, k, v, idx, softcap=20.0, block_kv=32,
                           interpret=True)
        ref = _ref_decode(q, k, v, idx, "causal", None, 20.0)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_pruned_matches_dense(self, key):
        """The clamp-and-elide remapping must not change the math."""
        q, k, v = _qkv_cache(key, 3, 4, 2, 64, 160)
        idx = jnp.asarray([4, 80, 159], jnp.int32)
        kw = dict(window=64, block_kv=32, interpret=True)
        out_p = flash_decode(q, k, v, idx, pruned=True, **kw)
        out_d = flash_decode(q, k, v, idx, pruned=False, **kw)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                                   rtol=2e-6, atol=2e-6)

    def test_scalar_index_broadcasts(self, key):
        q, k, v = _qkv_cache(key, 2, 4, 2, 64, 64)
        out_s = flash_decode(q, k, v, jnp.asarray(31, jnp.int32),
                             block_kv=16, interpret=True)
        out_v = flash_decode(q, k, v, jnp.full((2,), 31, jnp.int32),
                             block_kv=16, interpret=True)
        np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_v))

    def test_gqa_group_mapping(self, key):
        """Each q head must attend its own kv head through the folded
        group layout (scale kv head 1's values and check heads 2-3 move)."""
        B, H, K, D, T = 1, 4, 2, 64, 64
        q, k, v = _qkv_cache(key, B, H, K, D, T)
        idx = jnp.asarray([T - 1], jnp.int32)
        base = flash_decode(q, k, v, idx, block_kv=16, interpret=True)
        v2 = v.at[:, :, 1].mul(100.0)
        out = flash_decode(q, k, v2, idx, block_kv=16, interpret=True)
        delta = jnp.max(jnp.abs(out - base), axis=(0, 1, 3))  # per q head
        assert float(jnp.max(delta[:2])) < 1e-6  # group 0 untouched
        assert float(jnp.min(delta[2:])) > 1.0   # group 1 scaled


class TestModuleDecode:
    """Attention._decode pallas impl == xla impl over real cache streams."""

    POL = PolicyResolver.default("double")

    def _attn(self, mask, window, softcap=None, H=4, K=2):
        attn = Attention("attn", 64, H, K, 64, mask=mask, window=window,
                         softcap=softcap)
        params = init_params(attn, jax.random.PRNGKey(1), self.POL)
        return attn, params

    def _ctx(self, impl):
        return Ctx(policies=self.POL, impls=[("*", "attention", impl)],
                   extra={"cache_max_len": 64})

    def _decode_seq(self, attn, params, cache, impl, steps, start, B):
        outs = []
        key = jax.random.PRNGKey(3)
        for t in range(steps):
            x = jax.random.normal(jax.random.fold_in(key, t), (B, 1, 64))
            pos = jnp.full((B, 1), start + t, jnp.int32)
            y, cache = attn(params, x, ctx=self._ctx(impl), positions=pos,
                            mode="decode", cache=cache)
            outs.append(np.asarray(y, np.float32))
        return np.stack(outs), cache

    def test_ring_cache_wrap(self, key):
        """Sliding window, decode *past* the wrap point (index > W)."""
        attn, params = self._attn("sliding", 16)
        B = 2
        xpre = jax.random.normal(jax.random.PRNGKey(9), (B, 24, 64))
        _, cache0 = attn(params, xpre, ctx=self._ctx("xla"), mode="prefill")
        assert "pos" in cache0 and cache0["k"].shape[1] == 16  # ring, W slots
        o_x, c_x = self._decode_seq(attn, params, cache0, "xla", 20, 24, B)
        o_p, c_p = self._decode_seq(attn, params, cache0, "pallas", 20, 24, B)
        np.testing.assert_allclose(o_x, o_p, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(c_x["pos"]),
                                      np.asarray(c_p["pos"]))
        assert int(c_p["index"]) == 44  # wrapped nearly 3x

    def test_linear_cache_growth(self, key):
        """Causal decode from an empty cache: index 0 -> 10."""
        attn, params = self._attn("causal", None)
        cache0 = init_cache(2, 32, 2, 64, jnp.float32)
        o_x, _ = self._decode_seq(attn, params, cache0, "xla", 10, 0, 2)
        o_p, c_p = self._decode_seq(attn, params, cache0, "pallas", 10, 0, 2)
        np.testing.assert_allclose(o_x, o_p, rtol=1e-5, atol=1e-5)
        assert int(c_p["index"]) == 10

    def test_linear_cache_sliding_window(self, key):
        """window >= prefill length keeps the cache linear — the kernel must
        then apply the window mask itself."""
        attn, params = self._attn("sliding", 8)
        cache0 = init_cache(2, 40, 2, 64, jnp.float32)
        o_x, _ = self._decode_seq(attn, params, cache0, "xla", 24, 0, 2)
        o_p, _ = self._decode_seq(attn, params, cache0, "pallas", 24, 0, 2)
        np.testing.assert_allclose(o_x, o_p, rtol=1e-5, atol=1e-5)

    def test_per_request_index_linear(self, key):
        """Stacked serving caches: (B,) index, every request at a different
        fill level."""
        attn, params = self._attn("causal", None)
        B = 3
        cache = init_cache(B, 32, 2, 64, jnp.float32)
        cache["index"] = jnp.asarray([0, 7, 31], jnp.int32)
        k = jax.random.PRNGKey(11)
        cache["k"] = jax.random.normal(k, cache["k"].shape, jnp.float32)
        cache["v"] = jax.random.normal(jax.random.fold_in(k, 1),
                                       cache["v"].shape, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(k, 2), (B, 1, 64))
        pos_in = cache["index"][:, None]
        y_x, c_x = attn(params, x, ctx=self._ctx("xla"),
                        positions=pos_in, mode="decode", cache=cache)
        y_p, c_p = attn(params, x, ctx=self._ctx("pallas"),
                        positions=pos_in, mode="decode", cache=cache)
        np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(c_x["index"]),
                                      np.asarray(c_p["index"]))

    def test_per_request_index_ring(self, key):
        """Ring caches at *different wrap levels* per request: build each
        request's cache by actually decoding a B=1 stream, stack them, then
        one batched step must match xla — including requests past the wrap
        point."""
        attn, params = self._attn("sliding", 12)
        per_req_steps = (1, 5, 17)  # unwrapped / near-full / wrapped
        caches = []
        for steps in per_req_steps:
            c = init_ring_cache(1, 12, 2, 64, jnp.float32)
            _, c = self._decode_seq(attn, params, c, "xla", steps, 0, 1)
            caches.append(c)
        cache = {
            "k": jnp.concatenate([c["k"] for c in caches], axis=0),
            "v": jnp.concatenate([c["v"] for c in caches], axis=0),
            "pos": jnp.stack([c["pos"] for c in caches], axis=0),
            "index": jnp.stack([c["index"] for c in caches]),
        }
        B = len(per_req_steps)
        x = jax.random.normal(jax.random.PRNGKey(21), (B, 1, 64))
        pos_in = cache["index"][:, None]
        y_x, c_x = attn(params, x, ctx=self._ctx("xla"),
                        positions=pos_in, mode="decode", cache=cache)
        y_p, c_p = attn(params, x, ctx=self._ctx("pallas"),
                        positions=pos_in, mode="decode", cache=cache)
        np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(c_x["pos"]),
                                      np.asarray(c_p["pos"]))


class TestDecodeSchedule:
    """The numpy oracle: exact live-block streaming, never a dead block."""

    @pytest.mark.parametrize("T,bkv", [(128, 32), (512, 128), (100, 32),
                                       (2048, 512)])
    def test_exact_block_count(self, T, bkv):
        """Acceptance: exactly ceil(min(W, index+1)/block_kv) blocks per
        token for a ring/linear cache of W slots."""
        for index in (0, 1, bkv - 1, bkv, T // 2, T - 1, T, 3 * T):
            sched = decode_schedule(T, index, bkv)
            assert len(sched) == max(1, cdiv(min(T, index + 1), bkv)), \
                (T, index, bkv)
            assert sched == list(range(len(sched)))

    def test_no_dead_block_streamed(self):
        """Every streamed block must contain at least one live slot; every
        live slot must be covered."""
        T, bkv = 256, 32
        for index in (0, 5, 31, 32, 100, 255):
            for window in (None, 40, 200):
                sched = decode_schedule(T, index, bkv, window=window)
                live = min(T, index + 1)
                lo_slot = 0 if window is None else max(0, index + 1 - window)
                for ik in sched:
                    k0, k1 = ik * bkv, min((ik + 1) * bkv, T) - 1
                    assert k0 < live, (index, window, ik)  # causal-live
                    assert k1 >= lo_slot, (index, window, ik)  # window-live
                covered = {s for ik in sched
                           for s in range(ik * bkv, min((ik + 1) * bkv, T))}
                want = set(range(lo_slot, live))
                assert want <= covered, (index, window, want - covered)

    def test_dense_streams_everything(self):
        assert decode_schedule(256, 3, 64, pruned=False) == [0, 1, 2, 3]

    def test_steps_bounds_schedule(self):
        """The pruned kernel's *grid* is decode_steps_for long, so the bound
        must hold for EVERY index — exhaustive over small configs."""
        for T, bkv, w in ((256, 64, None), (256, 64, 100), (100, 32, 24),
                          (256, 64, 64), (256, 64, 65), (96, 32, 33)):
            steps = decode_steps_for(T, bkv, w)
            for index in range(0, 3 * T):
                assert len(decode_schedule(T, index, bkv, window=w)) <= steps, \
                    (T, bkv, w, index)

    def test_o_w_bound(self):
        """Decode traffic is O(W), independent of max_len: a ring cache of W
        slots streams ceil(W/bkv) blocks regardless of how long the stream
        has run, and a full linear sweep to max_len streams ~max_len/bkv
        *total* — the pruned per-token count never exceeds the window's."""
        bkv = 128
        for W in (128, 512, 2048):
            ring_blocks = len(decode_schedule(W, 10 ** 9, bkv))
            assert ring_blocks == cdiv(W, bkv)  # O(W), not O(stream length)
        # linear cache under a window: per-token traffic bounded by the
        # window, not by the 8k cache
        T, W = 8192, 512
        worst = max(
            len(decode_schedule(T, idx, bkv, window=W))
            for idx in range(0, T, 97)
        )
        assert worst <= cdiv(W, bkv) + 1  # +1: window straddles a block edge
        assert worst * bkv < T / 4       # far below the dense O(max_len)

    def test_kernel_streams_only_scheduled_blocks(self, key):
        """Poison the cache outside the scheduled blocks: the kernel output
        must not change — those blocks are never part of the math (their
        DMAs are elided on TPU; interpret mode at least proves masking)."""
        B, H, K, D, T, bkv = 1, 4, 2, 64, 128, 32
        q, k, v = _qkv_cache(key, B, H, K, D, T)
        index = jnp.asarray([40], jnp.int32)
        sched = decode_schedule(T, 40, bkv)
        out = flash_decode(q, k, v, index, block_kv=bkv, interpret=True)
        dead = [ik for ik in range(cdiv(T, bkv)) if ik not in sched]
        assert dead, "test needs at least one dead block"
        for ik in dead:
            sl = slice(ik * bkv, (ik + 1) * bkv)
            k = k.at[:, sl].set(jnp.nan)
            v = v.at[:, sl].set(jnp.nan)
        out2 = flash_decode(q, k, v, index, block_kv=bkv, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


class TestVmemBytesDec:
    def test_monotone_in_block(self):
        assert vmem_bytes_dec(4, 512, 128) > vmem_bytes_dec(4, 128, 128)

    def test_group_floor(self):
        """Sub-8 groups pad to the TPU sublane floor."""
        assert vmem_bytes_dec(1, 256, 128) == vmem_bytes_dec(8, 256, 128)
        assert vmem_bytes_dec(16, 256, 128) > vmem_bytes_dec(8, 256, 128)

    def test_default_fits_vmem(self):
        assert vmem_bytes_dec(8, 512, 256) < 16 * 2 ** 20


class TestBatchedServer:
    """serve_batch == per-request serve (the runtime-layer deliverable)."""

    def _server(self, arch):
        from repro.configs.base import SHAPES
        from repro.core.program import Program
        from repro.launch.weave import default_weave
        from repro.runtime.server import Server, ServerConfig

        program = Program.from_arch(arch, kind="serve", reduced=True)
        woven = default_weave(program, SHAPES["prefill_32k"], {})
        return Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4))

    @pytest.mark.parametrize("arch", ["yi-6b", "mixtral-8x22b", "rwkv6-3b"])
    def test_batched_equals_per_request(self, arch):
        srv = self._server(arch)
        prompts = [np.ones((5,), np.int32),
                   (np.arange(1, 9) % 50).astype(np.int32),
                   np.full((3,), 7, np.int32)]
        batched = srv.serve_batch(prompts)
        assert len(batched) == 3
        for p, got in zip(prompts, batched):
            solo = srv.serve(p[None])[0]
            np.testing.assert_array_equal(got, solo)

    def test_memoized_batch(self):
        srv = self._server("yi-6b")
        from repro.memo.table import MemoTable

        srv.memo = MemoTable(size=8)
        prompts = [np.ones((4,), np.int32), np.zeros((6,), np.int32)]
        a = srv.serve_batch(prompts)
        b = srv.serve_batch(prompts)
        assert srv.memo.hits >= 1
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
