"""The decode-path Pallas kernel (PR 3): one new token against a linear or
ring KV cache, streaming only the live cache blocks.

Covers the acceptance criteria:
  - parity with `xla_attention` across ring/linear caches, GQA, softcap and
    cache-wrap (index > W) cases, fp32 and bf16;
  - `decode_schedule` exactness: exactly ceil(min(W, index+1)/block_kv)
    blocks stream per token, never a dead block;
  - the O(W) streamed-block bound (decode traffic independent of max_len);
  - batched multi-request serving: `Server.serve_batch` output equals
    per-request `serve`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.decode import (
    decode_schedule,
    decode_steps_for,
    flash_decode_fwd,
    page_block_kv,
    paged_decode_schedule,
    vmem_bytes_dec,
)
from repro.kernels.flash_attention.kernel import cdiv
from repro.kernels.flash_attention.ops import flash_decode
from repro.nn.attention import (
    Attention,
    _mask_dense,
    init_cache,
    init_ring_cache,
    xla_attention,
)
from repro.nn.dtypes import PolicyResolver
from repro.nn.module import Ctx, init_params


def _qkv_cache(key, B, H, K, D, T, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, K, D), dtype)
    v = jax.random.normal(ks[2], (B, T, K, D), dtype)
    return q, k, v


def _ref_decode(q, k, v, idx, mask_kind, window, softcap=None):
    """xla_attention with the linear-cache decode mask (slot s = pos s)."""
    B = q.shape[0]
    T = k.shape[1]
    ar = jnp.arange(T, dtype=jnp.int32)
    kv_pos = jnp.where(ar[None] <= idx[:, None], ar[None], -1)
    kv_pos = jnp.broadcast_to(kv_pos, (B, T))
    mask = _mask_dense(idx[:, None], kv_pos, mask_kind, window)[:, None, None]
    return xla_attention(q, k, v, mask, softcap=softcap)


class TestKernelParity:
    """flash_decode == xla_attention over the same masked cache."""

    @pytest.mark.parametrize("name,HK,T,idx,window,softcap,bkv", [
        ("causal", (4, 2), 128, [0, 63, 127], None, None, 32),
        ("gqa8", (8, 1), 96, [5, 40, 95], None, None, 32),
        ("mha", (4, 4), 64, [10, 30, 63], None, None, 16),
        ("window", (4, 2), 128, [3, 64, 127], 48, None, 32),
        ("softcap", (4, 2), 96, [7, 50, 95], None, 30.0, 32),
        ("ragged_cache", (4, 2), 100, [0, 37, 99], 24, None, 32),
        ("block_gt_cache", (2, 2), 48, [0, 20, 47], None, None, 512),
    ])
    def test_parity_fp32(self, key, name, HK, T, idx, window, softcap, bkv):
        H, K = HK
        q, k, v = _qkv_cache(key, len(idx), H, K, 64, T)
        idx = jnp.asarray(idx, jnp.int32)
        out = flash_decode(q, k, v, idx, window=window, softcap=softcap,
                           block_kv=bkv, interpret=True)
        ref = _ref_decode(q, k, v, idx, "sliding" if window else "causal",
                          window, softcap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_parity_bf16(self, key):
        q, k, v = _qkv_cache(key, 2, 4, 2, 64, 128, jnp.bfloat16)
        idx = jnp.asarray([17, 127], jnp.int32)
        out = flash_decode(q, k, v, idx, softcap=20.0, block_kv=32,
                           interpret=True)
        ref = _ref_decode(q, k, v, idx, "causal", None, 20.0)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_pruned_matches_dense(self, key):
        """The clamp-and-elide remapping must not change the math."""
        q, k, v = _qkv_cache(key, 3, 4, 2, 64, 160)
        idx = jnp.asarray([4, 80, 159], jnp.int32)
        kw = dict(window=64, block_kv=32, interpret=True)
        out_p = flash_decode(q, k, v, idx, pruned=True, **kw)
        out_d = flash_decode(q, k, v, idx, pruned=False, **kw)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                                   rtol=2e-6, atol=2e-6)

    def test_scalar_index_broadcasts(self, key):
        q, k, v = _qkv_cache(key, 2, 4, 2, 64, 64)
        out_s = flash_decode(q, k, v, jnp.asarray(31, jnp.int32),
                             block_kv=16, interpret=True)
        out_v = flash_decode(q, k, v, jnp.full((2,), 31, jnp.int32),
                             block_kv=16, interpret=True)
        np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_v))

    def test_gqa_group_mapping(self, key):
        """Each q head must attend its own kv head through the folded
        group layout (scale kv head 1's values and check heads 2-3 move)."""
        B, H, K, D, T = 1, 4, 2, 64, 64
        q, k, v = _qkv_cache(key, B, H, K, D, T)
        idx = jnp.asarray([T - 1], jnp.int32)
        base = flash_decode(q, k, v, idx, block_kv=16, interpret=True)
        v2 = v.at[:, :, 1].mul(100.0)
        out = flash_decode(q, k, v2, idx, block_kv=16, interpret=True)
        delta = jnp.max(jnp.abs(out - base), axis=(0, 1, 3))  # per q head
        assert float(jnp.max(delta[:2])) < 1e-6  # group 0 untouched
        assert float(jnp.min(delta[2:])) > 1.0   # group 1 scaled


class TestModuleDecode:
    """Attention._decode pallas impl == xla impl over real cache streams."""

    POL = PolicyResolver.default("double")

    def _attn(self, mask, window, softcap=None, H=4, K=2):
        attn = Attention("attn", 64, H, K, 64, mask=mask, window=window,
                         softcap=softcap)
        params = init_params(attn, jax.random.PRNGKey(1), self.POL)
        return attn, params

    def _ctx(self, impl):
        return Ctx(policies=self.POL, impls=[("*", "attention", impl)],
                   extra={"cache_max_len": 64})

    def _decode_seq(self, attn, params, cache, impl, steps, start, B):
        outs = []
        key = jax.random.PRNGKey(3)
        for t in range(steps):
            x = jax.random.normal(jax.random.fold_in(key, t), (B, 1, 64))
            pos = jnp.full((B, 1), start + t, jnp.int32)
            y, cache = attn(params, x, ctx=self._ctx(impl), positions=pos,
                            mode="decode", cache=cache)
            outs.append(np.asarray(y, np.float32))
        return np.stack(outs), cache

    def test_ring_cache_wrap(self, key):
        """Sliding window, decode *past* the wrap point (index > W)."""
        attn, params = self._attn("sliding", 16)
        B = 2
        xpre = jax.random.normal(jax.random.PRNGKey(9), (B, 24, 64))
        _, cache0 = attn(params, xpre, ctx=self._ctx("xla"), mode="prefill")
        assert "pos" in cache0 and cache0["k"].shape[1] == 16  # ring, W slots
        o_x, c_x = self._decode_seq(attn, params, cache0, "xla", 20, 24, B)
        o_p, c_p = self._decode_seq(attn, params, cache0, "pallas", 20, 24, B)
        np.testing.assert_allclose(o_x, o_p, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(c_x["pos"]),
                                      np.asarray(c_p["pos"]))
        assert int(c_p["index"]) == 44  # wrapped nearly 3x

    def test_linear_cache_growth(self, key):
        """Causal decode from an empty cache: index 0 -> 10."""
        attn, params = self._attn("causal", None)
        cache0 = init_cache(2, 32, 2, 64, jnp.float32)
        o_x, _ = self._decode_seq(attn, params, cache0, "xla", 10, 0, 2)
        o_p, c_p = self._decode_seq(attn, params, cache0, "pallas", 10, 0, 2)
        np.testing.assert_allclose(o_x, o_p, rtol=1e-5, atol=1e-5)
        assert int(c_p["index"]) == 10

    def test_linear_cache_sliding_window(self, key):
        """window >= prefill length keeps the cache linear — the kernel must
        then apply the window mask itself."""
        attn, params = self._attn("sliding", 8)
        cache0 = init_cache(2, 40, 2, 64, jnp.float32)
        o_x, _ = self._decode_seq(attn, params, cache0, "xla", 24, 0, 2)
        o_p, _ = self._decode_seq(attn, params, cache0, "pallas", 24, 0, 2)
        np.testing.assert_allclose(o_x, o_p, rtol=1e-5, atol=1e-5)

    def test_per_request_index_linear(self, key):
        """Stacked serving caches: (B,) index, every request at a different
        fill level."""
        attn, params = self._attn("causal", None)
        B = 3
        cache = init_cache(B, 32, 2, 64, jnp.float32)
        cache["index"] = jnp.asarray([0, 7, 31], jnp.int32)
        k = jax.random.PRNGKey(11)
        cache["k"] = jax.random.normal(k, cache["k"].shape, jnp.float32)
        cache["v"] = jax.random.normal(jax.random.fold_in(k, 1),
                                       cache["v"].shape, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(k, 2), (B, 1, 64))
        pos_in = cache["index"][:, None]
        y_x, c_x = attn(params, x, ctx=self._ctx("xla"),
                        positions=pos_in, mode="decode", cache=cache)
        y_p, c_p = attn(params, x, ctx=self._ctx("pallas"),
                        positions=pos_in, mode="decode", cache=cache)
        np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(c_x["index"]),
                                      np.asarray(c_p["index"]))

    def test_per_request_index_ring(self, key):
        """Ring caches at *different wrap levels* per request: build each
        request's cache by actually decoding a B=1 stream, stack them, then
        one batched step must match xla — including requests past the wrap
        point."""
        attn, params = self._attn("sliding", 12)
        per_req_steps = (1, 5, 17)  # unwrapped / near-full / wrapped
        caches = []
        for steps in per_req_steps:
            c = init_ring_cache(1, 12, 2, 64, jnp.float32)
            _, c = self._decode_seq(attn, params, c, "xla", steps, 0, 1)
            caches.append(c)
        cache = {
            "k": jnp.concatenate([c["k"] for c in caches], axis=0),
            "v": jnp.concatenate([c["v"] for c in caches], axis=0),
            "pos": jnp.stack([c["pos"] for c in caches], axis=0),
            "index": jnp.stack([c["index"] for c in caches]),
        }
        B = len(per_req_steps)
        x = jax.random.normal(jax.random.PRNGKey(21), (B, 1, 64))
        pos_in = cache["index"][:, None]
        y_x, c_x = attn(params, x, ctx=self._ctx("xla"),
                        positions=pos_in, mode="decode", cache=cache)
        y_p, c_p = attn(params, x, ctx=self._ctx("pallas"),
                        positions=pos_in, mode="decode", cache=cache)
        np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(c_x["pos"]),
                                      np.asarray(c_p["pos"]))


class TestDecodeSchedule:
    """The numpy oracle: exact live-block streaming, never a dead block."""

    @pytest.mark.parametrize("T,bkv", [(128, 32), (512, 128), (100, 32),
                                       (2048, 512)])
    def test_exact_block_count(self, T, bkv):
        """Acceptance: exactly ceil(min(W, index+1)/block_kv) blocks per
        token for a ring/linear cache of W slots."""
        for index in (0, 1, bkv - 1, bkv, T // 2, T - 1, T, 3 * T):
            sched = decode_schedule(T, index, bkv)
            assert len(sched) == max(1, cdiv(min(T, index + 1), bkv)), \
                (T, index, bkv)
            assert sched == list(range(len(sched)))

    def test_no_dead_block_streamed(self):
        """Every streamed block must contain at least one live slot; every
        live slot must be covered."""
        T, bkv = 256, 32
        for index in (0, 5, 31, 32, 100, 255):
            for window in (None, 40, 200):
                sched = decode_schedule(T, index, bkv, window=window)
                live = min(T, index + 1)
                lo_slot = 0 if window is None else max(0, index + 1 - window)
                for ik in sched:
                    k0, k1 = ik * bkv, min((ik + 1) * bkv, T) - 1
                    assert k0 < live, (index, window, ik)  # causal-live
                    assert k1 >= lo_slot, (index, window, ik)  # window-live
                covered = {s for ik in sched
                           for s in range(ik * bkv, min((ik + 1) * bkv, T))}
                want = set(range(lo_slot, live))
                assert want <= covered, (index, window, want - covered)

    def test_dense_streams_everything(self):
        assert decode_schedule(256, 3, 64, pruned=False) == [0, 1, 2, 3]

    def test_steps_bounds_schedule(self):
        """The pruned kernel's *grid* is decode_steps_for long, so the bound
        must hold for EVERY index — exhaustive over small configs."""
        for T, bkv, w in ((256, 64, None), (256, 64, 100), (100, 32, 24),
                          (256, 64, 64), (256, 64, 65), (96, 32, 33)):
            steps = decode_steps_for(T, bkv, w)
            for index in range(0, 3 * T):
                assert len(decode_schedule(T, index, bkv, window=w)) <= steps, \
                    (T, bkv, w, index)

    def test_o_w_bound(self):
        """Decode traffic is O(W), independent of max_len: a ring cache of W
        slots streams ceil(W/bkv) blocks regardless of how long the stream
        has run, and a full linear sweep to max_len streams ~max_len/bkv
        *total* — the pruned per-token count never exceeds the window's."""
        bkv = 128
        for W in (128, 512, 2048):
            ring_blocks = len(decode_schedule(W, 10 ** 9, bkv))
            assert ring_blocks == cdiv(W, bkv)  # O(W), not O(stream length)
        # linear cache under a window: per-token traffic bounded by the
        # window, not by the 8k cache
        T, W = 8192, 512
        worst = max(
            len(decode_schedule(T, idx, bkv, window=W))
            for idx in range(0, T, 97)
        )
        assert worst <= cdiv(W, bkv) + 1  # +1: window straddles a block edge
        assert worst * bkv < T / 4       # far below the dense O(max_len)

    def test_kernel_streams_only_scheduled_blocks(self, key):
        """Poison the cache outside the scheduled blocks: the kernel output
        must not change — those blocks are never part of the math (their
        DMAs are elided on TPU; interpret mode at least proves masking)."""
        B, H, K, D, T, bkv = 1, 4, 2, 64, 128, 32
        q, k, v = _qkv_cache(key, B, H, K, D, T)
        index = jnp.asarray([40], jnp.int32)
        sched = decode_schedule(T, 40, bkv)
        out = flash_decode(q, k, v, index, block_kv=bkv, interpret=True)
        dead = [ik for ik in range(cdiv(T, bkv)) if ik not in sched]
        assert dead, "test needs at least one dead block"
        for ik in dead:
            sl = slice(ik * bkv, (ik + 1) * bkv)
            k = k.at[:, sl].set(jnp.nan)
            v = v.at[:, sl].set(jnp.nan)
        out2 = flash_decode(q, k, v, index, block_kv=bkv, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def _pool_from_dense(k, v, ps, seed=3):
    """Scatter a dense stacked (B, T, K, D) cache into a page pool with a
    *shuffled* page assignment — identity tables would hide indirection
    bugs.  Returns (pk, pv, tables) with pools (P, ps, K, D)."""
    B, T = k.shape[0], k.shape[1]
    nb = cdiv(T, ps)
    pad = nb * ps - T
    widths = ((0, 0), (0, pad), (0, 0), (0, 0))
    kp = jnp.pad(k, widths).reshape(B * nb, ps, *k.shape[2:])
    vp = jnp.pad(v, widths).reshape(B * nb, ps, *k.shape[2:])
    perm = np.random.default_rng(seed).permutation(B * nb).astype(np.int32)
    tables = perm.reshape(B, nb)
    pk = jnp.zeros_like(kp).at[perm].set(kp)
    pv = jnp.zeros_like(vp).at[perm].set(vp)
    return pk, pv, jnp.asarray(tables)


class TestPagedKernel:
    """Block-table flash_decode == dense flash_decode, bit for bit: the
    indirection lives in the index_map, the math is untouched (the
    tentpole acceptance criterion)."""

    @pytest.mark.parametrize("name,HK,T,idx,window,softcap,ps,bkv", [
        ("linear", (4, 2), 160, [4, 80, 159], None, None, 32, 32),
        ("subblock", (4, 2), 160, [4, 80, 159], None, None, 64, 16),
        ("window", (4, 2), 128, [3, 64, 127], 48, None, 32, 16),
        ("gqa_softcap", (8, 1), 96, [5, 40, 95], None, 30.0, 32, 32),
        ("block_gt_page", (4, 2), 128, [10, 127], None, None, 32, 512),
        ("ragged_kvlen", (4, 2), 100, [0, 37, 99], None, None, 64, 512),
    ])
    def test_paged_matches_dense_bitwise(self, key, name, HK, T, idx, window,
                                         softcap, ps, bkv):
        H, K = HK
        q, k, v = _qkv_cache(key, len(idx), H, K, 64, T)
        idx = jnp.asarray(idx, jnp.int32)
        eff = page_block_kv(bkv, ps)
        dense = flash_decode(q, k, v, idx, window=window, softcap=softcap,
                             block_kv=eff, interpret=True)
        pk, pv, tables = _pool_from_dense(k, v, ps)
        paged = flash_decode(q, pk, pv, idx, window=window, softcap=softcap,
                             block_kv=bkv, tables=tables, kv_len=T,
                             interpret=True)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged))

    def test_paged_ring_wrapped(self, key):
        """Ring-layout pool (logical length W, wrapped stream): same
        clamp-and-elide walk, pages resolved through the table."""
        B, H, K, D, W = 3, 4, 2, 64, 48
        q, k, v = _qkv_cache(key, B, H, K, D, W)
        idx = jnp.asarray([7, 47, 1000], jnp.int32)  # incl. deep wrap
        dense = flash_decode(q, k, v, idx, block_kv=16, interpret=True)
        pk, pv, tables = _pool_from_dense(k, v, 16)
        paged = flash_decode(q, pk, pv, idx, block_kv=16, tables=tables,
                             kv_len=W, interpret=True)
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged))

    def test_paged_schedule_oracle(self):
        """paged_decode_schedule = decode_schedule mapped through the
        table, logical order preserved."""
        table = [9, 4, 7, 2]
        sched = paged_decode_schedule(128, 70, 16, 32, table)
        logical = decode_schedule(128, 70, 16)
        assert sched == [(table[jb // 2], jb % 2) for jb in logical]
        windowed = paged_decode_schedule(128, 70, 16, 32, table, window=32)
        assert len(windowed) < len(sched)
        assert set(windowed) <= set(sched)

    def test_dead_pages_never_stream(self, key):
        """Poison every page the schedule does not reference: the output
        must not change (their DMAs are elided on TPU; interpret mode at
        least proves they never enter the math)."""
        B, H, K, D, T, ps, bkv = 1, 4, 2, 64, 128, 32, 32
        q, k, v = _qkv_cache(key, B, H, K, D, T)
        index = jnp.asarray([40], jnp.int32)
        pk, pv, tables = _pool_from_dense(k, v, ps)
        out = flash_decode(q, pk, pv, index, block_kv=bkv, tables=tables,
                           kv_len=T, interpret=True)
        live = {p for p, _ in paged_decode_schedule(
            T, 40, bkv, ps, np.asarray(tables[0]))}
        dead = [p for p in range(pk.shape[0]) if p not in live]
        assert dead, "test needs at least one dead page"
        for p in dead:
            pk = pk.at[p].set(jnp.nan)
            pv = pv.at[p].set(jnp.nan)
        out2 = flash_decode(q, pk, pv, index, block_kv=bkv, tables=tables,
                            kv_len=T, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))

    def test_page_block_kv(self):
        assert page_block_kv(512, 128) == 128   # clamp to the page
        assert page_block_kv(64, 256) == 64     # divisor passes through
        assert page_block_kv(256, 256) == 256
        assert 256 % page_block_kv(96, 256) == 0  # always a page divisor

    def test_ragged_kvlen_streams_page_sized_blocks(self):
        """The effective block must come from (block_kv, page_size) alone:
        a non-power-of-two kv_len must not collapse the gcd to slivers
        (kv_len=100 with 64-slot pages streams 64-slot blocks, not 4)."""
        table = list(range(2))
        sched = paged_decode_schedule(100, 99, 512, 64, table)
        assert sched == [(0, 0), (1, 0)]  # two page-sized blocks


class TestPagedModule:
    """Attention._decode over a paged cache == the dense stacked cache,
    bit for bit, for both impls (the XLA gather reference and the
    block-table kernel)."""

    POL = PolicyResolver.default("double")

    def _ctx(self, impl, ps):
        # pin the streamed block to the page so the pallas online-softmax
        # partitioning matches the dense run exactly
        return Ctx(policies=self.POL, impls=[("*", "attention", impl)],
                   extra={"flash_block_kv_dec": ps})

    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    def test_linear_paged_matches_stacked(self, key, impl):
        B, T, ps = 3, 32, 8
        attn = Attention("attn", 64, 4, 2, 64, mask="causal")
        params = init_params(attn, jax.random.PRNGKey(1), self.POL)
        cache = init_cache(B, T, 2, 64, jnp.float32)
        cache["index"] = jnp.asarray([0, 7, 31], jnp.int32)
        cache["k"] = jax.random.normal(key, cache["k"].shape, jnp.float32)
        cache["v"] = jax.random.normal(jax.random.fold_in(key, 1),
                                       cache["v"].shape, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, 64))
        pos_in = cache["index"][:, None]
        ar = jnp.arange(T, dtype=jnp.int32)
        kv_pos = jnp.where(ar[None] <= cache["index"][:, None], ar[None], -1)

        y_d, c_d = attn(params, x, ctx=self._ctx(impl, ps), positions=pos_in,
                        mode="decode", cache=dict(cache))
        pk, pv, tables = _pool_from_dense(cache["k"], cache["v"], ps)
        pcache = {"pk": pk, "pv": pv, "index": cache["index"]}
        y_p, c_p = attn(params, x, ctx=self._ctx(impl, ps), positions=pos_in,
                        mode="decode", cache=pcache, block_tables=tables,
                        kv_pos=kv_pos)
        np.testing.assert_array_equal(np.asarray(y_d), np.asarray(y_p))
        np.testing.assert_array_equal(np.asarray(c_d["index"]),
                                      np.asarray(c_p["index"]))
        # the write landed on the right physical slot: gather the logical
        # view back and compare against the dense cache
        nb = tables.shape[1]
        k_log = np.asarray(c_p["pk"][tables].reshape(B, nb * ps, 2, 64))
        np.testing.assert_array_equal(np.asarray(c_d["k"]), k_log[:, :T])

    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    def test_write_past_kv_len_drops_like_dense(self, key, impl):
        """A decode step at index == kv_len (cache full) must vanish in
        both layouts: the dense scatter drops out-of-bounds writes, and
        the paged path must not let the table *gather* clamp redirect the
        write onto a live page."""
        B, T, ps = 2, 8, 4
        attn = Attention("attn", 64, 4, 2, 64, mask="causal")
        params = init_params(attn, jax.random.PRNGKey(1), self.POL)
        cache = init_cache(B, T, 2, 64, jnp.float32)
        cache["index"] = jnp.full((B,), T, jnp.int32)  # past the end
        cache["k"] = jax.random.normal(key, cache["k"].shape, jnp.float32)
        cache["v"] = jax.random.normal(jax.random.fold_in(key, 1),
                                       cache["v"].shape, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, 64))
        pos_in = cache["index"][:, None]
        y_d, c_d = attn(params, x, ctx=self._ctx(impl, ps), positions=pos_in,
                        mode="decode", cache=dict(cache))
        np.testing.assert_array_equal(np.asarray(c_d["k"]),
                                      np.asarray(cache["k"]))  # dropped
        pk, pv, tables = _pool_from_dense(cache["k"], cache["v"], ps)
        pcache = {"pk": pk, "pv": pv, "index": cache["index"]}
        y_p, c_p = attn(params, x, ctx=self._ctx(impl, ps), positions=pos_in,
                        mode="decode", cache=pcache, block_tables=tables)
        np.testing.assert_array_equal(np.asarray(y_d), np.asarray(y_p))
        np.testing.assert_array_equal(np.asarray(c_p["pk"]),
                                      np.asarray(pk))  # no page corrupted

    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    def test_ring_paged_matches_stacked(self, key, impl):
        """Ring family at mixed wrap levels per request."""
        B, W, ps = 3, 12, 4
        attn = Attention("attn", 64, 4, 2, 64, mask="sliding", window=W)
        params = init_params(attn, jax.random.PRNGKey(2), self.POL)
        indices = [5, 12, 42]
        posm = np.full((B, W), -1, np.int32)
        for b, idx in enumerate(indices):  # pos[s] = last p < idx, p%W == s
            for s in range(W):
                p = ((idx - 1 - s) // W) * W + s
                if 0 <= p < idx:
                    posm[b, s] = p
        cache = {
            "k": jax.random.normal(key, (B, W, 2, 64)),
            "v": jax.random.normal(jax.random.fold_in(key, 5), (B, W, 2, 64)),
            "pos": jnp.asarray(posm),
            "index": jnp.asarray(indices, jnp.int32),
        }
        x = jax.random.normal(jax.random.fold_in(key, 7), (B, 1, 64))
        pos_in = cache["index"][:, None]
        y_d, c_d = attn(params, x, ctx=self._ctx(impl, ps), positions=pos_in,
                        mode="decode", cache=dict(cache))
        pk, pv, tables = _pool_from_dense(cache["k"], cache["v"], ps)
        pcache = {"pk": pk, "pv": pv, "pos": cache["pos"],
                  "index": cache["index"]}
        y_p, c_p = attn(params, x, ctx=self._ctx(impl, ps), positions=pos_in,
                        mode="decode", cache=pcache, block_tables=tables)
        np.testing.assert_array_equal(np.asarray(y_d), np.asarray(y_p))
        np.testing.assert_array_equal(np.asarray(c_d["pos"]),
                                      np.asarray(c_p["pos"]))


class TestCrossDecode:
    """Whisper's decoder cross-attention through flash_decode: the encoder
    length is static, so the schedule is the full fixed prefix — parity
    with the XLA reference over the cached encoder K/V."""

    POL = PolicyResolver.default("double")

    def _ctx(self, impl):
        return Ctx(policies=self.POL, impls=[("*", "attention", impl)],
                   extra={"flash_block_kv_dec": 32})

    @pytest.mark.parametrize("softcap", [None, 25.0])
    def test_decode_parity(self, key, softcap):
        B, T_enc = 2, 96
        attn = Attention("cross", 64, 4, 2, 64, use_rope=False, mask="full",
                         cross=True, softcap=softcap)
        params = init_params(attn, jax.random.PRNGKey(4), self.POL)
        kv_src = jax.random.normal(key, (B, T_enc, 64))
        xq = jax.random.normal(jax.random.fold_in(key, 1), (B, 1, 64))
        # prefill-style call computes + caches the encoder K/V
        _, cross_cache = attn(params,
                              jax.random.normal(jax.random.fold_in(key, 2),
                                                (B, 4, 64)),
                              ctx=self._ctx("xla"), kv_src=kv_src)
        assert "ck" in cross_cache
        y_x, _ = attn(params, xq, ctx=self._ctx("xla"), mode="decode",
                      cache=cross_cache)
        y_p, c_p = attn(params, xq, ctx=self._ctx("pallas"), mode="decode",
                        cache=cross_cache)
        np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                                   rtol=1e-5, atol=1e-5)
        assert c_p is cross_cache  # static cache passes through untouched

    def test_prefill_keeps_xla_path(self, key):
        """Multi-token (prefill/dense) cross calls must not hit the
        single-token kernel."""
        B, T_enc = 2, 64
        attn = Attention("cross", 64, 4, 2, 64, use_rope=False, mask="full",
                         cross=True)
        params = init_params(attn, jax.random.PRNGKey(4), self.POL)
        kv_src = jax.random.normal(key, (B, T_enc, 64))
        x = jax.random.normal(jax.random.fold_in(key, 1), (B, 6, 64))
        y_x, _ = attn(params, x, ctx=self._ctx("xla"), kv_src=kv_src)
        y_p, _ = attn(params, x, ctx=self._ctx("pallas"), kv_src=kv_src)
        np.testing.assert_array_equal(np.asarray(y_x), np.asarray(y_p))

    def test_whisper_decoder_parity(self, key):
        """End to end through EncDecLM: a decode step with the pallas impl
        (self-attn kernel + cross-attn kernel) matches the XLA reference."""
        from repro.models.registry import build_model, reduced_config
        from repro.nn.module import init_params as init_model_params

        # head_dim 64: the kernel's supported tile (reduced default is 16)
        cfg = reduced_config("whisper-small").replace(head_dim=64)
        model = build_model(cfg)
        params = init_model_params(model, jax.random.PRNGKey(0), self.POL)
        B, T_enc, S = 2, 16, 5
        frames = jax.random.normal(key, (B, T_enc, cfg.d_model))
        toks = (np.arange(B * S).reshape(B, S) % cfg.vocab).astype(np.int32)

        def run(impl):
            ctx = Ctx(policies=self.POL,
                      impls=[("*", "attention", impl)],
                      extra={"flash_block_kv_dec": 16, "cache_max_len": 8})
            logits, cache = model(params, {"tokens": jnp.asarray(toks),
                                           "frames": frames},
                                  ctx=ctx, mode="prefill")
            pos = jnp.full((B, 1), S, jnp.int32)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            logits2, _ = model(params, {"tokens": tok, "positions": pos},
                               ctx=ctx, mode="decode", cache=cache)
            return np.asarray(logits2, np.float32)

        np.testing.assert_allclose(run("xla"), run("pallas"),
                                   rtol=1e-4, atol=1e-4)


class TestVmemBytesDec:
    def test_monotone_in_block(self):
        assert vmem_bytes_dec(4, 512, 128) > vmem_bytes_dec(4, 128, 128)

    def test_group_floor(self):
        """Sub-8 groups pad to the TPU sublane floor."""
        assert vmem_bytes_dec(1, 256, 128) == vmem_bytes_dec(8, 256, 128)
        assert vmem_bytes_dec(16, 256, 128) > vmem_bytes_dec(8, 256, 128)

    def test_default_fits_vmem(self):
        assert vmem_bytes_dec(8, 512, 256) < 16 * 2 ** 20


class TestBatchedServer:
    """serve_batch == per-request serve (the runtime-layer deliverable)."""

    def _server(self, arch):
        from repro.configs.base import SHAPES
        from repro.core.program import Program
        from repro.launch.weave import default_weave
        from repro.runtime.server import Server, ServerConfig

        program = Program.from_arch(arch, kind="serve", reduced=True)
        woven = default_weave(program, SHAPES["prefill_32k"], {})
        return Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4))

    @pytest.mark.parametrize("arch", ["yi-6b", "mixtral-8x22b", "rwkv6-3b"])
    def test_batched_equals_per_request(self, arch):
        srv = self._server(arch)
        prompts = [np.ones((5,), np.int32),
                   (np.arange(1, 9) % 50).astype(np.int32),
                   np.full((3,), 7, np.int32)]
        batched = srv.serve_batch(prompts)
        assert len(batched) == 3
        for p, got in zip(prompts, batched):
            solo = srv.serve(p[None])[0]
            np.testing.assert_array_equal(got, solo)

    def test_memoized_batch(self):
        srv = self._server("yi-6b")
        from repro.memo.table import MemoTable

        srv.memo = MemoTable(size=8)
        prompts = [np.ones((4,), np.int32), np.zeros((6,), np.int32)]
        a = srv.serve_batch(prompts)
        b = srv.serve_batch(prompts)
        assert srv.memo.hits >= 1
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
