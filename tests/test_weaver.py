"""ANTAREX DSL core: selectors, aspects, weaving metrics (paper Tables 1-2),
variants, knobs."""

import jax.numpy as jnp
import pytest

from repro.core.program import Program
from repro.core.strategies.kernels import BlockSizeAspect, KernelAspect
from repro.core.strategies.memoization import find_memoizable
from repro.core.strategies.parallelization import (
    AccumAspect, AutoShard, RematAspect, ShardingAspect, validate_rules,
)
from repro.core.strategies.precision import (
    ChangePrecision, CreateLowPrecVersion, MixedPrecisionVersions,
)
from repro.core.strategies.versioning import Multiversion, SpecializeCall
from repro.core.weaver import Weaver, weave
from repro.core.knob import Knob, KnobSpace


@pytest.fixture
def program():
    return Program.from_arch("yi-6b", reduced=True)


class TestSelectors:
    def test_select_by_kind(self, program):
        w = Weaver(program)
        attn = w.select(kind="attention").all()
        assert len(attn) == 1  # scanned template stands for all layers
        assert attn[0].attr("n_heads") == 4

    def test_select_by_path_and_predicate(self, program):
        w = Weaver(program)
        sel = w.select("*norm*").where(lambda jp: jp.kind == "norm")
        assert len(sel.all()) >= 2

    def test_step_joinpoints(self, program):
        w = Weaver(program)
        steps = w.select(kind="step").all()
        assert {jp.attr("step") for jp in steps} == {"train_step", "serve_step"}


class TestPrecisionAspects:
    def test_change_precision_skips_norms(self, program):
        woven = weave(program, [ChangePrecision("*", "half")])
        # norms pin fp32 via ParamSpec dtype regardless of policy
        policy = woven.state.policies.resolve("yi_6b/blocks0/block/attn/wq")
        assert policy.param_dtype == jnp.bfloat16

    def test_versions_and_filter(self, program):
        aspect = MixedPrecisionVersions(
            ["*attn*", "*ffn*"], ["float", "half"],
            combination_filter=lambda combo: combo[0] == "half",
            max_versions=3,
        )
        woven = weave(program, [aspect])
        assert 0 < len(woven.variants) <= 3
        assert "precision_mix" in woven.knobs

    def test_create_float_version(self, program):
        woven = weave(program, [CreateLowPrecVersion("*", "half", "_f")])
        assert "f" in woven.variants


class TestVersioning:
    def test_multiversion_knob(self, program):
        woven = weave(program, [
            CreateLowPrecVersion("*", "half", "_f"),
            Multiversion("version", time_versions=True),
        ])
        assert "version" in woven.knobs
        assert "__default__" in woven.knobs["version"].values
        assert len(woven.state.step_wrappers) == 1

    def test_specialize_constants(self, program):
        woven = weave(program, [SpecializeCall("fast", {"accum_steps": 4})])
        assert woven.variants["fast"].extra["accum_steps"] == 4
        assert "accum_steps" not in woven.state.extra  # default untouched


class TestWeaveMetrics:
    def test_tables_1_2_counters(self, program):
        aspects = [
            ChangePrecision("*", "half"),
            RematAspect("full"),
            AccumAspect(4),
            KernelAspect("*attn*", "attention", "pallas"),
        ]
        woven = weave(program, aspects)
        totals = woven.report.totals()
        assert totals.selects > 0
        assert totals.attributes > 0
        assert totals.actions >= totals.inserts
        assert totals.actions > len(aspects)
        table = woven.report.table()
        assert "ChangePrecision" in table and "TOTAL" in table

    def test_analysis_exceeds_transformation(self, program):
        """Paper §3: analysis work >> transformation work."""
        woven = weave(program, [ChangePrecision("*", "half")])
        t = woven.report.totals()
        assert t.attributes >= t.inserts


class TestParallelization:
    def test_autoshard_megatron(self):
        program = Program.from_arch("yi-6b")  # full config: 32 heads % 16 == 0
        woven = weave(program, [AutoShard({"data": 16, "model": 16})])
        assert woven.state.extra["layout"] == "megatron_tp"
        assert woven.state.rules["heads"] == "model"
        assert woven.state.extra["expand_kv"]  # kv=4 does not divide tp=16

    def test_autoshard_fsdp_sp_for_mqa(self):
        program = Program.from_arch("gemma-2b")  # 8 heads < 16
        woven = weave(program, [AutoShard({"data": 16, "model": 16})])
        assert woven.state.extra["layout"] == "fsdp_sp"
        assert woven.state.rules["seq_act"] == "model"

    def test_autoshard_dp_for_ssm(self):
        program = Program.from_arch("rwkv6-3b")
        woven = weave(program, [AutoShard({"data": 16, "model": 16})])
        assert woven.state.extra["layout"] == "dp_fsdp"
        assert "model" in woven.state.rules["batch"]

    def test_nested_pragma_detection(self):
        with pytest.raises(ValueError, match="nested parallelism"):
            validate_rules({"batch": ("data",), "mlp": "data"})


class TestKnobs:
    def test_space_grid_and_neighbors(self):
        space = KnobSpace([Knob("a", (1, 2)), Knob("b", ("x", "y", "z"), "y")])
        assert len(space.grid()) == 6
        point = space.defaults()
        assert len(space.neighbors(point)) == 3
        with pytest.raises(ValueError):
            space.validate({"a": 99})


def test_find_memoizable(program):
    w = Weaver(program)
    paths = find_memoizable(w)
    assert any("embed" in p for p in paths)
