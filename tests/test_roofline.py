"""Roofline machinery: collective parsing (explicit + iota replica groups),
wire accounting, term math, and the loop-body-once guard that motivates the
compositional method (EXPERIMENTS.md §Roofline)."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis
from repro.roofline.hw import ICI_LINK_BW, PEAK_FLOPS_BF16


HLO_SNIPPET = """
  %ar = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[2048,256]{1,0} all-gather(bf16[1024,256]{1,0} %y), replica_groups=[2,2]<=[4], dimensions={0}
  %rs = f32[256,128]{1,0} reduce-scatter(f32[1024,128]{1,0} %z), replica_groups={{0,1,2,3}}, to_apply=%add
  %done = f32[8]{0} all-reduce-done(f32[8]{0} %start)
  %cp = bf16[64,64]{1,0} collective-permute(bf16[64,64]{1,0} %w), source_target_pairs={{0,1}}
"""


class TestCollectiveParsing:
    def test_ops_and_wire_accounting(self):
        stats = analysis.parse_collectives(HLO_SNIPPET)
        assert stats.counts == {"all-reduce": 1, "all-gather": 1,
                                "reduce-scatter": 1, "collective-permute": 1}
        ar = 2 * (1024 * 512 * 4) * 3 / 4
        ag = (2048 * 256 * 2) * 1 / 2  # iota group size 2
        rs = (256 * 128 * 4) * 3
        cp = 64 * 64 * 2
        assert stats.by_op["all-reduce"] == pytest.approx(ar)
        assert stats.by_op["all-gather"] == pytest.approx(ag)
        assert stats.by_op["reduce-scatter"] == pytest.approx(rs)
        assert stats.by_op["collective-permute"] == pytest.approx(cp)
        assert stats.wire_bytes == pytest.approx(ar + ag + rs + cp)

    def test_async_done_not_double_counted(self):
        stats = analysis.parse_collectives(HLO_SNIPPET)
        assert stats.counts.get("all-reduce", 0) == 1  # -done skipped

    def test_tuple_results(self):
        txt = ("%t = (f32[128,128]{1,0}, f32[64]{0}) all-reduce(...), "
               "replica_groups={{0,1}}, to_apply=%add")
        stats = analysis.parse_collectives(txt)
        size = 128 * 128 * 4 + 64 * 4
        assert stats.wire_bytes == pytest.approx(2 * size * 0.5)


class TestRooflineMath:
    def _roof(self, **kw):
        base = dict(arch="a", shape="s", mesh="m", chips=256,
                    flops_per_device=197e12, bytes_per_device=819e9,
                    collective_bytes_per_device=50e9, collective_counts={},
                    collective_by_op={}, model_flops=197e12 * 256 * 0.5,
                    memory_per_device={"argument": 0, "output": 0, "temp": 0,
                                       "alias": 0, "code": 0})
        base.update(kw)
        return analysis.Roofline(**base)

    def test_terms_are_one_second_each(self):
        r = self._roof()
        assert r.compute_s == pytest.approx(1.0)
        assert r.memory_s == pytest.approx(1.0)
        assert r.collective_s == pytest.approx(1.0)
        assert r.step_s == pytest.approx(1.0)
        assert r.roofline_fraction == pytest.approx(0.5)

    def test_bottleneck_selection(self):
        r = self._roof(collective_bytes_per_device=500e9)
        assert r.bottleneck == "collective"
        r2 = self._roof(flops_per_device=197e13)
        assert r2.bottleneck == "compute"

    def test_useful_ratio(self):
        r = self._roof(model_flops=197e12 * 256)
        assert r.useful_ratio == pytest.approx(1.0)


def test_xla_counts_loop_bodies_once():
    """The empirical fact the compositional §Roofline method rests on."""
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=10)[0]

    sds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(sds, sds).compile()
    flops = analysis.cost_properties(c)["flops"]
    one = 2 * 64 * 64 * 64
    assert flops < 2 * one  # 10 iterations, counted once
