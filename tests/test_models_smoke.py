"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward + prefill/decode consistency + one train step on CPU,
asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.launch.weave import default_weave
from repro.models.registry import ARCHS, get_config, reduced_config, build_model, input_specs
from repro.nn.module import Ctx, init_params
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.runtime.steps import build_train_step

ALL_ARCHS = sorted(ARCHS)


def _inputs(cfg, B, S, key, with_labels=False):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    inp = {"tokens": toks}
    if cfg.family == "vlm":
        P_img = cfg.num_image_tokens
        inp["embeds"] = jax.random.normal(jax.random.fold_in(key, 1),
                                          (B, P_img, cfg.d_model), jnp.bfloat16)
    elif cfg.family == "encdec":
        inp["frames"] = jax.random.normal(jax.random.fold_in(key, 1),
                                          (B, S, cfg.d_model), jnp.bfloat16)
    if with_labels:
        inp["labels"] = jax.random.randint(jax.random.fold_in(key, 2),
                                           (B, S), 0, cfg.vocab)
    return inp


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_finite(arch, key):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = init_params(model, key)
    B, S = 2, 24
    inp = _inputs(cfg, B, S, key)
    fwd = jax.jit(lambda p, i: model(p, i, ctx=Ctx(), mode="dense")[0])
    logits = fwd(params, inp)
    extra = cfg.num_image_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (B, S + extra, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_dense(arch, key):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    params = init_params(model, key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    base = _inputs(cfg, B, S, key)
    base["tokens"] = toks[:, :S]
    ext = dict(base, tokens=toks)
    P_img = cfg.num_image_tokens if cfg.family == "vlm" else 0
    extra = {"cache_max_len": S + P_img + 4, "moe_capacity_factor": 16.0}
    fwd = jax.jit(lambda p, i: model(p, i, ctx=Ctx(extra=extra), mode="dense")[0])
    pre = jax.jit(lambda p, i: model(p, i, ctx=Ctx(extra=extra), mode="prefill"))
    dec = jax.jit(lambda p, i, c: model(p, i, ctx=Ctx(extra=extra), mode="decode",
                                        cache=c))
    lp, cache = pre(params, base)
    npos = S + P_img
    ld, cache2 = dec(params, {"tokens": toks[:, S:], "positions":
                              jnp.full((B, 1), npos, jnp.int32)}, cache)
    l_ext = fwd(params, ext)
    np.testing.assert_allclose(
        np.asarray(l_ext[:, -1:], np.float32), np.asarray(ld, np.float32),
        atol=0.08, rtol=0.05,
    )


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_no_nans(arch, key):
    cfg = reduced_config(arch)
    program = Program.from_arch(arch, reduced=True)
    woven = default_weave(program, SHAPES["train_4k"], {},
                          overrides={"accum_steps": 2})
    B, S = 4, 16
    batch = _inputs(cfg, B, S, key, with_labels=True)
    params = init_params(program.model, key, woven.state.policies)
    opt_cfg = AdamWConfig()
    opt = adamw.init_state(params, opt_cfg)
    step = jax.jit(build_train_step(woven, opt_cfg=opt_cfg))
    params2, opt2, metrics = step(params, opt, batch, jnp.ones((), jnp.int32))  # step 1: warmup lr > 0
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2))
    assert max(delta) > 0


def test_exact_configs_match_assignment():
    """The full configs carry the exact published dimensions."""
    expect = {
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    }
    for arch, (L, d, H, K, ff, V) in expect.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.n_heads, cfg.kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, K, ff, V), arch


def test_input_specs_cover_cells():
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape in cfg.supported_shapes():
            specs = input_specs(cfg, shape)
            assert "inputs" in specs
            if SHAPES[shape].kind == "decode":
                assert specs["cache"] is not None


def test_long_500k_only_subquadratic():
    runs = {a for a in ALL_ARCHS if "long_500k" in get_config(a).supported_shapes()}
    assert runs == {"mixtral-8x22b", "recurrentgemma-2b", "rwkv6-3b"}
