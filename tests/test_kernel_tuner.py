"""Kernel-tuner subsystem: Lat DSE over block knobs, VMEM constraint,
mARGOt KnowledgeBase export, persistent cache (round-trip + second-lookup
hit), and the weave/ops wiring that consumes it."""

import json
import os

import pytest

from repro.autotune.kernel_tuner import (
    DEFAULT_VMEM_BUDGET,
    KernelSignature,
    KernelTuner,
    TunerCache,
    config_vmem_bytes,
    design_space,
    flash_decode_signature,
    flash_signature,
    paged_decode_signature,
    refine_from_runtime,
    rmsnorm_signature,
    tuned_decode_blocks,
    tuned_flash_blocks,
    tuned_paged_blocks,
)


def _sig(S=256, B=1, H=4, K=2, D=64, dtype="float32", causal=True,
         window=None):
    return flash_signature((B, S, H, D), K, dtype, causal=causal,
                           window=window)


def _measure_pref(best_bq, best_bkv, best_bqb=None, best_bkvb=None):
    """Deterministic fake latency minimized at the given blocks (backward
    knobs default to preferring the forward values)."""
    best_bqb = best_bq if best_bqb is None else best_bqb
    best_bkvb = best_bkv if best_bkvb is None else best_bkvb

    def measure(**kn):
        return (1.0 + abs(kn["block_q"] - best_bq)
                + abs(kn["block_kv"] - best_bkv)
                + abs(kn.get("block_q_bwd", best_bqb) - best_bqb)
                + abs(kn.get("block_kv_bwd", best_bkvb) - best_bkvb))
    return measure


def _best(bq, bkv, bqb=None, bkvb=None):
    return {"block_q": bq, "block_kv": bkv,
            "block_q_bwd": bq if bqb is None else bqb,
            "block_kv_bwd": bkv if bkvb is None else bkvb}


class TestSignature:
    def test_key_distinguishes_masks_and_shapes(self):
        keys = {
            _sig().key(),
            _sig(causal=False).key(),
            _sig(window=128).key(),
            _sig(S=512).key(),
            _sig(dtype="bfloat16").key(),
            _sig(K=4).key(),
        }
        assert len(keys) == 6

    def test_gqa_recorded(self):
        assert _sig(H=8, K=2).gqa == 4


class TestDesignSpace:
    def test_blocks_capped_by_seq(self):
        space = design_space(_sig(S=256))
        for name in ("block_q", "block_kv", "block_q_bwd", "block_kv_bwd"):
            assert max(space[name]) <= 256

    def test_vmem_budget_prunes_values(self):
        sig = _sig(S=1024)
        # vmem_of probes fwd blocks only -> bwd defaults to the same blocks
        # and dominates, so this budget pins the bwd knobs at 128 while
        # larger fwd-only tiles may still fit under it.
        budget = vmem_of(sig, 128, 128)
        tight = design_space(sig, vmem_budget=budget)
        assert tight["block_q_bwd"] == [128]
        assert tight["block_kv_bwd"] == [128]
        for name, vals in tight.items():
            for v in vals:  # every surviving value is feasible on its own
                probe = {n: min(vv) for n, vv in tight.items()}
                probe[name] = v
                assert config_vmem_bytes(sig, probe) <= budget, (name, v)

    def test_other_kernels_have_spaces(self):
        for kernel, shape in (("rwkv6", (2, 512, 4, 64)),
                              ("rglru", (2, 512, 256)),
                              ("rmsnorm", (1024, 512)),
                              ("flash_decode", (4, 2048, 8, 2, 128))):
            sig = KernelSignature(kernel=kernel, shape=shape)
            space = design_space(sig)
            assert space and all(vals for vals in space.values())
            knobs = {k: v[0] for k, v in space.items()}
            assert 0 < config_vmem_bytes(sig, knobs) <= DEFAULT_VMEM_BUDGET

    def test_decode_space_capped_by_cache_len(self):
        sig = flash_decode_signature(1, 256, 4, 2, 64)
        space = design_space(sig)
        assert max(space["block_kv_dec"]) <= 256

    def test_decode_signature_distinct_from_flash(self):
        dec = flash_decode_signature(1, 512, 4, 2, 64, window=128)
        fwd = flash_signature((1, 512, 4, 64), 2, "bfloat16", causal=True,
                              window=128)
        assert dec.key() != fwd.key()
        assert dec.gqa == 2


def vmem_of(sig, bq, bkv):
    return config_vmem_bytes(sig, {"block_q": bq, "block_kv": bkv})


class TestBwdVmemModel:
    def test_bwd_dominates_fwd_at_same_blocks(self):
        """The fused backward holds more live state than the forward, so the
        flash constraint (max of both) is the bwd working set."""
        from repro.kernels.flash_attention.kernel import (vmem_bytes,
                                                          vmem_bytes_bwd)

        assert vmem_bytes_bwd(256, 256, 64) > vmem_bytes(256, 256, 64)
        sig = _sig(S=1024)
        assert vmem_of(sig, 256, 256) == config_vmem_bytes(
            sig, _best(256, 256))

    def test_bwd_blocks_tighten_the_constraint(self):
        """Growing only the backward blocks must grow the config's VMEM."""
        sig = _sig(S=1024)
        small = config_vmem_bytes(sig, _best(128, 128, 128, 128))
        big = config_vmem_bytes(sig, _best(128, 128, 512, 512))
        assert big > small

    def test_monotone_in_blocks(self):
        from repro.kernels.flash_attention.kernel import vmem_bytes_bwd

        assert vmem_bytes_bwd(256, 256, 64) > vmem_bytes_bwd(128, 128, 64)


class TestTunerCache:
    def test_roundtrip_and_second_lookup_hit(self, tmp_path):
        path = str(tmp_path / "tuner.json")
        sig = _sig()
        tuner = KernelTuner(path)
        assert tuner.lookup(sig) is None  # cold

        best = tuner.tune(sig, _measure_pref(256, 256, 128, 256))
        assert best == _best(256, 256, 128, 256)
        assert os.path.exists(path)
        # on-disk payload is plain JSON keyed by the signature
        data = json.load(open(path))
        assert sig.key() in data
        assert data[sig.key()]["knobs"] == best

        # fresh tuner over the same file: hit, no measurement
        fresh = KernelTuner(path)
        calls = []

        def exploding_measure(**kn):
            calls.append(kn)
            return 0.0

        got = fresh.get(sig, exploding_measure)
        assert got == best
        assert calls == []
        assert fresh.cache.hits == 1
        assert fresh.tuned == 0

    def test_distinct_signatures_coexist(self, tmp_path):
        path = str(tmp_path / "tuner.json")
        tuner = KernelTuner(path)
        tuner.tune(_sig(), _measure_pref(128, 128))
        tuner.tune(_sig(window=64), _measure_pref(256, 128))
        assert tuner.lookup(_sig()) == _best(128, 128)
        assert tuner.lookup(_sig(window=64)) == _best(256, 128)
        assert len(tuner.cache) == 2

    def test_corrupt_cache_file_is_ignored(self, tmp_path):
        path = tmp_path / "tuner.json"
        path.write_text("{not json")
        tuner = KernelTuner(str(path))
        assert tuner.lookup(_sig()) is None
        tuner.tune(_sig(), _measure_pref(128, 128))
        assert KernelTuner(str(path)).lookup(_sig()) is not None

    def test_vmem_constraint_excludes_infeasible_points(self, tmp_path):
        sig = _sig(S=1024)
        budget = vmem_of(sig, 256, 256)
        tuner = KernelTuner(str(tmp_path / "t.json"), vmem_budget=budget)

        def measure(**kn):  # bigger blocks "faster": tempts the tuner
            return 1.0 / (kn["block_q"] * kn["block_kv"]
                          * kn["block_q_bwd"] * kn["block_kv_bwd"])

        best = tuner.tune(sig, measure)
        assert config_vmem_bytes(sig, best) <= budget


class TestKnowledgeBase:
    def test_dse_rows_become_operating_points(self, tmp_path):
        sig = _sig()
        tuner = KernelTuner(str(tmp_path / "t.json"))
        best = tuner.tune(sig, _measure_pref(256, 256))
        kb = tuner.knowledge_base(sig)
        assert len(kb) == 16  # 2^4 space (fwd + bwd block knobs) at S=256
        by_key = {op.key(): op for op in kb.ops}
        best_op = by_key[tuple(sorted(best.items()))]
        assert best_op.mean("latency_s") == min(
            op.mean("latency_s") for op in kb.ops
        )
        assert all("vmem_bytes" in op.metrics for op in kb.ops)

    def test_missing_signature_returns_none(self, tmp_path):
        tuner = KernelTuner(str(tmp_path / "t.json"))
        assert tuner.knowledge_base(_sig()) is None


class TestWiring:
    def test_ops_lookup_uses_env_cache(self, tmp_path, monkeypatch):
        path = str(tmp_path / "env.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        sig = _sig()
        KernelTuner(path).tune(sig, _measure_pref(128, 256, 256, 128))
        got = tuned_flash_blocks((1, 256, 4, 64), 2, "float32", causal=True)
        assert got == _best(128, 256, 256, 128)

    def test_ops_lookup_empty_when_untuned(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path / "none.json"))
        assert tuned_flash_blocks((1, 256, 4, 64), 2, "float32",
                                  causal=True) == {}

    def test_tuned_aspect_weaves_extras_and_knobs(self, tmp_path, monkeypatch):
        from repro.core.program import Program
        from repro.core.strategies.kernels import TunedKernelAspect
        from repro.core.weaver import Weaver

        path = str(tmp_path / "weave.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        program = Program.from_arch("gemma-2b", reduced=True)
        aspect = TunedKernelAspect(2, 256, dtype="bfloat16")
        sig = aspect.signature(program.cfg)
        KernelTuner(path).tune(sig, _measure_pref(128, 128))

        woven = Weaver(program).weave([aspect])
        assert woven.state.extra["flash_block_q"] == 128
        assert woven.state.extra["flash_block_kv"] == 128
        assert woven.state.extra["flash_block_q_bwd"] == 128
        assert woven.state.extra["flash_block_kv_bwd"] == 128
        assert "flash_block_q" in woven.knobs
        assert woven.knobs["flash_block_q"].default == 128
        assert "flash_block_q_bwd" in woven.knobs

    def test_tuned_aspect_noop_on_cache_miss(self, tmp_path, monkeypatch):
        from repro.core.program import Program
        from repro.core.strategies.kernels import TunedKernelAspect
        from repro.core.weaver import Weaver

        monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path / "miss.json"))
        program = Program.from_arch("gemma-2b", reduced=True)
        woven = Weaver(program).weave([TunedKernelAspect(2, 256)])
        assert "flash_block_q" not in woven.state.extra

    def test_pre_bwd_cache_entry_still_weaves_fwd_blocks(self, tmp_path,
                                                         monkeypatch):
        """Entries written before the bwd knobs existed (fwd-only) must keep
        working: fwd extras woven, bwd extras absent (ops falls back)."""
        import json

        from repro.core.program import Program
        from repro.core.strategies.kernels import TunedKernelAspect
        from repro.core.weaver import Weaver

        path = str(tmp_path / "old.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        program = Program.from_arch("gemma-2b", reduced=True)
        aspect = TunedKernelAspect(2, 256, dtype="bfloat16")
        sig = aspect.signature(program.cfg)
        with open(path, "w") as f:
            json.dump({sig.key(): {"knobs": {"block_q": 256, "block_kv": 128},
                                   "metrics": {}, "ops": []}}, f)

        woven = Weaver(program).weave([aspect])
        assert woven.state.extra["flash_block_q"] == 256
        assert "flash_block_q_bwd" not in woven.state.extra

    def test_wkv_chunk_threaded_to_woven_program(self, tmp_path, monkeypatch):
        """The rwkv6 tuner space must be consumed by woven programs: tuned
        chunk lands in the `wkv_chunk` extra TimeMix reads."""
        from repro.core.program import Program
        from repro.core.strategies.kernels import TunedKernelAspect
        from repro.core.weaver import Weaver

        path = str(tmp_path / "wkv.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        program = Program.from_arch("rwkv6-3b", reduced=True)
        aspect = TunedKernelAspect(2, 128, dtype="float32")
        sig = aspect.rwkv_signature(program.cfg)

        def measure(**kn):  # prefer chunk=64
            return 1.0 + abs(kn["chunk"] - 64)

        KernelTuner(path).tune(sig, measure)
        woven = Weaver(program).weave([aspect])
        assert woven.state.extra["wkv_chunk"] == 64
        assert "wkv_chunk" in woven.knobs
        assert woven.knobs["wkv_chunk"].default == 64
        # rwkv programs have no attention joinpoints: no flash extras
        assert "flash_block_q" not in woven.state.extra

    def test_decode_block_threaded_to_woven_program(self, tmp_path,
                                                    monkeypatch):
        """The flash_decode tuner space must land in the `flash_block_kv_dec`
        extra Attention._decode reads, with its own knob."""
        from repro.core.program import Program
        from repro.core.strategies.kernels import TunedKernelAspect
        from repro.core.weaver import Weaver

        path = str(tmp_path / "dec.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        program = Program.from_arch("gemma-2b", reduced=True)
        aspect = TunedKernelAspect(2, 256, dtype="bfloat16", cache_len=512)
        sig = aspect.decode_signature(program.cfg)
        assert sig.kernel == "flash_decode"

        def measure(**kn):  # prefer block_kv_dec=256
            return 1.0 + abs(kn["block_kv_dec"] - 256)

        KernelTuner(path).tune(sig, measure)
        woven = Weaver(program).weave([aspect])
        assert woven.state.extra["flash_block_kv_dec"] == 256
        assert "flash_block_kv_dec" in woven.knobs
        assert woven.knobs["flash_block_kv_dec"].default == 256

    def test_decode_signature_ring_clamps_to_window(self):
        """Windowed archs serve from a ring cache of W slots: the decode
        signature's cache length is the window and the window field clears
        (the ring layout *is* the window)."""
        from repro.core.program import Program
        from repro.core.strategies.kernels import TunedKernelAspect

        program = Program.from_arch("mixtral-8x22b", reduced=True)
        aspect = TunedKernelAspect(2, 256, cache_len=4096)
        sig = aspect.decode_signature(program.cfg)
        assert sig.shape[1] == program.cfg.attn_window
        assert sig.window is None

    def test_ops_decode_lookup_uses_env_cache(self, tmp_path, monkeypatch):
        path = str(tmp_path / "dec_env.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        sig = flash_decode_signature(2, 512, 4, 2, 64, "float32")

        def measure(**kn):
            return 1.0 + abs(kn["block_kv_dec"] - 128)

        KernelTuner(path).tune(sig, measure)
        got = tuned_decode_blocks((2, 1, 4, 64), 512, 2, "float32")
        assert got == {"block_kv_dec": 128}
        assert tuned_decode_blocks((2, 1, 4, 64), 1024, 2, "float32") == {}

    def test_rmsnorm_block_rows_threaded_to_woven_program(self, tmp_path,
                                                          monkeypatch):
        """The rmsnorm tuner space must land in the `rms_block_rows` extra
        the RMSNorm pallas weave path reads (ROADMAP tuner-coverage item)."""
        from repro.core.program import Program
        from repro.core.strategies.kernels import TunedKernelAspect
        from repro.core.weaver import Weaver

        path = str(tmp_path / "rms.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        program = Program.from_arch("gemma-2b", reduced=True)
        aspect = TunedKernelAspect(2, 256, dtype="bfloat16")
        sig = aspect.rmsnorm_signature(program.cfg)
        assert sig.shape == (2 * 256, program.cfg.d_model)

        def measure(**kn):  # prefer block_rows=128
            return 1.0 + abs(kn["block_rows"] - 128)

        KernelTuner(path).tune(sig, measure)
        woven = Weaver(program).weave([aspect])
        assert woven.state.extra["rms_block_rows"] == 128
        assert "rms_block_rows" in woven.knobs

    def test_rmsnorm_weave_path_matches_xla(self, tmp_path, monkeypatch):
        """A woven pallas norm impl + tuned block_rows must reproduce the
        XLA RMSNorm bit-for-bit at fp32."""
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.nn.blocks import RMSNorm
        from repro.nn.dtypes import PolicyResolver
        from repro.nn.module import Ctx, init_params

        pol = PolicyResolver.default("double")
        norm = RMSNorm("norm", 128)
        params = init_params(norm, jax.random.PRNGKey(0), pol)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 128))
        y_x = norm(params, x, ctx=Ctx(policies=pol))
        y_p = norm(params, x, ctx=Ctx(
            policies=pol, impls=[("*", "norm", "pallas")],
            extra={"rms_block_rows": 16}))
        np.testing.assert_allclose(np.asarray(y_x), np.asarray(y_p),
                                   rtol=1e-6, atol=1e-6)

    def test_paged_knobs_threaded_to_woven_program(self, tmp_path,
                                                   monkeypatch):
        """A tuned paged_decode entry must land both the pool geometry
        (`flash_page_size`) and the jointly-tuned streamed block
        (`flash_block_kv_dec`, overriding the plain decode entry) in the
        woven extras the serving runtime reads."""
        from repro.core.program import Program
        from repro.core.strategies.kernels import TunedKernelAspect
        from repro.core.weaver import Weaver

        path = str(tmp_path / "paged.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        program = Program.from_arch("gemma-2b", reduced=True)
        aspect = TunedKernelAspect(2, 256, dtype="bfloat16", cache_len=512)
        sig = aspect.paged_signature(program.cfg)
        assert sig.kernel == "paged_decode"

        def measure(**kn):  # prefer page_size=256, block_kv_dec=128
            return (1.0 + abs(kn["page_size"] - 256)
                    + abs(kn["block_kv_dec"] - 128))

        KernelTuner(path).tune(sig, measure)
        woven = Weaver(program).weave([aspect])
        assert woven.state.extra["flash_page_size"] == 256
        assert woven.state.extra["flash_block_kv_dec"] == 128
        assert "flash_page_size" in woven.knobs
        assert woven.knobs["flash_page_size"].default == 256

    def test_rglru_blocks_threaded_to_woven_program(self, tmp_path,
                                                    monkeypatch):
        from repro.core.program import Program
        from repro.core.strategies.kernels import TunedKernelAspect
        from repro.core.weaver import Weaver

        path = str(tmp_path / "rglru.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        program = Program.from_arch("recurrentgemma-2b", reduced=True)
        aspect = TunedKernelAspect(2, 128, dtype="float32")
        sig = aspect.rglru_signature(program.cfg)

        def measure(**kn):  # prefer block_d=128, chunk=128
            return 1.0 + abs(kn["block_d"] - 128) + abs(kn["chunk"] - 128)

        KernelTuner(path).tune(sig, measure)
        woven = Weaver(program).weave([aspect])
        assert woven.state.extra["rglru_block_d"] == 128
        assert woven.state.extra["rglru_chunk"] == 128
        assert "rglru_block_d" in woven.knobs


class TestPagedDecodeSpace:
    """The paged_decode kernel space: pool geometry (page_size) jointly
    tuned with the streamed block, VMEM-constrained via the effective
    (page-divisor-clamped) block."""

    def test_signature_distinct_from_decode(self):
        dec = flash_decode_signature(2, 1024, 8, 2, 64)
        paged = paged_decode_signature(2, 1024, 8, 2, 64)
        assert dec.key() != paged.key()
        assert paged.kernel == "paged_decode"

    def test_space_has_both_knobs_capped_by_cache(self):
        space = design_space(paged_decode_signature(1, 256, 4, 2, 64))
        assert max(space["page_size"]) <= 256
        assert max(space["block_kv_dec"]) <= 256
        knobs = {k: v[0] for k, v in space.items()}
        sig = paged_decode_signature(1, 256, 4, 2, 64)
        assert 0 < config_vmem_bytes(sig, knobs) <= DEFAULT_VMEM_BUDGET

    def test_block_clamped_to_page_divisor_in_vmem_model(self):
        """block_kv_dec > page_size streams page-sized blocks, so the VMEM
        working set must stop growing past the page (the knob interaction
        the DSE explores)."""
        sig = paged_decode_signature(2, 2048, 8, 2, 64)
        at_page = config_vmem_bytes(
            sig, {"page_size": 128, "block_kv_dec": 128})
        past_page = config_vmem_bytes(
            sig, {"page_size": 128, "block_kv_dec": 1024})
        assert at_page == past_page
        bigger_page = config_vmem_bytes(
            sig, {"page_size": 512, "block_kv_dec": 1024})
        assert bigger_page > at_page

    def test_tuned_paged_lookup(self, tmp_path, monkeypatch):
        path = str(tmp_path / "paged_env.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        sig = paged_decode_signature(2, 512, 4, 2, 64, "float32")

        def measure(**kn):
            return (1.0 + abs(kn["page_size"] - 128)
                    + abs(kn["block_kv_dec"] - 256))

        KernelTuner(path).tune(sig, measure)
        got = tuned_paged_blocks((2, 1, 4, 64), 512, 2, "float32")
        assert got == {"page_size": 128, "block_kv_dec": 256}

    def test_untuned_paged_falls_back_to_decode_entry(self, tmp_path,
                                                      monkeypatch):
        """A pool built before paged tuning ran still streams the plain
        flash_decode entry's tuned block."""
        path = str(tmp_path / "fb.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        dec = flash_decode_signature(2, 512, 4, 2, 64, "float32")

        def measure(**kn):
            return 1.0 + abs(kn["block_kv_dec"] - 128)

        KernelTuner(path).tune(dec, measure)
        got = tuned_paged_blocks((2, 1, 4, 64), 512, 2, "float32")
        assert got == {"block_kv_dec": 128}
        monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path / "none.json"))
        assert tuned_paged_blocks((2, 1, 4, 64), 512, 2, "float32") == {}

    def test_shared_prefix_hbm_model(self):
        """prefix_shared_pool_bytes: full prefix pages are stored once,
        suffixes per request — and sharing rounds the prefix *down* to a
        page boundary, so smaller pages share more of it (monotone
        page-size penalty at fixed prefix)."""
        from repro.autotune.kernel_tuner import prefix_shared_pool_bytes

        sig = paged_decode_signature(4, 1024, 8, 2, 64, "float32")
        kv_bytes = 2 * 64 * 2 * 4  # K+V per slot: K heads x D x fp32
        # page-aligned prefix, page-aligned cache: geometry cancels out
        assert prefix_shared_pool_bytes(sig, {"page_size": 64},
                                        prefix_len=512) \
            == prefix_shared_pool_bytes(sig, {"page_size": 512},
                                        prefix_len=512) \
            == (8 + 4 * 8) * 64 * kv_bytes
        # an unaligned prefix rounds DOWN to the page boundary: small pages
        # keep sharing almost all of it, a cache-sized page shares nothing
        small = prefix_shared_pool_bytes(sig, {"page_size": 64},
                                         prefix_len=511)
        big = prefix_shared_pool_bytes(sig, {"page_size": 512},
                                       prefix_len=511)
        assert small == (7 + 4 * 9) * 64 * kv_bytes  # 448 slots still shared
        assert big == 4 * 2 * 512 * kv_bytes         # 2 private pages each
        assert small < big  # finer pages -> more of the prefix shared

    def test_paged_tune_records_pool_hbm_metric(self, tmp_path, monkeypatch):
        """The paged_decode DSE rows persist the shared-prefix HBM model
        alongside latency/VMEM, so refinement and offline analysis can
        weigh page_size against prefix-cache capacity."""
        path = str(tmp_path / "hbm.json")
        monkeypatch.setenv("REPRO_TUNER_CACHE", path)
        sig = paged_decode_signature(2, 512, 4, 2, 64, "float32")
        tuner = KernelTuner(path)
        tuner.tune(sig, lambda **kn: 1.0)
        entry = tuner.cache.get(sig.key())
        rows = entry["ops"]
        assert all("pool_hbm_bytes" in r["metrics"] for r in rows)
        by_ps = {}
        for r in rows:
            by_ps.setdefault(r["knobs"]["page_size"],
                             r["metrics"]["pool_hbm_bytes"][0])
        sizes = sorted(by_ps)
        assert [by_ps[s] for s in sizes] == sorted(by_ps[s] for s in sizes)


class TestRuntimeFeedback:
    """refine_from_runtime: mARGOt error coefficients over the persisted
    DSE rows — serving traffic refines the priors (ROADMAP feedback-loop
    item)."""

    def _seed_entry(self, path, sig):
        """Synthetic DSE result: latency grows with page_size (bigger pool
        granularity, bigger worst-case DMA), so a latency budget caps how
        big a page the objective (maximize page_size) may pick."""
        tuner = KernelTuner(path)
        ops = []
        for ps, lat in ((64, 0.4e-3), (128, 0.6e-3), (256, 0.9e-3)):
            knobs = {"page_size": ps, "block_kv_dec": 256}
            ops.append({
                "knobs": knobs,
                "metrics": {
                    "latency_s": [lat, 1e-5],
                    "vmem_bytes": [float(config_vmem_bytes(sig, knobs)), 0.0],
                },
            })
        tuner.cache.put(sig.key(), {
            "knobs": dict(ops[-1]["knobs"]),
            "metrics": dict(ops[-1]["metrics"]),
            "ops": ops,
        })
        return tuner

    def test_observation_shifts_selected_knob(self, tmp_path):
        """Observed latency 2x the expectation on the current operating
        point rescales every op; only the small page now fits the budget,
        so the persisted selection must move."""
        path = str(tmp_path / "rt.json")
        sig = paged_decode_signature(2, 1024, 8, 2, 64)
        tuner = self._seed_entry(path, sig)
        assert tuner.lookup(sig)["page_size"] == 256

        # accurate observations: selection stays (largest page under budget)
        got = refine_from_runtime(sig, {"latency_s": 0.9e-3}, tuner=tuner,
                                  latency_budget=1.0e-3)
        assert got["page_size"] == 256

        # drifted context: current op observed at 1.8ms (2x) -> coef 2 ->
        # adjusted latencies (0.8, 1.2, 1.8)ms -> only page_size=64 fits
        got = refine_from_runtime(sig, {"latency_s": 1.8e-3}, tuner=tuner,
                                  latency_budget=1.0e-3)
        assert got["page_size"] == 64
        assert tuner.lookup(sig)["page_size"] == 64

    def test_adjusted_ops_persisted(self, tmp_path):
        """The error-coefficient-adjusted operating points land in the JSON
        cache: a fresh process starts from traffic-refined priors."""
        path = str(tmp_path / "persist.json")
        sig = paged_decode_signature(2, 1024, 8, 2, 64)
        tuner = self._seed_entry(path, sig)
        refine_from_runtime(sig, {"latency_s": 1.8e-3}, tuner=tuner,
                            latency_budget=1.0e-3)

        data = json.load(open(path))
        entry = data[sig.key()]
        assert entry["runtime"]["error_coef"]["latency_s"] == pytest.approx(2.0)
        by_ps = {row["knobs"]["page_size"]: row for row in entry["ops"]}
        assert by_ps[64]["metrics"]["latency_s"][0] == pytest.approx(0.8e-3)
        assert by_ps[256]["metrics"]["latency_s"][0] == pytest.approx(1.8e-3)
        # fresh tuner over the same file serves the refined knob
        assert KernelTuner(path).lookup(sig)["page_size"] == 64

    def test_refinement_compounds_across_observations(self, tmp_path):
        """Coefficients apply to the *persisted* (already adjusted) ops, so
        a second accurate observation keeps the refined expectations."""
        path = str(tmp_path / "compound.json")
        sig = paged_decode_signature(2, 1024, 8, 2, 64)
        tuner = self._seed_entry(path, sig)
        refine_from_runtime(sig, {"latency_s": 1.8e-3}, tuner=tuner,
                            latency_budget=1.0e-3)  # -> page 64 @ 0.8ms
        got = refine_from_runtime(sig, {"latency_s": 0.8e-3}, tuner=tuner,
                                  latency_budget=1.0e-3)
        assert got["page_size"] == 64  # coef 1: expectations already match

    def test_never_tuned_returns_none(self, tmp_path):
        tuner = KernelTuner(str(tmp_path / "cold.json"))
        sig = paged_decode_signature(2, 1024, 8, 2, 64)
        assert refine_from_runtime(sig, {"latency_s": 1e-3},
                                   tuner=tuner, latency_budget=1e-3) is None
