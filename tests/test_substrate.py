"""Data pipeline, optimizer (+compression), checkpointing (+elastic reshard),
fault tolerance (watchdog/heartbeat/straggler/fleet sim), sharding rules."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.distributed.fault import (
    FleetSim, HeartbeatMonitor, PreemptionHandler, Watchdog,
)
from repro.monitor.examon import ExamonBroker
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.optim.compression import compressed_bytes, ef_compress
from repro.optim.schedule import warmup_cosine


class TestPipeline:
    def test_deterministic_and_resumable(self):
        cfg = PipelineConfig(vocab=100, seq_len=8, global_batch=4)
        p1 = TokenPipeline(cfg)
        batches = [next(p1) for _ in range(5)]
        state = p1.state_dict()
        more = [next(p1) for _ in range(3)]
        p2 = TokenPipeline(cfg)
        p2.load_state_dict(state)
        replay = [next(p2) for _ in range(3)]
        for a, b in zip(more, replay):
            np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_host_sharding_disjoint(self):
        cfg = PipelineConfig(vocab=1000, seq_len=8, global_batch=8, mode="uniform")
        h0 = TokenPipeline(cfg, host_id=0, num_hosts=2).batch_at(0)
        h1 = TokenPipeline(cfg, host_id=1, num_hosts=2).batch_at(0)
        assert h0["tokens"].shape == (4, 8)
        assert not np.array_equal(h0["tokens"], h1["tokens"])

    def test_labels_shifted(self):
        cfg = PipelineConfig(vocab=100, seq_len=8, global_batch=2, noise=0.0)
        b = TokenPipeline(cfg).batch_at(0)
        np.testing.assert_array_equal(
            (31 * b["tokens"].astype(np.int64) + 17) % 100, b["labels"])

    @settings(max_examples=10, deadline=None)
    @given(step=st.integers(0, 1000), hosts=st.sampled_from([1, 2, 4]))
    def test_property_stateless_addressing(self, step, hosts):
        cfg = PipelineConfig(vocab=50, seq_len=4, global_batch=8)
        a = TokenPipeline(cfg, host_id=0, num_hosts=hosts).batch_at(step)
        b = TokenPipeline(cfg, host_id=0, num_hosts=hosts).batch_at(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


class TestAdamW:
    def _quad(self, cfg, steps=60, lr=0.1):
        params = {"w": jnp.asarray([2.0, -3.0, 1.5])}
        state = adamw.init_state(params, cfg)
        for i in range(steps):
            grads = {"w": 2 * params["w"]}  # d/dw of ||w||^2
            params, state, _ = adamw.apply_updates(
                params, grads, state, cfg, jnp.asarray(lr))
        return float(jnp.max(jnp.abs(params["w"])))

    def test_converges_quadratic(self):
        final = self._quad(AdamWConfig(weight_decay=0.0))
        assert final < 0.3

    def test_bf16_states_still_converge(self):
        final = self._quad(AdamWConfig(weight_decay=0.0, state_dtype="bfloat16"))
        assert final < 0.4

    def test_compression_error_feedback_converges(self):
        final = self._quad(AdamWConfig(weight_decay=0.0, compression=True))
        assert final < 0.4

    def test_clipping(self):
        cfg = AdamWConfig(clip_norm=1.0)
        params = {"w": jnp.zeros(4)}
        state = adamw.init_state(params, cfg)
        _, _, m = adamw.apply_updates(params, {"w": jnp.full(4, 100.0)},
                                      state, cfg, jnp.asarray(0.0))
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_schedule(self):
        assert float(warmup_cosine(0, peak=1.0, warmup=10, total=100)) == 0.0
        assert float(warmup_cosine(10, peak=1.0, warmup=10, total=100)) == pytest.approx(1.0)
        assert float(warmup_cosine(100, peak=1.0, warmup=10, total=100)) == pytest.approx(0.1)


class TestCompression:
    @settings(max_examples=15, deadline=None)
    @given(scale=st.floats(0.001, 100.0), n=st.sampled_from([256, 1024]))
    def test_property_ef_bounded_error(self, scale, n):
        g = jnp.asarray(np.random.default_rng(int(scale * 10)).normal(
            0, scale, (2, n)), jnp.float32)
        ef = jnp.zeros_like(g)
        deq, ef_new = ef_compress(g, ef)
        # quantization error is carried, not lost
        np.testing.assert_allclose(np.asarray(deq + ef_new), np.asarray(g),
                                   rtol=1e-5, atol=1e-5 * scale)
        # per-row error bounded by one quantization bucket
        bucket = np.abs(np.asarray(g)).max(-1) / 127.0
        assert float(jnp.max(jnp.abs(ef_new))) <= float(bucket.max()) + 1e-6

    def test_wire_reduction(self):
        g = {"w": jnp.zeros((512, 512), jnp.float32)}
        assert compressed_bytes(g) < 0.3 * 512 * 512 * 4


class TestCheckpointer:
    def _tree(self, v=0.0):
        return {"params": {"w": jnp.full((4, 4), v)}, "step": jnp.asarray(3)}

    def test_roundtrip_async_atomic(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path), keep=2)
        ckpt.save(10, self._tree(1.0))
        ckpt.wait()
        ckpt.save(20, self._tree(2.0))
        ckpt.wait()
        tree, manifest = ckpt.restore(self._tree())
        assert manifest["step"] == 20
        assert float(tree["params"]["w"][0, 0]) == 2.0
        assert not any(".tmp" in n for n in os.listdir(tmp_path))

    def test_gc_keeps_last_k(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            ckpt.save(s, self._tree(float(s)))
        assert ckpt.all_steps() == [3, 4]

    def test_restore_specific_step(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path), keep=5, async_save=False)
        ckpt.save(1, self._tree(1.0))
        ckpt.save(2, self._tree(2.0))
        tree, _ = ckpt.restore(self._tree(), step=1)
        assert float(tree["params"]["w"][0, 0]) == 1.0


class TestFault:
    def test_watchdog_fires(self):
        fired = []
        wd = Watchdog(0.05, lambda: fired.append(1))
        wd.beat()
        time.sleep(0.15)
        assert fired
        wd.beat()
        wd.cancel()
        time.sleep(0.1)
        assert len(fired) == 1

    def test_preemption_flag(self):
        p = PreemptionHandler(install=False)
        assert not p.pending
        p.request()
        assert p.pending

    def test_straggler_detection(self):
        broker = ExamonBroker()
        flagged = []
        mon = HeartbeatMonitor(broker, factor=2.0, patience=2,
                               on_straggler=flagged.append)
        for _ in range(6):
            for host in range(4):
                dt = 0.5 if host == 2 else 0.1
                broker.publish(f"fleet/heartbeat/@host{host}", dt)
        assert flagged == [2]

    def test_fleet_sim_failure_and_straggler(self):
        broker = ExamonBroker()
        sim = FleetSim(4, broker)
        ok = [sim.tick() for _ in range(3)]
        assert all(ok)
        sim.inject_failure(1)
        assert sim.tick() is False  # global step lost
        assert sim.tick() is True  # worker restarted
        sim.inject_straggler(3, slowdown=6.0)
        for _ in range(6):
            sim.tick()
        assert 3 in sim.replacements


class TestShardingRules:
    def test_pspec_shape_guarded(self):
        import jax
        from repro.distributed.sharding import logical_to_pspec
        if jax.device_count() < 2:
            pytest.skip("single device")

    def test_rules_validate(self):
        from repro.core.strategies.parallelization import validate_rules
        validate_rules({"batch": ("data",), "mlp": "model"})
        with pytest.raises(ValueError):
            validate_rules({"batch": ("data", "model"), "heads": "model"})
