"""Block-sparse grid pruning: the pruned kernel must match the dense kernel
and the oracle across causal / sliding-window / GQA / ragged shapes, and its
KV schedule must never stream a fully-masked block (deliverable: the §Perf
follow-up recorded in the kernel docstring, now implemented)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import (
    block_fully_masked,
    cdiv,
    flash_attention_fwd,
    kv_schedule,
    kv_steps_for,
    vmem_bytes,
)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _qkv(key, B, S, H, K, D, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    return q, k, v


class TestPrunedParity:
    """Interpret-mode outputs: pruned == dense == attention_ref."""

    @pytest.mark.parametrize("name,S,HK,causal,window,bq,bkv", [
        ("causal", 256, (4, 2), True, None, 128, 128),
        ("sliding", 256, (4, 4), True, 64, 64, 64),
        ("window_lt_block", 256, (8, 1), True, 32, 128, 128),
        ("ragged_q", 320, (4, 2), True, None, 128, 128),
        ("ragged_window", 320, (4, 2), True, 96, 128, 64),
        ("tiny", 96, (2, 2), True, 48, 64, 64),
        ("noncausal", 256, (2, 2), False, None, 128, 128),
    ])
    def test_parity(self, key, name, S, HK, causal, window, bq, bkv):
        H, K = HK
        q, k, v = _qkv(key, 2, S, H, K, 64)
        kw = dict(causal=causal, window=window, block_q=bq, block_kv=bkv,
                  interpret=True)
        out_p = flash_attention(q, k, v, pruned=True, **kw)
        out_d = flash_attention(q, k, v, pruned=False, **kw)
        ref = attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                                   rtol=2e-6, atol=2e-6)

    def test_gqa_group_mapping_pruned(self, key):
        """Each q head must attend its own kv group through the remapped
        index maps too."""
        B, S, H, K, D = 1, 128, 4, 2, 64
        q, k, v = _qkv(key, B, S, H, K, D)
        v = v.at[:, :, 1].mul(100.0)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                              pruned=True, interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-3)

    def test_bf16_softcap(self, key):
        q, k, v = _qkv(key, 1, 256, 4, 2, 64, jnp.bfloat16)
        out = flash_attention(q, k, v, causal=True, window=64, softcap=30.0,
                              block_q=128, block_kv=128, pruned=True,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=True, window=64, softcap=30.0)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_kernel_layout_entry(self, key):
        """flash_attention_fwd (kernel layout) prunes identically."""
        B, H, K, S, D = 1, 4, 2, 320, 64
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, H, S, D))
        k = jax.random.normal(ks[1], (B, K, S, D))
        v = jax.random.normal(ks[2], (B, K, S, D))
        out_p = flash_attention_fwd(q, k, v, causal=True, window=128,
                                    block_q=128, block_kv=128, pruned=True,
                                    interpret=True)
        out_d = flash_attention_fwd(q, k, v, causal=True, window=128,
                                    block_q=128, block_kv=128, pruned=False,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                                   rtol=2e-6, atol=2e-6)


class TestKVSchedule:
    """The schedule (the kernel's exact index remapping, in numpy) streams
    no dead blocks and shrinks with the mask."""

    @pytest.mark.parametrize("S,T,bq,bkv,window", [
        (1024, 1024, 128, 128, None),
        (1024, 1024, 128, 128, 256),
        (1024, 1024, 256, 128, 384),
        (896, 896, 128, 256, 128),   # ragged + mixed blocks
        (4096, 4096, 512, 512, 512),
    ])
    def test_no_fully_masked_block_streamed(self, S, T, bq, bkv, window):
        sched = kv_schedule(S, T, bq, bkv, causal=True, window=window,
                            pruned=True)
        for iq, row in enumerate(sched):
            for ik in row:
                assert not block_fully_masked(
                    iq, ik, bq, bkv, kv_len=T, causal=True, window=window
                ), f"pruned schedule streams dead block (iq={iq}, ik={ik})"

    @pytest.mark.parametrize("S,T,bq,bkv,window", [
        (1024, 1024, 128, 128, None),
        (1024, 1024, 128, 128, 256),
    ])
    def test_every_live_block_streamed(self, S, T, bq, bkv, window):
        """Pruning must be exact, not lossy: every partially-unmasked block
        appears in the schedule."""
        sched = kv_schedule(S, T, bq, bkv, causal=True, window=window,
                            pruned=True)
        nq, nk = cdiv(S, bq), cdiv(T, bkv)
        for iq in range(nq):
            live = {ik for ik in range(nk)
                    if not block_fully_masked(iq, ik, bq, bkv, kv_len=T,
                                              causal=True, window=window)}
            assert live <= set(sched[iq]), (iq, live - set(sched[iq]))

    def test_causal_halves_traffic(self):
        sched = kv_schedule(2048, 2048, 128, 128, causal=True, pruned=True)
        streamed = sum(len(r) for r in sched)
        dense = 16 * 16
        assert streamed == sum(range(1, 17))  # triangular
        assert streamed / dense < 0.6

    def test_window_traffic_is_linear_in_S(self):
        """O(S*W): doubling S doubles streamed blocks under a fixed window
        (dense doubles quadratically)."""
        W, b = 512, 128
        n1 = sum(len(r) for r in kv_schedule(4096, 4096, b, b, causal=True,
                                             window=W, pruned=True))
        n2 = sum(len(r) for r in kv_schedule(8192, 8192, b, b, causal=True,
                                             window=W, pruned=True))
        # affine in S (n = steps*nq - c with c from the truncated first rows),
        # so doubling S doubles the count plus at most that constant
        steps = kv_steps_for(8192, 8192, b, b, True, W)
        assert n2 <= 2 * n1 + steps * (steps - 1) // 2
        assert n2 < 0.2 * (8192 // b) ** 2  # far below dense O(S^2)

    def test_dense_schedule_streams_everything(self):
        sched = kv_schedule(512, 512, 128, 128, causal=True, pruned=False)
        assert all(row == [0, 1, 2, 3] for row in sched)

    def test_kv_steps_matches_schedule_width(self):
        for S, W in ((1024, None), (1024, 256), (768, 128)):
            steps = kv_steps_for(S, S, 128, 128, True, W)
            sched = kv_schedule(S, S, 128, 128, causal=True, window=W,
                                pruned=True)
            assert max(len(r) for r in sched) <= steps


class TestVmemBytes:
    def test_kv_dtype_counted_for_k_and_v(self):
        """K and V must both scale with the KV dtype."""
        base = vmem_bytes(128, 128, 64, 2, kv_dtype_bytes=2)
        wide = vmem_bytes(128, 128, 64, 2, kv_dtype_bytes=4)
        # doubling kv bytes adds exactly 2 (K+V) * block * D * 2 (extra
        # bytes) * 2 (double buffering)
        assert wide - base == 2 * (2 * 128 * 64 * 2)

    def test_monotone_in_blocks(self):
        assert vmem_bytes(256, 256, 64) > vmem_bytes(128, 128, 64)

    def test_default_config_fits_vmem(self):
        assert vmem_bytes(512, 512, 128) < 16 * 2**20
