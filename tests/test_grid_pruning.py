"""Block-sparse grid pruning: the pruned kernel must match the dense kernel
and the oracle across causal / sliding-window / GQA / ragged shapes, and its
KV schedule must never stream a fully-masked block — in *both* directions:
the forward / dq pass streams `kv_schedule`, the fused dk/dv backward pass
streams the transposed `q_schedule` (the PR 2 deliverable)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import (
    block_fully_masked,
    cdiv,
    flash_attention_bwd,
    flash_attention_fwd,
    kv_schedule,
    kv_steps_for,
    q_schedule,
    q_steps_for,
    vmem_bytes,
    vmem_bytes_bwd,
)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _qkv(key, B, S, H, K, D, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    return q, k, v


class TestPrunedParity:
    """Interpret-mode outputs: pruned == dense == attention_ref."""

    @pytest.mark.parametrize("name,S,HK,causal,window,bq,bkv", [
        ("causal", 256, (4, 2), True, None, 128, 128),
        ("sliding", 256, (4, 4), True, 64, 64, 64),
        ("window_lt_block", 256, (8, 1), True, 32, 128, 128),
        ("ragged_q", 320, (4, 2), True, None, 128, 128),
        ("ragged_window", 320, (4, 2), True, 96, 128, 64),
        ("tiny", 96, (2, 2), True, 48, 64, 64),
        ("noncausal", 256, (2, 2), False, None, 128, 128),
    ])
    def test_parity(self, key, name, S, HK, causal, window, bq, bkv):
        H, K = HK
        q, k, v = _qkv(key, 2, S, H, K, 64)
        kw = dict(causal=causal, window=window, block_q=bq, block_kv=bkv,
                  interpret=True)
        out_p = flash_attention(q, k, v, pruned=True, **kw)
        out_d = flash_attention(q, k, v, pruned=False, **kw)
        ref = attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                                   rtol=2e-6, atol=2e-6)

    def test_gqa_group_mapping_pruned(self, key):
        """Each q head must attend its own kv group through the remapped
        index maps too."""
        B, S, H, K, D = 1, 128, 4, 2, 64
        q, k, v = _qkv(key, B, S, H, K, D)
        v = v.at[:, :, 1].mul(100.0)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                              pruned=True, interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-3)

    def test_bf16_softcap(self, key):
        q, k, v = _qkv(key, 1, 256, 4, 2, 64, jnp.bfloat16)
        out = flash_attention(q, k, v, causal=True, window=64, softcap=30.0,
                              block_q=128, block_kv=128, pruned=True,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=True, window=64, softcap=30.0)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_kernel_layout_entry(self, key):
        """flash_attention_fwd (kernel layout) prunes identically."""
        B, H, K, S, D = 1, 4, 2, 320, 64
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, H, S, D))
        k = jax.random.normal(ks[1], (B, K, S, D))
        v = jax.random.normal(ks[2], (B, K, S, D))
        out_p = flash_attention_fwd(q, k, v, causal=True, window=128,
                                    block_q=128, block_kv=128, pruned=True,
                                    interpret=True)
        out_d = flash_attention_fwd(q, k, v, causal=True, window=128,
                                    block_q=128, block_kv=128, pruned=False,
                                    interpret=True)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                                   rtol=2e-6, atol=2e-6)


class TestBackwardParity:
    """jax.grad through the fused Pallas backward == the reference VJP."""

    @pytest.mark.parametrize("name,S,HK,causal,window,softcap,bq,bkv", [
        ("causal", 256, (4, 2), True, None, None, 128, 128),
        ("sliding", 256, (4, 4), True, 64, None, 64, 64),
        ("softcap", 128, (2, 2), True, 64, 30.0, 64, 64),
        ("gqa", 128, (8, 2), True, None, None, 64, 64),
        ("ragged", 320, (4, 2), True, 96, None, 128, 64),
        ("noncausal", 192, (2, 2), False, None, None, 64, 64),
    ])
    def test_grad_parity(self, key, name, S, HK, causal, window, softcap,
                         bq, bkv):
        H, K = HK
        q, k, v = _qkv(key, 2, S, H, K, 64)
        g = jax.random.normal(jax.random.fold_in(key, 7), q.shape)
        kw = dict(causal=causal, window=window, softcap=softcap)

        def loss_pallas(q, k, v):
            out = flash_attention(q, k, v, block_q=bq, block_kv=bkv,
                                  block_q_bwd=bq, block_kv_bwd=bkv,
                                  pruned=True, interpret=True, **kw)
            return jnp.sum(out * g)

        def loss_ref(q, k, v):
            return jnp.sum(attention_ref(q, k, v, **kw) * g)

        got = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
        want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for name_g, a, b in zip(("dq", "dk", "dv"), got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4, err_msg=name_g)

    def test_bwd_blocks_differ_from_fwd_blocks(self, key):
        """Independently tuned backward blocks must not change gradients."""
        q, k, v = _qkv(key, 1, 256, 4, 2, 64)

        def loss(bqb, bkvb):
            def f(q, k, v):
                out = flash_attention(q, k, v, causal=True, window=96,
                                      block_q=128, block_kv=128,
                                      block_q_bwd=bqb, block_kv_bwd=bkvb,
                                      pruned=True, interpret=True)
                return jnp.sum(out * out)
            return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

        base = loss(128, 128)
        other = loss(64, 256)
        for a, b in zip(base, other):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=2e-5)

    def test_bf16_grad_parity(self, key):
        q, k, v = _qkv(key, 1, 192, 4, 2, 64, jnp.bfloat16)
        g = jax.random.normal(jax.random.fold_in(key, 3), q.shape)

        def loss_pallas(q, k, v):
            out = flash_attention(q, k, v, causal=True, block_q=64,
                                  block_kv=64, interpret=True)
            return jnp.sum(out.astype(jnp.float32) * g)

        def loss_ref(q, k, v):
            return jnp.sum(attention_ref(q, k, v, causal=True)
                           .astype(jnp.float32) * g)

        got = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
        want = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-2, atol=5e-2)

    def test_backward_never_calls_attention_ref(self, key, monkeypatch):
        """The acceptance criterion: grad no longer recomputes through the
        dense reference.  Poison attention_ref; tracing the backward (fresh
        unseen shape, so no jit-cache hit) must not touch it."""
        import repro.kernels.flash_attention.ref as ref_mod

        def boom(*a, **kw):  # pragma: no cover - fails the test if reached
            raise AssertionError("fused backward recomputed via attention_ref")

        monkeypatch.setattr(ref_mod, "attention_ref", boom)
        q, k, v = _qkv(key, 1, 160, 2, 1, 64)

        def loss(q, k, v):
            out = flash_attention(q, k, v, causal=True, window=80,
                                  block_q=32, block_kv=32, interpret=True)
            return jnp.sum(out * out)

        grads = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
        assert all(bool(jnp.all(jnp.isfinite(t))) for t in grads)

    def test_kernel_layout_bwd_entry(self, key):
        """flash_attention_bwd (kernel layout) pruned == dense."""
        B, H, K, S, D = 1, 4, 2, 320, 64
        ks = jax.random.split(key, 4)
        q = jax.random.normal(ks[0], (B, H, S, D))
        k = jax.random.normal(ks[1], (B, K, S, D))
        v = jax.random.normal(ks[2], (B, K, S, D))
        do = jax.random.normal(ks[3], (B, H, S, D))
        out, lse = flash_attention_fwd(q, k, v, causal=True, window=128,
                                       block_q=128, block_kv=128,
                                       interpret=True, return_lse=True)
        kw = dict(causal=True, window=128, block_q=128, block_kv=128,
                  interpret=True)
        grads_p = flash_attention_bwd(q, k, v, out, lse, do, pruned=True, **kw)
        grads_d = flash_attention_bwd(q, k, v, out, lse, do, pruned=False, **kw)
        for a, b in zip(grads_p, grads_d):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=2e-5)


class TestGroupLocalDkv:
    """The dk/dv pass accumulates group-locally: its HBM write is the true
    (B, K, T, D) gradient pair — O(S·K·D) — never a per-q-head (B, H, T, D)
    transient (the PR 3 satellite; was the recorded PR 2 follow-up)."""

    def _captured_bwd_out_shapes(self, key, H, K, monkeypatch):
        import repro.kernels.flash_attention.kernel as kmod

        captured = []
        real = kmod.pl.pallas_call

        def spy(kernel, *args, **kw):
            out_shape = kw.get("out_shape")
            if (isinstance(out_shape, list) and len(out_shape) == 2
                    and all(len(s.shape) == 4 for s in out_shape)):
                captured.append([tuple(s.shape) for s in out_shape])  # dk, dv
            return real(kernel, *args, **kw)

        monkeypatch.setattr(kmod.pl, "pallas_call", spy)
        B, S, D = 1, 128, 64
        ks = jax.random.split(key, 4)
        q = jax.random.normal(ks[0], (B, H, S, D))
        k = jax.random.normal(ks[1], (B, K, S, D))
        v = jax.random.normal(ks[2], (B, K, S, D))
        do = jax.random.normal(ks[3], (B, H, S, D))
        out, lse = flash_attention_fwd(q, k, v, causal=True, block_q=64,
                                       block_kv=64, interpret=True,
                                       return_lse=True)
        grads = flash_attention_bwd(q, k, v, out, lse, do, causal=True,
                                    block_q=64, block_kv=64, interpret=True)
        return captured, grads

    def test_dkv_write_volume_is_kv_heads_sized(self, key, monkeypatch):
        """With a 4:1 GQA group, the dk/dv HBM write must be 4x smaller than
        the per-q-head layout."""
        H, K = 8, 2
        captured, grads = self._captured_bwd_out_shapes(key, H, K, monkeypatch)
        assert len(captured) == 1, "expected exactly one dk/dv pallas_call"
        dk_shape, dv_shape = captured[0]
        B, S, D = 1, 128, 64
        assert dk_shape == (B, K, S, D), dk_shape   # K heads, not H
        assert dv_shape == (B, K, S, D), dv_shape
        written = 2 * np.prod(dk_shape)             # dk + dv fp32 elements
        per_q_head = 2 * B * H * S * D              # the old transient
        assert written * (H // K) == per_q_head     # exactly G-fold smaller
        assert grads[1].shape == (B, K, S, D)
        assert grads[2].shape == (B, K, S, D)

    def test_group_local_grads_match_reference(self, key):
        """Group-local accumulation must equal the reference group-sum."""
        H, K, S, D = 8, 2, 192, 64
        q, k, v = _qkv(key, 2, S, H, K, D)
        g = jax.random.normal(jax.random.fold_in(key, 5), q.shape)

        def loss_pallas(q, k, v):
            out = flash_attention(q, k, v, causal=True, window=64,
                                  block_q=64, block_kv=64, interpret=True)
            return jnp.sum(out * g)

        def loss_ref(q, k, v):
            return jnp.sum(attention_ref(q, k, v, causal=True, window=64) * g)

        got = jax.grad(loss_pallas, argnums=(1, 2))(q, k, v)
        want = jax.grad(loss_ref, argnums=(1, 2))(q, k, v)
        for name, a, b in zip(("dk", "dv"), got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4, err_msg=name)


class TestQSchedule:
    """The transposed (dk/dv) schedule: exact pruning, and bwd HBM traffic
    stays O(S·W) for windowed attention."""

    @pytest.mark.parametrize("S,T,bq,bkv,window", [
        (1024, 1024, 128, 128, None),
        (1024, 1024, 128, 128, 256),
        (1024, 1024, 256, 128, 384),
        (896, 896, 128, 256, 128),   # ragged + mixed blocks
        (4096, 4096, 512, 512, 512),
    ])
    def test_no_fully_masked_block_streamed(self, S, T, bq, bkv, window):
        sched = q_schedule(S, T, bq, bkv, causal=True, window=window,
                           pruned=True)
        for ik, row in enumerate(sched):
            for iq in row:
                assert not block_fully_masked(
                    iq, ik, bq, bkv, kv_len=T, causal=True, window=window
                ), f"pruned bwd schedule streams dead block (ik={ik}, iq={iq})"

    @pytest.mark.parametrize("S,T,bq,bkv,window", [
        (1024, 1024, 128, 128, None),
        (1024, 1024, 128, 128, 256),
        (896, 896, 128, 256, 128),
    ])
    def test_every_live_block_streamed(self, S, T, bq, bkv, window):
        sched = q_schedule(S, T, bq, bkv, causal=True, window=window,
                           pruned=True)
        nq, nk = cdiv(S, bq), cdiv(T, bkv)
        for ik in range(nk):
            live = {iq for iq in range(nq)
                    if not block_fully_masked(iq, ik, bq, bkv, kv_len=T,
                                              causal=True, window=window)}
            assert live <= set(sched[ik]), (ik, live - set(sched[ik]))

    def test_bwd_window_traffic_is_linear_in_S(self):
        """The full backward (dq pass: kv_schedule, dk/dv pass: q_schedule)
        streams O(S*W) blocks for window-W attention, not O(S^2)."""
        W, b = 512, 128

        def bwd_streamed(S):
            dq = sum(len(r) for r in kv_schedule(S, S, b, b, causal=True,
                                                 window=W, pruned=True))
            dkv = sum(len(r) for r in q_schedule(S, S, b, b, causal=True,
                                                 window=W, pruned=True))
            return dq + dkv

        n1, n2 = bwd_streamed(4096), bwd_streamed(8192)
        steps = (kv_steps_for(8192, 8192, b, b, True, W)
                 + q_steps_for(8192, 8192, b, b, True, W))
        # affine in S modulo the truncated boundary rows
        assert n2 <= 2 * n1 + steps * (steps - 1)
        assert n2 < 0.2 * 2 * (8192 // b) ** 2  # far below dense both-pass S^2

    def test_dense_schedule_streams_everything(self):
        sched = q_schedule(512, 512, 128, 128, causal=True, pruned=False)
        assert all(row == [0, 1, 2, 3] for row in sched)

    def test_q_steps_matches_schedule_width(self):
        for S, W in ((1024, None), (1024, 256), (768, 128)):
            steps = q_steps_for(S, S, 128, 128, True, W)
            sched = q_schedule(S, S, 128, 128, causal=True, window=W,
                               pruned=True)
            assert max(len(r) for r in sched) <= steps


class TestKVSchedule:
    """The schedule (the kernel's exact index remapping, in numpy) streams
    no dead blocks and shrinks with the mask."""

    @pytest.mark.parametrize("S,T,bq,bkv,window", [
        (1024, 1024, 128, 128, None),
        (1024, 1024, 128, 128, 256),
        (1024, 1024, 256, 128, 384),
        (896, 896, 128, 256, 128),   # ragged + mixed blocks
        (4096, 4096, 512, 512, 512),
    ])
    def test_no_fully_masked_block_streamed(self, S, T, bq, bkv, window):
        sched = kv_schedule(S, T, bq, bkv, causal=True, window=window,
                            pruned=True)
        for iq, row in enumerate(sched):
            for ik in row:
                assert not block_fully_masked(
                    iq, ik, bq, bkv, kv_len=T, causal=True, window=window
                ), f"pruned schedule streams dead block (iq={iq}, ik={ik})"

    @pytest.mark.parametrize("S,T,bq,bkv,window", [
        (1024, 1024, 128, 128, None),
        (1024, 1024, 128, 128, 256),
    ])
    def test_every_live_block_streamed(self, S, T, bq, bkv, window):
        """Pruning must be exact, not lossy: every partially-unmasked block
        appears in the schedule."""
        sched = kv_schedule(S, T, bq, bkv, causal=True, window=window,
                            pruned=True)
        nq, nk = cdiv(S, bq), cdiv(T, bkv)
        for iq in range(nq):
            live = {ik for ik in range(nk)
                    if not block_fully_masked(iq, ik, bq, bkv, kv_len=T,
                                              causal=True, window=window)}
            assert live <= set(sched[iq]), (iq, live - set(sched[iq]))

    def test_causal_halves_traffic(self):
        sched = kv_schedule(2048, 2048, 128, 128, causal=True, pruned=True)
        streamed = sum(len(r) for r in sched)
        dense = 16 * 16
        assert streamed == sum(range(1, 17))  # triangular
        assert streamed / dense < 0.6

    def test_window_traffic_is_linear_in_S(self):
        """O(S*W): doubling S doubles streamed blocks under a fixed window
        (dense doubles quadratically)."""
        W, b = 512, 128
        n1 = sum(len(r) for r in kv_schedule(4096, 4096, b, b, causal=True,
                                             window=W, pruned=True))
        n2 = sum(len(r) for r in kv_schedule(8192, 8192, b, b, causal=True,
                                             window=W, pruned=True))
        # affine in S (n = steps*nq - c with c from the truncated first rows),
        # so doubling S doubles the count plus at most that constant
        steps = kv_steps_for(8192, 8192, b, b, True, W)
        assert n2 <= 2 * n1 + steps * (steps - 1) // 2
        assert n2 < 0.2 * (8192 // b) ** 2  # far below dense O(S^2)

    def test_dense_schedule_streams_everything(self):
        sched = kv_schedule(512, 512, 128, 128, causal=True, pruned=False)
        assert all(row == [0, 1, 2, 3] for row in sched)

    def test_kv_steps_matches_schedule_width(self):
        for S, W in ((1024, None), (1024, 256), (768, 128)):
            steps = kv_steps_for(S, S, 128, 128, True, W)
            sched = kv_schedule(S, S, 128, 128, causal=True, window=W,
                                pruned=True)
            assert max(len(r) for r in sched) <= steps


class TestVmemBytes:
    def test_kv_dtype_counted_for_k_and_v(self):
        """K and V must both scale with the KV dtype."""
        base = vmem_bytes(128, 128, 64, 2, kv_dtype_bytes=2)
        wide = vmem_bytes(128, 128, 64, 2, kv_dtype_bytes=4)
        # doubling kv bytes adds exactly 2 (K+V) * block * D * 2 (extra
        # bytes) * 2 (double buffering)
        assert wide - base == 2 * (2 * 128 * 64 * 2)

    def test_monotone_in_blocks(self):
        assert vmem_bytes(256, 256, 64) > vmem_bytes(128, 128, 64)

    def test_default_config_fits_vmem(self):
        assert vmem_bytes(512, 512, 128) < 16 * 2**20

    def test_default_bwd_config_fits_vmem(self):
        assert vmem_bytes(512, 512, 128) < vmem_bytes_bwd(512, 512, 128) \
            < 16 * 2**20
