"""Paged KV-cache pool + continuous batching (the PR 4 serving layer).

Covers:
  - PagePool allocator invariants, property-tested over random op
    sequences: no double allocation, free-list reuse, block tables only
    ever reference live pages, conservation of pages;
  - reservation-aware admission (deadlock-free growth);
  - PagedCacheManager round-trips (admit -> batch -> absorb -> retire);
  - Server.serve_continuous == serve_batch == per-request serve, including
    under interleaved admit/retire (tiny pool / batch caps) — the
    continuous-batching acceptance criterion.
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from _hypothesis_compat import given, settings, st

from repro.runtime.pages import (
    PagePool,
    PagedCacheManager,
    PoolExhausted,
    build_linear_pool,
    cdiv,
    paged_compatible,
)


class TestPagePool:
    def test_alloc_release_roundtrip(self):
        pool = PagePool(8, 16)
        a = pool.alloc("a", 3)
        b = pool.alloc("b", 2)
        assert len(set(a) | set(b)) == 5  # disjoint
        assert pool.free_pages == 3
        pool.release("a")
        assert pool.free_pages == 6
        c = pool.alloc("c", 4)
        assert set(c) & set(a)  # freed pages are reused
        assert not (set(c) & set(b))

    def test_lifo_reuse_keeps_working_set_compact(self):
        pool = PagePool(16, 8)
        first = pool.alloc("a", 2)
        pool.release("a")
        again = pool.alloc("b", 2)
        assert set(again) == set(first)

    def test_exhaustion_raises_and_rolls_back_nothing(self):
        pool = PagePool(4, 8)
        pool.alloc("a", 3)
        with pytest.raises(PoolExhausted):
            pool.alloc("b", 2)
        assert pool.free_pages == 1
        assert "b" not in pool.tables

    def test_grow_appends_at_tail(self):
        pool = PagePool(8, 8)
        start = list(pool.alloc("a", 2))
        new = pool.grow_to("a", 4)
        assert pool.tables["a"][:2] == start  # prefix untouched
        assert pool.tables["a"][2:] == new
        assert pool.grow_to("a", 3) == []  # already covered

    def test_double_alloc_rejected(self):
        pool = PagePool(4, 8)
        pool.alloc("a", 1)
        with pytest.raises(KeyError):
            pool.alloc("a", 1)

    def test_table_rows_pads_with_valid_page(self):
        pool = PagePool(8, 8)
        pool.alloc("a", 2)
        pool.alloc("b", 3)
        rows = pool.table_rows(["a", "b"], width=4)
        assert rows.shape == (2, 4)
        assert (rows >= 0).all() and (rows < 8).all()
        assert list(rows[1, :3]) == pool.tables["b"]

    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 5)),
                    min_size=1, max_size=60))
    @settings(max_examples=50, deadline=None)
    def test_invariants_under_random_churn(self, ops):
        """Random alloc/grow/release sequences preserve the allocator
        invariants: live tables are pairwise disjoint, live + free is a
        partition of the pool, and every table entry is a valid page."""
        pool = PagePool(24, 8)
        rid = 0
        live = {}
        for op, arg in ops:
            if op == 0:  # alloc a new request
                try:
                    live[rid] = pool.alloc(rid, arg)
                except PoolExhausted:
                    assert pool.free_pages < arg
                rid += 1
            elif op == 1 and live:  # grow the oldest live request
                target = next(iter(live))
                want = len(pool.tables[target]) + arg
                try:
                    pool.grow_to(target, want)
                    live[target] = pool.tables[target]
                except PoolExhausted:
                    assert pool.free_pages < arg
            elif op == 2 and live:  # release the oldest live request
                target = next(iter(live))
                pool.release(target)
                del live[target]

            allocated = [p for t in pool.tables.values() for p in t]
            assert len(allocated) == len(set(allocated))  # no double alloc
            assert len(allocated) + pool.free_pages == pool.num_pages
            assert all(0 <= p < pool.num_pages for p in allocated)
            assert set(pool.tables) == set(live)


class TestBuildLinearPool:
    def test_pool_packs_prefixes_and_tables_resolve(self):
        ks = [np.arange(l * 2 * 4, dtype=np.float32).reshape(l, 2, 4)
              for l in (5, 12)]
        pk, pv, tables, pool = build_linear_pool(ks, ks, 4, max_len=16)
        assert pool.live_pages == cdiv(5, 4) + cdiv(12, 4)
        for i, l in enumerate((5, 12)):
            got = np.asarray(pk[tables[i]]).reshape(-1, 2, 4)[:l]
            np.testing.assert_array_equal(got, ks[i])


class TestPagedCacheManager:
    def _prefill_cache(self, model, params, pol, toks):
        from repro.nn.module import Ctx

        ctx = Ctx(policies=pol, extra={"cache_max_len": 24})
        _, cache = model(params, {"tokens": toks}, ctx=ctx, mode="prefill")
        return cache

    def _setup(self):
        from repro.models.registry import build_model, reduced_config
        from repro.nn.dtypes import PolicyResolver
        from repro.nn.module import init_params

        pol = PolicyResolver.default("double")
        cfg = reduced_config("yi-6b")
        model = build_model(cfg)
        params = init_params(model, jax.random.PRNGKey(0), pol)
        return model, params, pol

    def test_admit_batch_absorb_retire_roundtrip(self):
        model, params, pol = self._setup()
        manager = PagedCacheManager(num_pages=12, page_size=8)
        for rid, S in enumerate((3, 7)):
            toks = jnp.ones((1, S), jnp.int32)
            cache = self._prefill_cache(model, params, pol, toks)
            assert paged_compatible(cache)
            assert rid == 0 or manager.can_admit(S + 4)
            manager.admit(rid, cache, final_len=S + 4)
        cache = manager.batch([0, 1])
        assert "block_tables" in cache and "kv_pos" in cache
        group = next(v for k, v in cache.items()
                     if isinstance(v, dict) and "pk" in v)
        assert group["index"].shape[-1] == 2
        np.testing.assert_array_equal(np.asarray(group["index"])[..., 0], 3)
        manager.absorb([0, 1], cache)  # identity step: lengths advance
        assert manager._meta[0]["length"] == 4
        manager.retire(0)
        assert manager.pool.free_pages > 0
        cache2 = manager.batch([1])
        assert cache2["block_tables"].shape[0] == 1

    def test_rejects_mixed_cache_families(self):
        """Sliding-window models ring only when prompt_len > window, so a
        batch straddling W would mix ring and linear layouts in one pool —
        the manager must refuse loudly instead of silently mis-packing."""
        from repro.models.registry import build_model, reduced_config
        from repro.nn.dtypes import PolicyResolver
        from repro.nn.module import Ctx, init_params

        pol = PolicyResolver.default("double")
        cfg = reduced_config("mixtral-8x22b")  # attn_window=16 reduced
        model = build_model(cfg)
        params = init_params(model, jax.random.PRNGKey(0), pol)
        ctx = Ctx(policies=pol, extra={"cache_max_len": 24})
        caches = []
        for S in (3, 20):  # linear (S <= W) then ring (S > W)
            _, cache = model(params,
                             {"tokens": jnp.ones((1, S), jnp.int32)},
                             ctx=ctx, mode="prefill")
            caches.append(cache)
        manager = PagedCacheManager(num_pages=16, page_size=8)
        manager.admit(0, caches[0], final_len=8)
        with pytest.raises(ValueError, match="family mismatch"):
            manager.admit(1, caches[1], final_len=23)

    def test_rejects_ssm_state(self):
        from repro.models.registry import build_model, reduced_config
        from repro.nn.dtypes import PolicyResolver
        from repro.nn.module import Ctx, init_params

        pol = PolicyResolver.default("double")
        cfg = reduced_config("rwkv6-3b")
        model = build_model(cfg)
        params = init_params(model, jax.random.PRNGKey(0), pol)
        ctx = Ctx(policies=pol, extra={"cache_max_len": 16})
        _, cache = model(params, {"tokens": jnp.ones((1, 4), jnp.int32)},
                         ctx=ctx, mode="prefill")
        assert not paged_compatible(cache)
        manager = PagedCacheManager(num_pages=4, page_size=8)
        with pytest.raises(ValueError):
            manager.admit(0, cache, final_len=8)


def _server(arch, **cfg_kw):
    from repro.configs.base import SHAPES
    from repro.core.program import Program
    from repro.launch.weave import default_weave
    from repro.runtime.server import Server, ServerConfig

    program = Program.from_arch(arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    return Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4,
                                      **cfg_kw))


PROMPTS = [np.ones((5,), np.int32),
           (np.arange(1, 9) % 50).astype(np.int32),
           np.full((3,), 7, np.int32)]


class TestContinuousServer:
    """serve_continuous == serve_batch == per-request serve — bit-identical
    greedy decode over the paged pool (acceptance criterion), for both the
    linear (yi) and ring (mixtral sliding-window) cache families."""

    @pytest.mark.parametrize("arch", ["yi-6b", "mixtral-8x22b"])
    def test_continuous_equals_batch_and_solo(self, arch):
        srv = _server(arch)
        batched = srv.serve_batch(PROMPTS)
        cont = srv.serve_continuous(PROMPTS, page_size=8)
        for p, b, c in zip(PROMPTS, batched, cont):
            np.testing.assert_array_equal(b, c)
            np.testing.assert_array_equal(c, srv.serve(p[None])[0])

    def test_interleaved_admit_retire_parity(self):
        """A batch cap forces late arrivals to wait for a retirement —
        the continuous path must still match the all-at-once batch."""
        srv = _server("yi-6b")
        batched = srv.serve_batch(PROMPTS)
        for max_batch in (1, 2):
            cont = srv.serve_continuous(PROMPTS, page_size=8,
                                        max_batch=max_batch)
            for b, c in zip(batched, cont):
                np.testing.assert_array_equal(b, c)

    def test_page_constrained_admission_parity(self):
        """A pool that cannot hold every request at once must admit in
        waves (pages freed by retirement re-admit the waiters) and still
        match."""
        srv = _server("yi-6b")
        batched = srv.serve_batch(PROMPTS)
        # worst case per request: ceil((8+3)/8) = 2 pages; 4 pages = 2-wide
        cont = srv.serve_continuous(PROMPTS, page_size=8, pool_pages=4)
        for b, c in zip(batched, cont):
            np.testing.assert_array_equal(b, c)

    def test_pool_too_small_raises(self):
        srv = _server("yi-6b")
        with pytest.raises((RuntimeError, PoolExhausted)):
            srv.serve_continuous(PROMPTS, page_size=8, pool_pages=1)

    def test_ssm_family_raises(self):
        srv = _server("rwkv6-3b")
        with pytest.raises(ValueError):
            srv.serve_continuous([np.ones((4,), np.int32)])

    def test_memoized_continuous(self):
        from repro.memo.table import MemoTable

        srv = _server("yi-6b")
        srv.memo = MemoTable(size=8)
        a = srv.serve_continuous(PROMPTS[:2], page_size=8)
        b = srv.serve_continuous(PROMPTS[:2], page_size=8)
        assert srv.memo.hits >= 1
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_decode_step_latencies_recorded_and_refine_smoke(self, tmp_path,
                                                            monkeypatch):
        """Serving records per-step decode latencies and can push them into
        the tuner cache once the paged signature has DSE rows."""
        from repro.autotune.kernel_tuner import KernelTuner, config_vmem_bytes

        monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path / "rt.json"))
        srv = _server("yi-6b")
        srv.serve_continuous(PROMPTS, page_size=8)
        assert srv.decode_step_latencies
        assert srv._paged_sig is not None
        assert srv.refine_kernel_tuner(latency_budget=1.0) is None  # untuned

        tuner = KernelTuner(str(tmp_path / "rt.json"))
        sig = srv._paged_sig
        knobs = {"page_size": 64, "block_kv_dec": 128}
        tuner.cache.put(sig.key(), {
            "knobs": dict(knobs),
            "metrics": {"latency_s": [1e-3, 0.0]},
            "ops": [{"knobs": dict(knobs),
                     "metrics": {
                         "latency_s": [1e-3, 0.0],
                         "vmem_bytes": [
                             float(config_vmem_bytes(sig, knobs)), 0.0]}}],
        })
        got = srv.refine_kernel_tuner(latency_budget=10.0, tuner=tuner)
        assert got == knobs
        entry = tuner.cache.get(sig.key())
        assert "runtime" in entry and "error_coef" in entry["runtime"]
