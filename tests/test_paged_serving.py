"""Paged KV-cache pool + continuous batching (the PR 4/5 serving layer).

Covers:
  - PagePool allocator invariants, property-tested over random op
    sequences — now including refcounted prefix *sharing* and
    copy-on-write splits: refcounts >= 1 for every table entry, no page
    both free and referenced, conservation (free + distinct live =
    num_pages), CoW never touches a page another request still maps;
  - reservation-aware admission (deadlock-free growth, including CoW
    exposure and the first-admission capacity check);
  - PagedCacheManager round-trips (admit -> batch -> absorb -> retire);
  - Server.serve_continuous == serve_batch == per-request serve, including
    under interleaved admit/retire (tiny pool / batch caps), shared
    prompt prefixes (linear + ring families, GQA + softcap), full-prompt
    re-score admissions and copy-on-write divergence — the
    continuous-batching + prefix-caching acceptance criteria.
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from _hypothesis_compat import given, settings, st

from repro.runtime.pages import (
    PagePool,
    PagedCacheManager,
    PoolExhausted,
    build_linear_pool,
    cdiv,
    paged_compatible,
)


class TestPagePool:
    def test_alloc_release_roundtrip(self):
        pool = PagePool(8, 16)
        a = pool.alloc("a", 3)
        b = pool.alloc("b", 2)
        assert len(set(a) | set(b)) == 5  # disjoint
        assert pool.free_pages == 3
        pool.release("a")
        assert pool.free_pages == 6
        c = pool.alloc("c", 4)
        assert set(c) & set(a)  # freed pages are reused
        assert not (set(c) & set(b))

    def test_lifo_reuse_keeps_working_set_compact(self):
        pool = PagePool(16, 8)
        first = pool.alloc("a", 2)
        pool.release("a")
        again = pool.alloc("b", 2)
        assert set(again) == set(first)

    def test_exhaustion_raises_and_rolls_back_nothing(self):
        pool = PagePool(4, 8)
        pool.alloc("a", 3)
        with pytest.raises(PoolExhausted):
            pool.alloc("b", 2)
        assert pool.free_pages == 1
        assert "b" not in pool.tables

    def test_grow_appends_at_tail(self):
        pool = PagePool(8, 8)
        start = list(pool.alloc("a", 2))
        new = pool.grow_to("a", 4)
        assert pool.tables["a"][:2] == start  # prefix untouched
        assert pool.tables["a"][2:] == new
        assert pool.grow_to("a", 3) == []  # already covered

    def test_double_alloc_rejected(self):
        pool = PagePool(4, 8)
        pool.alloc("a", 1)
        with pytest.raises(KeyError):
            pool.alloc("a", 1)

    def test_table_rows_pads_with_valid_page(self):
        pool = PagePool(8, 8)
        pool.alloc("a", 2)
        pool.alloc("b", 3)
        rows = pool.table_rows(["a", "b"], width=4)
        assert rows.shape == (2, 4)
        assert (rows >= 0).all() and (rows < 8).all()
        assert list(rows[1, :3]) == pool.tables["b"]

    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(1, 5)),
                    min_size=1, max_size=60))
    @settings(max_examples=50, deadline=None)
    def test_invariants_under_random_churn(self, ops):
        """Random alloc/grow/release sequences preserve the allocator
        invariants: live tables are pairwise disjoint, live + free is a
        partition of the pool, and every table entry is a valid page."""
        pool = PagePool(24, 8)
        rid = 0
        live = {}
        for op, arg in ops:
            if op == 0:  # alloc a new request
                try:
                    live[rid] = pool.alloc(rid, arg)
                except PoolExhausted:
                    assert pool.free_pages < arg
                rid += 1
            elif op == 1 and live:  # grow the oldest live request
                target = next(iter(live))
                want = len(pool.tables[target]) + arg
                try:
                    pool.grow_to(target, want)
                    live[target] = pool.tables[target]
                except PoolExhausted:
                    assert pool.free_pages < arg
            elif op == 2 and live:  # release the oldest live request
                target = next(iter(live))
                pool.release(target)
                del live[target]

            allocated = [p for t in pool.tables.values() for p in t]
            assert len(allocated) == len(set(allocated))  # no double alloc
            assert len(allocated) + pool.free_pages == pool.num_pages
            assert all(0 <= p < pool.num_pages for p in allocated)
            assert set(pool.tables) == set(live)


class TestRefcountedPool:
    def test_shared_alloc_bumps_refcounts_not_free_list(self):
        pool = PagePool(8, 8)
        a = pool.alloc("a", 3)
        free_before = pool.free_pages
        b = pool.alloc("b", 4, shared=a[:2])
        assert b[:2] == a[:2]
        assert pool.free_pages == free_before - 2  # only the fresh pages
        assert all(pool.refcount(p) == 2 for p in a[:2])
        assert pool.refcount(a[2]) == 1
        assert pool.live_pages == 5  # distinct: 3 + 2 fresh
        assert pool.mapped_pages == 7

    def test_release_frees_only_at_zero(self):
        pool = PagePool(8, 8)
        a = pool.alloc("a", 2)
        pool.alloc("b", 2, shared=a)
        freed = pool.release("a")
        assert freed == []  # b still maps both pages
        assert all(pool.refcount(p) == 1 for p in a)
        freed = pool.release("b")
        assert set(freed) == set(a)
        assert pool.free_pages == 8

    def test_stale_share_rejected(self):
        pool = PagePool(4, 8)
        a = pool.alloc("a", 1)
        pool.release("a")
        with pytest.raises(ValueError, match="stale"):
            pool.alloc("b", 1, shared=a)

    def test_cow_splits_shared_and_skips_exclusive(self):
        pool = PagePool(8, 8)
        a = pool.alloc("a", 2)
        pool.alloc("b", 2, shared=a)
        assert pool.cow("a", 0) is not None
        old_new = pool.tables["a"][0], pool.tables["b"][0]
        assert old_new[0] != old_new[1]           # remapped, not mutated
        assert pool.tables["b"][0] == a[0]        # b keeps the original
        assert pool.refcount(a[0]) == 1
        assert pool.cow("a", 0) is None           # now exclusive: no split
        assert pool.cow("a", 1) is not None       # second shared page splits

    def test_cow_exhaustion_raises(self):
        pool = PagePool(2, 8)
        a = pool.alloc("a", 2)
        pool.alloc("b", 2, shared=a)
        with pytest.raises(PoolExhausted):
            pool.cow("b", 0)

    @given(st.lists(st.tuples(st.integers(0, 4), st.integers(1, 5)),
                    min_size=1, max_size=80))
    @settings(max_examples=50, deadline=None)
    def test_refcount_invariants_under_shared_churn(self, ops):
        """Random alloc/grow/release/share/cow sequences preserve the
        refcounted-pool invariants: every table entry's refcount >= 1, no
        page is both free and referenced, free + distinct live pages
        partition the pool, total references equal total table entries,
        tables never alias a page twice, and a CoW split leaves the
        original page in every *other* table that mapped it."""
        pool = PagePool(24, 8)
        rid = 0
        for op, arg in ops:
            live = list(pool.tables)
            if op == 0:  # alloc a new request
                try:
                    pool.alloc(rid, arg)
                except PoolExhausted:
                    assert pool.free_pages < arg
                rid += 1
            elif op == 1 and live:  # grow the oldest live request
                target = live[0]
                want = len(pool.tables[target]) + arg
                try:
                    pool.grow_to(target, want)
                except PoolExhausted:
                    assert pool.free_pages < arg
            elif op == 2 and live:  # release the oldest live request
                pool.release(live[0])
            elif op == 3 and live:  # share a donor's prefix + fresh tail
                donor = live[arg % len(live)]
                prefix = pool.tables[donor][: max(1, arg)]
                extra = arg % 3
                try:
                    got = pool.alloc(rid, len(prefix) + extra, shared=prefix)
                    assert got[: len(prefix)] == prefix
                except PoolExhausted:
                    assert pool.free_pages < extra
                rid += 1
            elif op == 4 and live:  # copy-on-write split
                target = live[arg % len(live)]
                table = pool.tables[target]
                logical = arg % len(table)
                before = table[logical]
                holders = [r for r, t in pool.tables.items()
                           if r != target and before in t]
                try:
                    split = pool.cow(target, logical)
                except PoolExhausted:
                    assert pool.free_pages == 0
                    split = None
                if split is not None:
                    old, new = split
                    assert old == before and new != old
                    # the original stays mapped by every other holder
                    for r in holders:
                        assert old in pool.tables[r]

            entries = [p for t in pool.tables.values() for p in t]
            refs = [pool.refcount(p) for p in range(pool.num_pages)]
            referenced = {p for p in range(pool.num_pages) if refs[p] > 0}
            free = set(pool._free)
            assert all(pool.refcount(p) >= 1 for p in entries)
            assert not (free & referenced)  # never both free and referenced
            assert len(free) + len(referenced) == pool.num_pages
            assert set(entries) == referenced
            assert sum(refs) == len(entries) == pool.mapped_pages
            for t in pool.tables.values():  # no within-table aliasing
                assert len(t) == len(set(t))


class TestBuildLinearPool:
    def test_pool_packs_prefixes_and_tables_resolve(self):
        ks = [np.arange(l * 2 * 4, dtype=np.float32).reshape(l, 2, 4)
              for l in (5, 12)]
        pk, pv, tables, pool = build_linear_pool(ks, ks, 4, max_len=16)
        assert pool.live_pages == cdiv(5, 4) + cdiv(12, 4)
        for i, l in enumerate((5, 12)):
            got = np.asarray(pk[tables[i]]).reshape(-1, 2, 4)[:l]
            np.testing.assert_array_equal(got, ks[i])


class TestPagedCacheManager:
    def _prefill_cache(self, model, params, pol, toks):
        from repro.nn.module import Ctx

        ctx = Ctx(policies=pol, extra={"cache_max_len": 24})
        _, cache = model(params, {"tokens": toks}, ctx=ctx, mode="prefill")
        return cache

    def _setup(self):
        from repro.models.registry import build_model, reduced_config
        from repro.nn.dtypes import PolicyResolver
        from repro.nn.module import init_params

        pol = PolicyResolver.default("double")
        cfg = reduced_config("yi-6b")
        model = build_model(cfg)
        params = init_params(model, jax.random.PRNGKey(0), pol)
        return model, params, pol

    def test_admit_batch_absorb_retire_roundtrip(self):
        model, params, pol = self._setup()
        manager = PagedCacheManager(num_pages=12, page_size=8)
        for rid, S in enumerate((3, 7)):
            toks = jnp.ones((1, S), jnp.int32)
            cache = self._prefill_cache(model, params, pol, toks)
            assert paged_compatible(cache)
            assert rid == 0 or manager.can_admit(S + 4)
            manager.admit(rid, cache, final_len=S + 4)
        cache = manager.batch([0, 1])
        assert "block_tables" in cache and "kv_pos" in cache
        group = next(v for k, v in cache.items()
                     if isinstance(v, dict) and "pk" in v)
        assert group["index"].shape[-1] == 2
        np.testing.assert_array_equal(np.asarray(group["index"])[..., 0], 3)
        manager.absorb([0, 1], cache)  # identity step: lengths advance
        assert manager._meta[0]["length"] == 4
        manager.retire(0)
        assert manager.pool.free_pages > 0
        cache2 = manager.batch([1])
        assert cache2["block_tables"].shape[0] == 1

    def test_rejects_mixed_cache_families(self):
        """Sliding-window models ring only when prompt_len > window, so a
        batch straddling W would mix ring and linear layouts in one pool —
        the manager must refuse loudly instead of silently mis-packing."""
        from repro.models.registry import build_model, reduced_config
        from repro.nn.dtypes import PolicyResolver
        from repro.nn.module import Ctx, init_params

        pol = PolicyResolver.default("double")
        cfg = reduced_config("mixtral-8x22b")  # attn_window=16 reduced
        model = build_model(cfg)
        params = init_params(model, jax.random.PRNGKey(0), pol)
        ctx = Ctx(policies=pol, extra={"cache_max_len": 24})
        caches = []
        for S in (3, 20):  # linear (S <= W) then ring (S > W)
            _, cache = model(params,
                             {"tokens": jnp.ones((1, S), jnp.int32)},
                             ctx=ctx, mode="prefill")
            caches.append(cache)
        manager = PagedCacheManager(num_pages=16, page_size=8)
        manager.admit(0, caches[0], final_len=8)
        with pytest.raises(ValueError, match="family mismatch"):
            manager.admit(1, caches[1], final_len=23)

    def test_rejects_ssm_state(self):
        from repro.models.registry import build_model, reduced_config
        from repro.nn.dtypes import PolicyResolver
        from repro.nn.module import Ctx, init_params

        pol = PolicyResolver.default("double")
        cfg = reduced_config("rwkv6-3b")
        model = build_model(cfg)
        params = init_params(model, jax.random.PRNGKey(0), pol)
        ctx = Ctx(policies=pol, extra={"cache_max_len": 16})
        _, cache = model(params, {"tokens": jnp.ones((1, 4), jnp.int32)},
                         ctx=ctx, mode="prefill")
        assert not paged_compatible(cache)
        manager = PagedCacheManager(num_pages=4, page_size=8)
        with pytest.raises(ValueError):
            manager.admit(0, cache, final_len=8)


def _server(arch, **cfg_kw):
    from repro.configs.base import SHAPES
    from repro.core.program import Program
    from repro.launch.weave import default_weave
    from repro.runtime.server import Server, ServerConfig

    program = Program.from_arch(arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    return Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4,
                                      **cfg_kw))


PROMPTS = [np.ones((5,), np.int32),
           (np.arange(1, 9) % 50).astype(np.int32),
           np.full((3,), 7, np.int32)]


class TestContinuousServer:
    """serve_continuous == serve_batch == per-request serve — bit-identical
    greedy decode over the paged pool (acceptance criterion), for both the
    linear (yi) and ring (mixtral sliding-window) cache families."""

    @pytest.mark.parametrize("arch", ["yi-6b", "mixtral-8x22b"])
    def test_continuous_equals_batch_and_solo(self, arch):
        srv = _server(arch)
        batched = srv.serve_batch(PROMPTS)
        cont = srv.serve_continuous(PROMPTS, page_size=8)
        for p, b, c in zip(PROMPTS, batched, cont):
            np.testing.assert_array_equal(b, c)
            np.testing.assert_array_equal(c, srv.serve(p[None])[0])

    def test_interleaved_admit_retire_parity(self):
        """A batch cap forces late arrivals to wait for a retirement —
        the continuous path must still match the all-at-once batch."""
        srv = _server("yi-6b")
        batched = srv.serve_batch(PROMPTS)
        for max_batch in (1, 2):
            cont = srv.serve_continuous(PROMPTS, page_size=8,
                                        max_batch=max_batch)
            for b, c in zip(batched, cont):
                np.testing.assert_array_equal(b, c)

    def test_page_constrained_admission_parity(self):
        """A pool that cannot hold every request at once must admit in
        waves (pages freed by retirement re-admit the waiters) and still
        match."""
        srv = _server("yi-6b")
        batched = srv.serve_batch(PROMPTS)
        # worst case per request: ceil((8+3)/8) = 2 pages; 4 pages = 2-wide
        cont = srv.serve_continuous(PROMPTS, page_size=8, pool_pages=4)
        for b, c in zip(batched, cont):
            np.testing.assert_array_equal(b, c)

    def test_pool_too_small_rejects_only_unfittable(self):
        """An unfittable request no longer kills the serve (the old path
        raised RuntimeError mid-serve and threw away every completed
        request's output): it gets a structured rejection and everyone
        else's tokens survive, bit-identical to a roomy serve."""
        srv = _server("yi-6b")
        roomy = srv.serve_continuous(PROMPTS, page_size=8)
        out = srv.serve_continuous(PROMPTS, page_size=8, pool_pages=1)
        st = {o["rid"]: o["status"] for o in srv.last_outcomes}
        # prompt 1 (7 tokens -> final 10 -> 2 pages) can never fit 1 page
        assert st[1] == "rejected" and out[1].size == 0
        assert "page pool too small" in srv.last_outcomes[1]["reason"]
        for r in (0, 2):  # 1-page requests serve sequentially, bit-exact
            assert st[r] == "ok"
            np.testing.assert_array_equal(out[r], roomy[r])

    def test_ssm_family_raises(self):
        srv = _server("rwkv6-3b")
        with pytest.raises(ValueError):
            srv.serve_continuous([np.ones((4,), np.int32)])

    def test_memoized_continuous(self):
        from repro.memo.table import MemoTable

        srv = _server("yi-6b")
        srv.memo = MemoTable(size=8)
        a = srv.serve_continuous(PROMPTS[:2], page_size=8)
        b = srv.serve_continuous(PROMPTS[:2], page_size=8)
        assert srv.memo.hits >= 1
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_decode_step_latencies_recorded_and_refine_smoke(self, tmp_path,
                                                            monkeypatch):
        """Serving records per-step decode latencies and can push them into
        the tuner cache once the paged signature has DSE rows."""
        from repro.autotune.kernel_tuner import KernelTuner, config_vmem_bytes

        monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path / "rt.json"))
        srv = _server("yi-6b")
        srv.serve_continuous(PROMPTS, page_size=8)
        assert srv.decode_step_latencies
        assert srv._paged_sig is not None
        assert srv.refine_kernel_tuner(latency_budget=1.0) is None  # untuned

        tuner = KernelTuner(str(tmp_path / "rt.json"))
        sig = srv._paged_sig
        knobs = {"page_size": 64, "block_kv_dec": 128}
        tuner.cache.put(sig.key(), {
            "knobs": dict(knobs),
            "metrics": {"latency_s": [1e-3, 0.0]},
            "ops": [{"knobs": dict(knobs),
                     "metrics": {
                         "latency_s": [1e-3, 0.0],
                         "vmem_bytes": [
                             float(config_vmem_bytes(sig, knobs)), 0.0]}}],
        })
        got = srv.refine_kernel_tuner(latency_budget=10.0, tuner=tuner)
        assert got == knobs
        entry = tuner.cache.get(sig.key())
        assert "runtime" in entry and "error_coef" in entry["runtime"]

    def test_memo_hit_clears_refine_state(self):
        """Regression: a memo hit used to return before the paged
        signature / latency window were refreshed, so a following
        refine_kernel_tuner read stale state from the previous serve.
        Now the hit clears both and refine declines cleanly."""
        from repro.memo.table import MemoTable

        srv = _server("yi-6b")
        srv.memo = MemoTable(size=8)
        srv.serve_continuous(PROMPTS[:2], page_size=8)
        assert srv._paged_sig is not None
        srv.serve_continuous(PROMPTS[:2], page_size=8)  # memo hit
        assert srv.memo.hits >= 1
        assert srv._paged_sig is None and srv._paged_dtype is None
        assert not srv.decode_step_latencies and not srv._step_lat_by_batch
        assert srv.refine_kernel_tuner(latency_budget=1.0) is None


class TestAdmissionControl:
    def test_first_admission_capacity_checked(self):
        """Regression: the first admission used to bypass can_admit (no
        structure yet), wasting a full prefill and dying with a raw
        PoolExhausted out of pool.alloc.  The capacity check now derives
        slots-per-token before packing, so an oversized *first* request
        hits the clean 'page pool too small' rejection without
        prefilling."""
        srv = _server("yi-6b")
        big = (np.arange(12) % 9 + 1).astype(np.int32)  # final 15 -> 2 pages
        out = srv.serve_continuous([big], page_size=8, pool_pages=1)
        assert out[0].size == 0
        assert srv.last_outcomes[0]["status"] == "rejected"
        assert "page pool too small" in srv.last_outcomes[0]["reason"]
        for vc in (srv.prefill_vc, srv.probe_vc, srv.paged_prefill_vc,
                   srv.rescore_vc):
            assert not vc.dispatch_counts  # nothing was prefilled

    def test_clipped_final_len_interleaves_safely(self):
        """Regression: requests whose final_len is clipped by
        max_cache_len must not grow past their reservation (batch() clamps
        at final_len), so a clipped long request interleaved with waiting
        short ones on a tight pool completes without PoolExhausted and
        matches the batch path."""
        srv = _server("yi-6b")  # max_cache_len=24: S=20, n=8 clips to 24
        long_p = (np.arange(20) % 40 + 1).astype(np.int32)
        pr = [long_p, np.full((4,), 9, np.int32), np.full((4,), 11, np.int32)]
        batched = srv.serve_batch(pr, decode_tokens=8)
        # 3 pages (clipped long) + 2 pages (one short): the second short
        # must wait for a retirement
        cont = srv.serve_continuous(pr, decode_tokens=8, page_size=8,
                                    pool_pages=5)
        for b, c in zip(batched, cont):
            np.testing.assert_array_equal(b, c)


BASE16 = np.arange(1, 17, dtype=np.int32)  # two full pages at page_size=8
SHARED_PROMPTS = [
    np.concatenate([BASE16, np.array([21, 22, 23], np.int32)]),
    np.concatenate([BASE16, np.array([31, 32], np.int32)]),
    np.full((3,), 7, np.int32),  # unrelated short request rides along
]


def _softcap_gqa_server():
    """Dense-family GQA config with grok's logit soft-cap: the softcap
    acceptance axis for prefix sharing (the MoE softcap arch can't share —
    capacity routing makes prefix K/V request-dependent)."""
    from repro.configs.base import SHAPES
    from repro.core.program import Program
    from repro.launch.weave import default_weave
    from repro.models.registry import build_model, reduced_config
    from repro.runtime.server import Server, ServerConfig

    cfg = reduced_config("yi-6b").replace(attn_softcap=30.0)
    program = Program(model=build_model(cfg), cfg=cfg, kind="serve")
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    return Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4))


class TestPrefixSharing:
    """Shared-prefix serving is bit-identical to unshared serving at the
    server level (the acceptance criterion), across the linear GQA family
    (yi), GQA + softcap (dense grok-style cap), and the ring family
    (mixtral, prompts past the window — sharing disabled, direct-to-pool
    ring prefill still exact).  Capacity-routed MoE keeps sharing off
    (prefix K/V are group-coupled, not request-independent) but the paged
    prefill must still match exactly."""

    @pytest.mark.parametrize("arch", ["yi-6b", "softcap-gqa"])
    def test_shared_prefix_bit_identical(self, arch):
        srv = _softcap_gqa_server() if arch == "softcap-gqa" \
            else _server(arch)
        batched = srv.serve_batch(SHARED_PROMPTS)
        shared = srv.serve_continuous(SHARED_PROMPTS, page_size=8)
        unshared = srv.serve_continuous(SHARED_PROMPTS, page_size=8,
                                        prefix_sharing=False)
        for b, s, u in zip(batched, shared, unshared):
            np.testing.assert_array_equal(b, s)
            np.testing.assert_array_equal(s, u)
        # the 16-token prefix is two pages, mapped (not copied) for req 1
        assert srv.last_pool_stats["prefix_hits"] == 0  # unshared run
        srv.serve_continuous(SHARED_PROMPTS, page_size=8)
        stats = srv.last_pool_stats
        assert stats["prefix_hits"] >= 2
        assert stats["peak_live_pages"] < stats["peak_mapped_pages"]

    def test_pallas_weave_keeps_sharing_with_parity(self):
        """Flipped from the PR 5 disable-guard: the widened-q (q_offset)
        flash_decode kernel now serves the suffix-over-prefix prefill, so
        a pallas-woven attention impl keeps prefix sharing ON — shared
        serving stays bit-identical to the batch path and the donor's
        prefix pages are mapped, not copied."""
        from repro.configs.base import SHAPES
        from repro.core.program import Program
        from repro.core.strategies.kernels import KernelAspect
        from repro.launch.weave import default_weave
        from repro.runtime.server import Server, ServerConfig

        program = Program.from_arch("yi-6b", kind="serve", reduced=True)
        woven = default_weave(
            program, SHAPES["prefill_32k"], {},
            extra_aspects=[KernelAspect("*", "attention", "pallas")])
        srv = Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4))
        batched = srv.serve_batch(SHARED_PROMPTS)
        cont = srv.serve_continuous(SHARED_PROMPTS, page_size=8)
        for b, c in zip(batched, cont):
            np.testing.assert_array_equal(b, c)
        stats = srv.last_pool_stats
        assert stats["prefix_hits"] >= 2  # the 16-token prefix: two pages
        assert stats["peak_live_pages"] < stats["peak_mapped_pages"]

    def test_sharer_jumps_queue_behind_blocked_nonsharer(self):
        """Prefix-aware admission: a sharer queued behind a non-sharer
        that cannot fit gets admitted while its donor's pages are still
        live — the shared prefix costs it no fresh pages — and maps the
        donor's prefix pages; outputs still match the batch path."""
        donor = np.concatenate([BASE16, np.array([21, 22, 23], np.int32)])
        blocker = (np.arange(19) % 37 + 60).astype(np.int32)  # no prefix
        sharer = np.concatenate([BASE16, np.array([31, 32], np.int32)])
        pr = [donor, blocker, sharer]
        srv = _server("yi-6b")
        batched = srv.serve_batch(pr)
        # donor needs 3 pages; 5-page pool leaves 2 free: the blocker's 3
        # fresh pages don't fit, the sharer's 1 fresh page (2 shared) does
        cont = srv.serve_continuous(pr, page_size=8, pool_pages=5)
        for b, c in zip(batched, cont):
            np.testing.assert_array_equal(b, c)
        # FIFO would stall until the donor retires and share nothing —
        # the hits are the witness that the sharer jumped the queue while
        # the donor still held its pages
        assert srv.last_pool_stats["prefix_hits"] >= 2

    def test_moe_family_keeps_sharing_off_and_matches(self):
        """grok (MoE + softcap + GQA): the scheduler must not share prefix
        pages — capacity routing couples tokens across the group, so a
        sharer's prefix K/V could differ from the donor's — but the
        direct-to-pool paged prefill still serves bit-identically."""
        srv = _server("grok-1-314b")
        batched = srv.serve_batch(SHARED_PROMPTS)
        cont = srv.serve_continuous(SHARED_PROMPTS, page_size=8)
        for b, c in zip(batched, cont):
            np.testing.assert_array_equal(b, c)
        assert srv.last_pool_stats["prefix_hits"] == 0
        assert srv.last_pool_stats["cow_splits"] == 0

    def test_ring_family_paged_prefill_parity(self):
        """Prompts past the sliding window ring the pool: prefix sharing
        stays off (slot contents depend on the wrap) but the direct-to-
        pool ring prefill must still match the batch path exactly."""
        srv = _server("mixtral-8x22b")  # reduced window 16
        prompts = [(np.arange(20) % 50 + 1).astype(np.int32),
                   (np.arange(18) % 31 + 2).astype(np.int32)]
        batched = srv.serve_batch(prompts)
        cont = srv.serve_continuous(prompts, page_size=8)
        for b, c in zip(batched, cont):
            np.testing.assert_array_equal(b, c)
        assert srv.last_pool_stats["prefix_hits"] == 0

    def test_identical_prompts_rescore_and_cow(self):
        """A full-prompt prefix hit admits with zero prefill (the re-score
        decode step supplies the first logits) and the first decode write
        into the shared tail page splits it copy-on-write — outputs stay
        bit-identical to solo serving."""
        srv = _server("yi-6b")
        p = np.array([3, 1, 4, 1, 5], np.int32)  # S % page_size != 0
        out = srv.serve_continuous([p, p], page_size=8)
        solo = srv.serve(p[None])[0]
        np.testing.assert_array_equal(out[0], solo)
        np.testing.assert_array_equal(out[1], solo)
        stats = srv.last_pool_stats
        assert stats["prefix_hits"] >= 1  # the whole prompt rode one page
        assert stats["cow_splits"] >= 1   # first decode write split it
        assert srv.rescore_vc.dispatch_counts  # no-prefill admission ran

    def test_long_prompt_full_share_falls_back_to_suffix_prefill(self):
        """Prompts past the blocked-attention threshold must not take the
        re-score shortcut (their unshared first token comes from the
        blocked online-softmax path — a different numeric family than the
        decode softmax): the share is trimmed so a suffix prefill runs,
        and parity still holds."""
        from repro.configs.base import SHAPES
        from repro.core.program import Program
        from repro.launch.weave import default_weave
        from repro.runtime.server import Server, ServerConfig

        program = Program.from_arch("yi-6b", kind="serve", reduced=True)
        woven = default_weave(program, SHAPES["prefill_32k"], {})
        woven.state.extra["xla_attn_block"] = 2  # S=5 > 2*block
        srv = Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4))
        p = np.array([3, 1, 4, 1, 5], np.int32)
        out = srv.serve_continuous([p, p], page_size=2)
        solo = srv.serve(p[None])[0]
        np.testing.assert_array_equal(out[0], solo)
        np.testing.assert_array_equal(out[1], solo)
        assert not srv.rescore_vc.dispatch_counts  # gate held
        # the trimmed share still maps the full prefix pages
        assert srv.last_pool_stats["prefix_hits"] >= 2

    def test_aligned_full_share_trim_is_reserved(self):
        """Regression: a page-aligned full-prompt hit that the long-prompt
        gate trims back to a suffix prefill costs one fresh page the share
        would have covered — can_admit must reserve it, so a tight pool
        defers the admission instead of hitting PoolExhausted mid-serve."""
        from repro.configs.base import SHAPES
        from repro.core.program import Program
        from repro.launch.weave import default_weave
        from repro.runtime.server import Server, ServerConfig

        program = Program.from_arch("yi-6b", kind="serve", reduced=True)
        woven = default_weave(program, SHAPES["prefill_32k"], {})
        woven.state.extra["xla_attn_block"] = 2  # S=6 > 2*block
        srv = Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4))
        p = np.array([3, 1, 4, 1, 5, 9], np.int32)  # S % page_size == 0
        # final = 9 -> 5 pages each; 7 pages force the second admission to
        # wait (5 growth + trim page > remaining) rather than overcommit
        out = srv.serve_continuous([p, p], page_size=2, pool_pages=7)
        solo = srv.serve(p[None])[0]
        np.testing.assert_array_equal(out[0], solo)
        np.testing.assert_array_equal(out[1], solo)
        assert not srv.rescore_vc.dispatch_counts  # gate held

    def test_mixed_legacy_and_direct_admissions_compose(self):
        """A batch mixing a legacy admit() of a hand-built cache (no
        hoisted kv_pos) with a direct-to-pool admission must still
        compose: the manager synthesizes the missing map."""
        import jax.numpy as jnp

        from repro.runtime.pages import PagedCacheManager

        srv = _server("yi-6b")
        manager = PagedCacheManager(8, 8, max_len=24, window=None)
        p = np.array([3, 1, 4, 1, 5], np.int32)
        srv._paged_admit(manager, 0, p, 12, None)
        # hand-built dense cache without kv_pos, matching the pool groups
        legacy = {}
        for name, info in manager._groups.items():
            shape = (info["n"], 1, info["length"], info["kv_heads"],
                     info["head_dim"])
            legacy[name] = {
                "k": jnp.zeros(shape, info["dtype"]),
                "v": jnp.zeros(shape, info["dtype"]),
                "index": jnp.full((info["n"],), 4, jnp.int32),
            }
        manager.admit(1, legacy, final_len=8)
        cache = manager.batch([0, 1])
        assert cache["kv_pos"].shape == (2, 24)
        np.testing.assert_array_equal(
            np.asarray(cache["kv_pos"][1]),
            np.where(np.arange(24) < 4, np.arange(24), -1))

    def test_cow_divergence_isolates_requests(self):
        """Two requests that share a whole prompt then *diverge* (forced
        different continuations) must never see each other's tokens: the
        split remaps the writer's table, the donor keeps the original
        page, and each stream's logits match its own solo run exactly."""
        import jax.numpy as jnp

        from repro.runtime.pages import PagedCacheManager

        srv = _server("yi-6b")
        state = srv.woven.variant_state(None)
        state.extra["cache_max_len"] = 24
        p = np.array([3, 1, 4, 1, 5], np.int32)
        manager = PagedCacheManager(8, 8, max_len=24, window=None)
        first = [srv._paged_admit(manager, rid, p, 12, None)
                 for rid in (0, 1)]
        assert first[0] == first[1]
        assert manager.prefix_hits >= 1
        shared_page = manager.pool.tables[0][0]
        assert manager.pool.tables[1][0] == shared_page

        forced = {0: [5, 6], 1: [9, 10]}  # divergent continuations
        paged_logits = {0: [], 1: []}
        for step in range(2):
            cache = manager.batch([0, 1])
            tok = jnp.asarray([[forced[0][step]], [forced[1][step]]],
                              jnp.int32)
            pos = jnp.full((2, 1), 5 + step, jnp.int32)
            logits, new_cache = srv.decode_vc(
                None, srv.params, {"tokens": tok, "positions": pos}, cache)
            manager.absorb([0, 1], new_cache)
            paged_logits[0].append(np.asarray(logits[0]))
            paged_logits[1].append(np.asarray(logits[1]))
        assert manager.cow_splits >= 1
        t0, t1 = manager.pool.tables[0], manager.pool.tables[1]
        assert t0[0] != t1[0]  # the written tail page split
        assert shared_page in (t0[0], t1[0])  # one side kept the original

        # each stream matches a solo dense run of the same forced tokens
        for rid in (0, 1):
            toks = jnp.asarray(p, jnp.int32).reshape(1, -1)
            _, cache = srv.prefill_vc(None, srv.params, {"tokens": toks})
            for step in range(2):
                tok = jnp.asarray([[forced[rid][step]]], jnp.int32)
                pos = jnp.full((1, 1), 5 + step, jnp.int32)
                logits, cache = srv.decode_vc(
                    None, srv.params, {"tokens": tok, "positions": pos},
                    cache)
                np.testing.assert_array_equal(paged_logits[rid][step],
                                              np.asarray(logits[0]))
