"""Speculative decoding end-to-end (the PR 6 serving layer).

Covers:
  - the widened-q flash_decode tile: S draft tokens scored in one kernel
    launch are bit-identical to S sequential single-token decodes, dense
    and paged, windowed and not (token s attends through cache slot
    index + s);
  - the draft/verify serving loop: greedy speculative serve_continuous is
    bit-identical to plain greedy (self-draft, registry cross-model
    draft, knob-driven draft_len), with strictly fewer target steps; ring
    pools and capacity-routed MoE gate speculation off and still match;
  - O(1) page-pool rollback: PagePool.truncate / PagedCacheManager.rollback
    refcount semantics, rollback across a copy-on-write boundary leaving
    donor pages untouched, a no-copy spy over a rejection-heavy
    speculative serve, and allocator invariants under random churn that
    now includes truncation;
  - the `speculative` tuner space (draft_len x block_kv_dec under the
    widened-q VMEM model) and the acceptance-feedback refinement loop
    (Server.refine_speculative -> refine_from_runtime).
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from _hypothesis_compat import given, settings, st

from repro.runtime.pages import (
    PagePool,
    PagedCacheManager,
    PoolExhausted,
    build_linear_pool,
    cdiv,
)


def _server(arch, **cfg_kw):
    from repro.configs.base import SHAPES
    from repro.core.program import Program
    from repro.launch.weave import default_weave
    from repro.runtime.server import Server, ServerConfig

    program = Program.from_arch(arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    return Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4,
                                      **cfg_kw))


def _windowed_server(window=16):
    """Dense-family (non-MoE) sliding-window config: the windowed axis of
    the widened-q mask without mixtral's capacity-routed MoE (which gates
    speculation off for its own reason)."""
    from repro.configs.base import SHAPES
    from repro.core.program import Program
    from repro.launch.weave import default_weave
    from repro.models.registry import build_model, reduced_config
    from repro.runtime.server import Server, ServerConfig

    cfg = reduced_config("yi-6b").replace(attn_window=window)
    program = Program(model=build_model(cfg), cfg=cfg, kind="serve")
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    return Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4))


PROMPTS = [np.ones((5,), np.int32),
           (np.arange(1, 9) % 50).astype(np.int32),
           np.full((3,), 7, np.int32)]


class TestWidenedQKernel:
    """flash_decode with S > 1 q tokens == S sequential S=1 calls, bit for
    bit: each q row runs the same online softmax over the same block walk,
    with its causal boundary at index + row."""

    @pytest.mark.parametrize("window", [None, 7])
    def test_dense_widened_matches_sequential(self, window):
        from repro.kernels.flash_attention.ops import flash_decode

        B, S, T, H, K, D = 2, 3, 24, 4, 2, 16
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, T, K, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, T, K, D)), jnp.float32)
        index = jnp.asarray([5, 9], jnp.int32)
        wide = flash_decode(q, k, v, index, window=window, block_kv=8)
        assert wide.shape == (B, S, H, D)
        for s in range(S):
            one = flash_decode(q[:, s:s + 1], k, v, index + s,
                               window=window, block_kv=8)
            np.testing.assert_array_equal(np.asarray(wide[:, s]),
                                          np.asarray(one[:, 0]))

    def test_paged_widened_matches_sequential(self):
        from repro.kernels.flash_attention.ops import flash_decode

        B, S, H, K, D, ps, T = 2, 3, 4, 2, 16, 8, 24
        rng = np.random.default_rng(1)
        idx = np.array([5, 9], np.int32)  # first new token's position
        ks = [rng.standard_normal((int(i) + S, K, D)).astype(np.float32)
              for i in idx]
        vs = [rng.standard_normal((int(i) + S, K, D)).astype(np.float32)
              for i in idx]
        pk, pv, tables, _ = build_linear_pool(ks, vs, ps, max_len=T)
        q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        wide = flash_decode(q, pk, pv, jnp.asarray(idx), tables=tables,
                            kv_len=T, block_kv=8)
        for s in range(S):
            one = flash_decode(q[:, s:s + 1], pk, pv, jnp.asarray(idx + s),
                               tables=tables, kv_len=T, block_kv=8)
            np.testing.assert_array_equal(np.asarray(wide[:, s]),
                                          np.asarray(one[:, 0]))


class TestSpeculativeServing:
    """Greedy speculative serve_continuous is bit-identical to plain
    greedy — every emitted token is a target argmax; the draft only
    changes how many target steps the output costs."""

    @pytest.mark.parametrize("k", [1, 2, 4])
    def test_self_draft_bit_exact_and_fewer_target_steps(self, k):
        srv = _server("yi-6b")
        plain = srv.serve_continuous(PROMPTS, page_size=8)
        assert srv.last_spec_stats is None  # plain serve leaves no stats
        spec = srv.serve_continuous(PROMPTS, page_size=8, draft_len=k)
        for p, s in zip(plain, spec):
            np.testing.assert_array_equal(p, s)
        stats = srv.last_spec_stats
        assert stats["draft_len"] == k
        assert stats["verify_steps"] >= 1
        # self-drafting: the draft IS the target, every proposal matches
        assert stats["acceptance"] == 1.0
        # n - 1 plain decode steps collapse to ceil((n-1)/(k+1)) verify
        # rounds — k=1 is the ≥1.5x step-reduction acceptance criterion,
        # and the draft_len=1 degenerate case stays bit-exact
        plain_steps = srv.cfg.decode_tokens - 1
        assert stats["target_steps"] == cdiv(plain_steps, k + 1)
        assert stats["target_steps"] < plain_steps

    def test_registry_cross_model_draft_bit_exact(self):
        from repro.models.registry import draft_for

        assert draft_for("yi-6b") == "gemma-2b"
        srv = _server("yi-6b")
        srv.draft = _server(draft_for("yi-6b"))
        plain = srv.serve_continuous(PROMPTS, page_size=8)
        spec = srv.serve_continuous(PROMPTS, page_size=8, draft_len=2)
        for p, s in zip(plain, spec):
            np.testing.assert_array_equal(p, s)
        stats = srv.last_spec_stats
        # a foreign draft mispredicts freely — correctness must not depend
        # on acceptance, only the step count does
        assert 0.0 <= stats["acceptance"] <= 1.0
        assert stats["draft_steps"] == 3 * stats["rounds"]  # k+1 per round
        assert stats["emitted_spec"] + len(PROMPTS) == sum(
            srv.cfg.decode_tokens for _ in PROMPTS)

    def test_knob_driven_draft_len(self):
        """A TunedKernelAspect-woven "speculative_draft_len" extra turns
        speculation on without any explicit argument; an explicit
        draft_len=0 overrides the knob off."""
        srv = _server("yi-6b")
        batched = srv.serve_batch(PROMPTS)
        srv.woven.state.extra["speculative_draft_len"] = 2
        cont = srv.serve_continuous(PROMPTS, page_size=8)
        for b, c in zip(batched, cont):
            np.testing.assert_array_equal(b, c)
        assert srv.last_spec_stats["draft_len"] == 2
        assert srv.last_spec_stats["verify_steps"] >= 1
        srv.serve_continuous(PROMPTS, page_size=8, draft_len=0)
        assert srv.last_spec_stats is None

    def test_windowed_linear_spec_parity(self):
        """Sliding-window arch, prompts inside the window (linear pool):
        the widened per-row window mask must stay bit-exact."""
        srv = _windowed_server()
        plain = srv.serve_continuous(PROMPTS, page_size=8)
        spec = srv.serve_continuous(PROMPTS, page_size=8, draft_len=2)
        for p, s in zip(plain, spec):
            np.testing.assert_array_equal(p, s)
        assert srv.last_spec_stats["verify_steps"] >= 1
        assert srv.last_spec_stats["acceptance"] == 1.0

    def test_ring_pool_gates_speculation_off(self):
        """Prompts past the window ring the pool: eviction-on-write breaks
        the widened verify mask, so the server falls back to plain decode
        rounds — and still matches."""
        srv = _windowed_server()
        prompts = [(np.arange(20) % 50 + 1).astype(np.int32),
                   (np.arange(18) % 31 + 2).astype(np.int32)]
        plain = srv.serve_continuous(prompts, page_size=8)
        spec = srv.serve_continuous(prompts, page_size=8, draft_len=2)
        for p, s in zip(plain, spec):
            np.testing.assert_array_equal(p, s)
        stats = srv.last_spec_stats
        assert stats["verify_steps"] == 0 and stats["decode_steps"] > 0

    def test_moe_capacity_routing_gates_speculation_off(self):
        """Capacity-routed MoE couples tokens within a group: an S-token
        verify router sees different capacity/drop decisions than S
        sequential steps, so speculation stays off entirely (stats are
        cleared) and serving still matches plain."""
        srv = _server("mixtral-8x22b")
        plain = srv.serve_continuous(PROMPTS, page_size=8)
        spec = srv.serve_continuous(PROMPTS, page_size=8, draft_len=2)
        for p, s in zip(plain, spec):
            np.testing.assert_array_equal(p, s)
        assert srv.last_spec_stats is None


class TestRollback:
    def test_pool_truncate_refcount_semantics(self):
        pool = PagePool(8, 8)
        a = pool.alloc("a", 3)
        b = pool.alloc("b", 4, shared=a[:2])
        free_before = pool.free_pages
        freed = pool.truncate("b", 3)  # exclusive tail page frees
        assert freed == [b[3]]
        assert pool.free_pages == free_before + 1
        freed = pool.truncate("b", 1)  # fresh b[2] frees; shared a[1] stays
        assert freed == [b[2]]
        assert pool.refcount(a[1]) == 1 and pool.refcount(a[0]) == 2
        assert pool.tables["b"] == [a[0]]
        assert pool.tables["a"] == a  # donor table untouched throughout
        assert pool.truncate("b", 1) == []  # idempotent at the target
        with pytest.raises(ValueError):
            pool.truncate("b", -1)

    def test_manager_rollback_rewinds_length_pages_and_kv_pos(self):
        srv = _server("yi-6b")
        state = srv.woven.variant_state(None)
        state.extra["cache_max_len"] = 24
        manager = PagedCacheManager(8, 8, max_len=24, window=None)
        p = np.array([3, 1, 4, 1, 5], np.int32)
        srv._paged_admit(manager, 0, p, 12, None)
        # two identity verify rounds: grow + advance past a page boundary
        for _ in range(2):
            cache = manager.batch([0], tokens=3)
            manager.absorb([0], cache, advance=3)
        assert manager._meta[0]["length"] == 11
        assert len(manager.pool.tables[0]) == 2
        # a real verify step would have marked the written slots live in
        # the hoisted kv_pos map; the identity absorb above didn't — set
        # it so the rewind below is observable
        ar = jnp.arange(24, dtype=jnp.int32)
        manager._meta[0]["kv_pos"] = jnp.where(ar < 11, ar, -1)
        freed = manager.rollback(0, 6)
        assert len(freed) == 1  # the grown tail page came back
        assert len(manager.pool.tables[0]) == 1
        assert manager._meta[0]["length"] == 6
        kvp = np.asarray(manager._meta[0]["kv_pos"])
        ar = np.arange(kvp.shape[-1])
        np.testing.assert_array_equal(kvp, np.where(ar < 6, ar, -1))
        with pytest.raises(ValueError):
            manager.rollback(0, 7)  # beyond the live length
        with pytest.raises(ValueError):
            manager.rollback(0, -1)

    def test_rollback_across_cow_boundary_leaves_donor_pages(self):
        """A verify round that CoW-split a shared page and grew a fresh
        tail, then fully rejected: rollback returns the fresh page, keeps
        the private copy (it holds valid prefix slots), and the donor's
        table, refcounts and bytes are untouched."""
        srv = _server("yi-6b")
        state = srv.woven.variant_state(None)
        state.extra["cache_max_len"] = 24
        manager = PagedCacheManager(8, 2, max_len=24, window=None)
        p = np.array([3, 1, 4, 1, 5], np.int32)
        for rid in (0, 1):  # full-prompt prefix hit: rid 1 maps rid 0's pages
            srv._paged_admit(manager, rid, p, 12, None)
        donor_table = list(manager.pool.tables[0])
        assert manager.pool.tables[1] == donor_table  # all three shared
        donor_bytes = {
            name: np.asarray(pools["pk"])[..., donor_table[2], :, :, :].copy()
            for name, pools in manager._pools.items()
        }
        cache = manager.batch([1], tokens=3)  # writes slots 5..7
        assert manager.cow_splits >= 1        # shared straddling page split
        split_page = manager.pool.tables[1][2]
        assert split_page != donor_table[2]
        manager.absorb([1], cache, advance=3)
        freed = manager.rollback(1, 5)        # full rejection
        assert len(freed) == 1                # only the grown tail page
        assert manager.pool.tables[1] == donor_table[:2] + [split_page]
        # donor untouched: same table, back to exclusive, same bytes
        assert manager.pool.tables[0] == donor_table
        assert manager.pool.refcount(donor_table[2]) == 1
        for name, pools in manager._pools.items():
            np.testing.assert_array_equal(
                np.asarray(pools["pk"])[..., donor_table[2], :, :, :],
                donor_bytes[name])
        pool = manager.pool
        refs = [pool.refcount(q) for q in range(pool.num_pages)]
        entries = [q for t in pool.tables.values() for q in t]
        assert sum(refs) == len(entries) == pool.mapped_pages

    def test_speculative_rollback_performs_no_page_copies(self, monkeypatch):
        """The no-copy criterion, spy-asserted: a rejection-heavy
        cross-model speculative serve (every round rolls back) never runs
        the device page copy inside rollback — truncation is pure
        refcount bookkeeping."""
        import repro.runtime.pages as pages_mod

        copies = {"n": 0}
        real_copy = pages_mod._copy_pool_page

        def spy(pool, src, dst):
            copies["n"] += 1
            return real_copy(pool, src, dst)

        monkeypatch.setattr(pages_mod, "_copy_pool_page", spy)
        in_rollback = {"n": 0}
        real_rollback = pages_mod.PagedCacheManager.rollback

        def wrapped(self, rid, new_length):
            before = copies["n"]
            out = real_rollback(self, rid, new_length)
            in_rollback["n"] += copies["n"] - before
            return out

        monkeypatch.setattr(pages_mod.PagedCacheManager, "rollback", wrapped)
        srv = _server("yi-6b")
        srv.draft = _server("gemma-2b")
        plain = srv.serve_continuous(PROMPTS, page_size=8)
        spec = srv.serve_continuous(PROMPTS, page_size=8, draft_len=2)
        for p, s in zip(plain, spec):
            np.testing.assert_array_equal(p, s)
        assert srv.last_spec_stats["verify_steps"] >= 1
        assert in_rollback["n"] == 0

    @given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 5)),
                    min_size=1, max_size=80))
    @settings(max_examples=50, deadline=None)
    def test_invariants_under_truncate_churn(self, ops):
        """Random alloc/grow/release/share/cow/truncate sequences preserve
        the refcounted-pool invariants — truncation (the speculative
        rollback primitive) composes with sharing and CoW: freed pages are
        exactly the dropped entries whose refcount hit zero, and a shared
        page dropped by one holder stays live for the others."""
        pool = PagePool(24, 8)
        rid = 0
        for op, arg in ops:
            live = list(pool.tables)
            if op == 0:
                try:
                    pool.alloc(rid, arg)
                except PoolExhausted:
                    assert pool.free_pages < arg
                rid += 1
            elif op == 1 and live:
                target = live[0]
                want = len(pool.tables[target]) + arg
                try:
                    pool.grow_to(target, want)
                except PoolExhausted:
                    assert pool.free_pages < arg
            elif op == 2 and live:
                pool.release(live[0])
            elif op == 3 and live:
                donor = live[arg % len(live)]
                prefix = pool.tables[donor][: max(1, arg)]
                extra = arg % 3
                try:
                    got = pool.alloc(rid, len(prefix) + extra, shared=prefix)
                    assert got[: len(prefix)] == prefix
                except PoolExhausted:
                    assert pool.free_pages < extra
                rid += 1
            elif op == 4 and live:
                target = live[arg % len(live)]
                if pool.tables[target]:  # truncate-to-zero leaves empties
                    logical = arg % len(pool.tables[target])
                    try:
                        pool.cow(target, logical)
                    except PoolExhausted:
                        assert pool.free_pages == 0
            elif op == 5 and live:  # speculative rollback
                target = live[arg % len(live)]
                table = pool.tables[target]
                keep = max(0, len(table) - arg)
                dropped = table[keep:]
                holders_elsewhere = {
                    q for q in dropped
                    if pool.refcount(q) > dropped.count(q)
                }
                freed = pool.truncate(target, keep)
                assert set(freed) <= set(dropped)
                # pages other requests still map are never freed
                assert not (set(freed) & holders_elsewhere)
                assert len(pool.tables[target]) == keep

            entries = [q for t in pool.tables.values() for q in t]
            refs = [pool.refcount(q) for q in range(pool.num_pages)]
            referenced = {q for q in range(pool.num_pages) if refs[q] > 0}
            free = set(pool._free)
            assert all(pool.refcount(q) >= 1 for q in entries)
            assert not (free & referenced)
            assert len(free) + len(referenced) == pool.num_pages
            assert set(entries) == referenced
            assert sum(refs) == len(entries) == pool.mapped_pages
            for t in pool.tables.values():
                assert len(t) == len(set(t))


class TestSpeculativeTuning:
    def test_space_and_vmem_model(self):
        from repro.autotune.kernel_tuner import (
            config_vmem_bytes,
            design_space,
            speculative_signature,
        )

        sig = speculative_signature(2, 128, 4, 2, 16, "float32")
        space = design_space(sig)
        assert space["draft_len"] == [1, 2, 4, 8]
        assert space["block_kv_dec"] == [128]
        v1 = config_vmem_bytes(sig, {"draft_len": 1, "block_kv_dec": 128})
        v8 = config_vmem_bytes(sig, {"draft_len": 8, "block_kv_dec": 128})
        assert v8 > v1 > 0  # the widened q tile costs VMEM

    def test_tune_records_acceptance_prior_and_lookup(self, tmp_path,
                                                      monkeypatch):
        monkeypatch.setenv("REPRO_TUNER_CACHE", str(tmp_path / "kt.json"))
        from repro.autotune.kernel_tuner import (
            KernelTuner,
            speculative_signature,
            tuned_speculative_knobs,
        )

        tuner = KernelTuner(str(tmp_path / "kt.json"))
        sig = speculative_signature(1, 64, 4, 2, 16, "float32")
        knobs = tuner.tune(sig, num_tests=1)
        assert set(knobs) == {"draft_len", "block_kv_dec"}
        entry = tuner.cache.get(sig.key())
        # the acceptance-1 prior: draft_len + 1 useful tokens per step
        for row in entry["ops"]:
            assert row["metrics"]["tokens_per_step"][0] == \
                row["knobs"]["draft_len"] + 1
        assert tuned_speculative_knobs(1, 64, 4, 2, 16, "float32") == knobs

    def test_refine_speculative_feeds_acceptance_back(self, tmp_path):
        """Served acceptance rescales the cached tokens_per_step priors
        (error coefficient = observed mean tokens per verify / prior) and
        the draft_len knob is re-selected under the adjusted budget."""
        from repro.autotune.kernel_tuner import (
            KernelTuner,
            config_vmem_bytes,
            speculative_signature,
        )

        srv = _server("yi-6b")
        assert srv.refine_speculative(latency_budget=1.0) is None  # no spec
        srv.serve_continuous(PROMPTS, page_size=8, draft_len=2,
                             decode_tokens=8)
        stats = srv.last_spec_stats
        assert stats["verify_steps"] >= 2  # latency observations recorded

        cfg = srv.woven.program.cfg
        batch = max(1, round(stats["request_rounds"]
                             / max(stats["rounds"], 1)))
        sig = speculative_signature(
            batch, srv.cfg.max_cache_len, cfg.n_heads, cfg.kv_heads,
            cfg.resolved_head_dim, srv._paged_dtype, window=cfg.attn_window)
        tuner = KernelTuner(str(tmp_path / "spec.json"))
        ops = []
        for dl in (1, 2, 4):
            knobs = {"draft_len": dl, "block_kv_dec": 128}
            ops.append({"knobs": dict(knobs), "metrics": {
                "latency_s": [1e-3, 0.0],
                "tokens_per_step": [float(dl + 1), 0.0],
                "vmem_bytes": [float(config_vmem_bytes(sig, knobs)), 0.0],
            }})
        tuner.cache.put(sig.key(), {
            "knobs": {"draft_len": 2, "block_kv_dec": 128},
            "metrics": {"latency_s": [1e-3, 0.0],
                        "tokens_per_step": [3.0, 0.0]},
            "ops": ops,
        })
        got = srv.refine_speculative(latency_budget=10.0, tuner=tuner)
        assert got is not None and got["draft_len"] == 4  # maximized
        entry = tuner.cache.get(sig.key())
        coef = entry["runtime"]["error_coef"]["tokens_per_step"]
        assert coef == pytest.approx(stats["mean_tokens_per_verify"] / 3.0)
