"""Multi-replica serving fleet: prefix-affinity routing, replica-loss
re-dispatch, graceful drain — plus the HeartbeatMonitor clock-domain and
malformed-topic fixes and `serve_continuous`'s preemption drain these
fleet semantics ride on.
"""

import numpy as np
import pytest

from repro.core.strategies.resilience import (
    ALL_JOIN_POINTS,
    FLEET_JOIN_POINTS,
    JOIN_POINTS,
    FaultInjector,
    FaultSpec,
    FleetResilienceAspect,
)
from repro.distributed.fault import HeartbeatMonitor, PreemptionHandler
from repro.monitor.examon import ExamonBroker
from repro.runtime.fleet import ServingFleet, _PollPreemption


def _server(arch="yi-6b", *, extra_aspects=None, **cfg_kw):
    from repro.configs.base import SHAPES
    from repro.core.program import Program
    from repro.launch.weave import default_weave
    from repro.runtime.server import Server, ServerConfig

    program = Program.from_arch(arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {},
                          extra_aspects=extra_aspects or [])
    cfg_kw.setdefault("max_cache_len", 24)
    cfg_kw.setdefault("decode_tokens", 4)
    return Server(woven, ServerConfig(**cfg_kw))


def _fleet_prompts(n=8, shared=8, tail=3, seed=0):
    """A shared-system-prompt workload: every prompt opens with the same
    `shared` tokens (page-aligned at page_size=8), distinct tails."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(1, 90, shared)
    return [np.concatenate([sys_prompt, rng.integers(1, 90, tail)])
            .astype(np.int64) for _ in range(n)]


@pytest.fixture(scope="module")
def woven():
    from repro.configs.base import SHAPES
    from repro.core.program import Program
    from repro.launch.weave import default_weave

    program = Program.from_arch("yi-6b", kind="serve", reduced=True)
    return default_weave(program, SHAPES["prefill_32k"], {})


@pytest.fixture(scope="module")
def factory(woven):
    from repro.runtime.server import Server, ServerConfig

    return lambda: Server(woven, ServerConfig(
        max_cache_len=24, decode_tokens=4, max_batch=2, page_size=8))


@pytest.fixture(scope="module")
def baseline(factory):
    """Single-server fault-free serve of the shared workload — the
    bit-parity reference every fleet scenario is held to."""
    prompts = _fleet_prompts()
    return prompts, factory().serve_continuous(prompts, decode_tokens=4)


def _parity(outs, base):
    return all(np.array_equal(a, b) for a, b in zip(outs, base))


# ---------------------------------------------------------------------------
# HeartbeatMonitor: clock domains, malformed beats, liveness (satellites)
# ---------------------------------------------------------------------------


class TestHeartbeatMonitor:
    def test_liveness_declared_on_monitor_clock(self):
        """Beats are arrival-stamped with the monitor's own clock, so a
        publisher stamping its beats in a *different* clock domain (epoch
        seconds here, vs the monitor's logical counter) cannot corrupt
        liveness."""
        broker = ExamonBroker()
        tick = {"now": 0.0}
        dead = []
        mon = HeartbeatMonitor(broker, dead_after_s=2.0,
                               clock=lambda: tick["now"],
                               on_dead=dead.append)
        broker.publish("fleet/heartbeat/@host0", 0.01,
                       timestamp=1.7e9)  # epoch-domain publisher ts
        broker.publish("fleet/heartbeat/@host1", 0.01, timestamp=-5.0)
        tick["now"] = 1.0
        broker.publish("fleet/heartbeat/@host1", 0.01, timestamp=0.0)
        tick["now"] = 3.0
        mon.check_liveness()
        assert dead == [0]          # host1 beat at 1.0: 2.0 elapsed, alive
        assert mon.dead == {0}
        tick["now"] = 4.0
        mon.check_liveness()
        assert set(dead) == {0, 1}  # host1 now 3.0 silent

    def test_liveness_default_clock_is_monotonic_both_sides(self):
        """With no custom clock, publish-side default and check side are
        both time.monotonic — a fresh beat is never declared dead."""
        broker = ExamonBroker()
        dead = []
        mon = HeartbeatMonitor(broker, dead_after_s=30.0,
                               on_dead=dead.append)
        broker.publish("fleet/heartbeat/@host0", 0.01)
        mon.check_liveness()
        assert dead == [] and not mon.dead

    def test_dead_host_revives_on_new_beat(self):
        broker = ExamonBroker()
        tick = {"now": 0.0}
        mon = HeartbeatMonitor(broker, dead_after_s=1.0,
                               clock=lambda: tick["now"])
        broker.publish("fleet/heartbeat/@host3", 0.01)
        tick["now"] = 5.0
        mon.check_liveness()
        assert mon.dead == {3}
        broker.publish("fleet/heartbeat/@host3", 0.01)  # spare took the slot
        assert mon.dead == set()
        mon.check_liveness()
        assert mon.dead == set()

    def test_malformed_topics_dropped_and_counted(self):
        broker = ExamonBroker()
        mon = HeartbeatMonitor(broker)
        # none of these may raise inside the broker callback
        broker.publish("fleet/heartbeat/oops", 0.01)
        broker.publish("fleet/heartbeat/@hostX", 0.01)
        broker.publish("fleet/heartbeat/@host", 0.01)
        broker.publish("fleet/heartbeat/@host7", 0.01)  # well-formed
        assert mon.malformed_beats == 3
        assert 7 in mon._last_seen

    def test_forget_clears_all_host_state(self):
        broker = ExamonBroker()
        tick = {"now": 0.0}
        mon = HeartbeatMonitor(broker, dead_after_s=1.0,
                               clock=lambda: tick["now"])
        broker.publish("fleet/heartbeat/@host2", 0.01)
        tick["now"] = 5.0
        mon.check_liveness()
        assert mon.dead == {2}
        mon.forget(2)
        assert 2 not in mon._last_seen and mon.dead == set()
        mon.check_liveness()   # no stale entry to re-declare
        assert mon.dead == set()


# ---------------------------------------------------------------------------
# serve_continuous graceful drain (PreemptionHandler satellite)
# ---------------------------------------------------------------------------


class TestServeDrain:
    def test_pending_from_start_drains_everything(self):
        srv = _server()
        pre = PreemptionHandler(install=False)
        pre.request()  # SIGTERM before the wave starts
        prompts = _fleet_prompts(3)
        outs = srv.serve_continuous(prompts, preemption=pre)
        assert all(len(o) == 0 for o in outs)
        assert {o["status"] for o in srv.last_outcomes} == {"drained"}
        assert srv.last_fault_stats["drained"] == 3

    def test_midwave_sigterm_finishes_inflight_drains_waiting(self):
        """SIGTERM during an active wave: the admitted cohort finishes
        its full decode (bit-identical to an unpreempted serve), nothing
        new is admitted, the rest returns structured drained outcomes."""
        prompts = _fleet_prompts(5)
        clean_srv = _server(max_batch=2, page_size=8)
        base = clean_srv.serve_continuous(prompts, decode_tokens=4)

        class _SigtermAfterFirstPoll(PreemptionHandler):
            def __init__(self):
                super().__init__(install=False)
                self.polls = 0

            @property
            def pending(self):
                self.polls += 1
                if self.polls > 1:
                    self.request()
                return super().pending

        srv = _server(max_batch=2, page_size=8)
        pre = _SigtermAfterFirstPoll()
        outs = srv.serve_continuous(prompts, decode_tokens=4,
                                    preemption=pre)
        statuses = {o["rid"]: o["status"] for o in srv.last_outcomes}
        finished = [r for r, s in statuses.items() if s == "ok"]
        drained = [r for r, s in statuses.items() if s == "drained"]
        assert len(finished) == 2           # the admitted cohort
        assert len(drained) == 3            # nothing new admitted
        for r in finished:
            assert np.array_equal(outs[r], base[r])
        for r in drained:
            assert len(outs[r]) == 0
        assert srv.last_fault_stats["drained"] == 3

    def test_no_preemption_keeps_bit_parity_and_memo(self):
        prompts = _fleet_prompts(3)
        a = _server().serve_continuous(prompts, decode_tokens=4)
        b = _server().serve_continuous(prompts, decode_tokens=4,
                                       preemption=None)
        assert _parity(a, b)


# ---------------------------------------------------------------------------
# Fleet join points + aspect
# ---------------------------------------------------------------------------


class TestFleetWeave:
    def test_join_point_split(self):
        # the 8-point serving sweep matrix is untouched; fleet points are
        # validation-visible but separate
        assert set(FLEET_JOIN_POINTS) == {"route", "replica_loss", "drain"}
        assert not set(FLEET_JOIN_POINTS) & set(JOIN_POINTS)
        assert set(ALL_JOIN_POINTS) == set(JOIN_POINTS) | set(FLEET_JOIN_POINTS)

    def test_fleet_specs_validate_and_fire(self):
        inj = FaultInjector([FaultSpec("replica_loss", "raise", at=1)])
        assert inj.fire("replica_loss", rid=0) is None
        with pytest.raises(Exception):
            inj.fire("replica_loss", rid=1)
        with pytest.raises(ValueError):
            FaultSpec("not_a_point", "raise")

    def test_aspect_weaves_policy_and_injector(self):
        inj = FaultInjector()
        srv = _server(extra_aspects=[FleetResilienceAspect(
            inj, retries=5, wave_size=2, affinity=False)])
        extra = srv.woven.state.extra
        assert extra["fleet_injector"] is inj
        assert extra["fleet_resilience"]["retries"] == 5
        assert extra["fleet_resilience"]["wave_size"] == 2
        assert extra["fleet_resilience"]["affinity"] is False

    def test_fleet_resolves_woven_policy(self, woven):
        from repro.core.program import Program
        from repro.configs.base import SHAPES
        from repro.launch.weave import default_weave
        from repro.runtime.server import Server, ServerConfig

        inj = FaultInjector()
        program = Program.from_arch("yi-6b", kind="serve", reduced=True)
        w = default_weave(program, SHAPES["prefill_32k"], {},
                          extra_aspects=[FleetResilienceAspect(
                              inj, retries=7, wave_size=2)])
        fleet = ServingFleet(
            lambda: Server(w, ServerConfig(max_cache_len=24,
                                           decode_tokens=4)),
            replicas=1)
        assert fleet.policy["retries"] == 7
        assert fleet.policy["wave_size"] == 2
        assert fleet.injector is inj
        # explicit constructor args still win
        fleet2 = ServingFleet(
            lambda: Server(w, ServerConfig(max_cache_len=24,
                                           decode_tokens=4)),
            replicas=1, retries=1)
        assert fleet2.policy["retries"] == 1


# ---------------------------------------------------------------------------
# ServingFleet end-to-end scenarios
# ---------------------------------------------------------------------------


class TestServingFleet:
    def test_clean_fleet_parity_and_affinity(self, factory, baseline):
        prompts, base = baseline
        fleet = ServingFleet(factory, replicas=2, wave_size=3)
        outs = fleet.serve(prompts, decode_tokens=4)
        st = fleet.last_fleet_stats
        assert st["outcomes"] == {"ok": len(prompts)}
        assert _parity(outs, base)
        # injection off: zero fleet events, routing-only overhead
        assert st["events"] == [] and st["injected_events"] == []
        # shared-system-prompt workload warms the prefix index on >= 2
        # replicas (wave_size spill) and affinity routing actually fires
        assert len(st["replicas_with_prefix_hits"]) >= 2
        assert st["affinity_hits"] > 0

    def test_kill_midwave_recovers_with_parity(self, factory, baseline):
        prompts, base = baseline
        inj = FaultInjector.single("replica_loss", "raise", at=1)
        fleet = ServingFleet(factory, replicas=2, spares=1, wave_size=3,
                             injector=inj)
        outs = fleet.serve(prompts, decode_tokens=4)
        st = fleet.last_fleet_stats
        assert st["outcomes"] == {"ok": len(prompts)}   # 100% recovery
        assert _parity(outs, base)                       # bit-parity
        kinds = [e["kind"] for e in st["events"]]
        assert "replica_loss" in kinds and "declared_dead" in kinds
        assert "spare_in" in kinds and st["spares_left"] == 0
        assert st["redispatched"] >= 1
        # the kill wave's completed requests were kept, not replayed
        loss = next(e for e in st["events"] if e["kind"] == "replica_loss")
        assert loss["kept"] >= 1
        red = [o for o in fleet.last_outcomes if o["attempts"] > 0]
        assert red and all(np.array_equal(outs[o["rid"]], base[o["rid"]])
                           for o in red)

    def test_drain_midwave_hands_queue_to_peers(self, factory, baseline):
        prompts, base = baseline
        fleet = ServingFleet(factory, replicas=2, spares=1, wave_size=4)
        fleet.request_drain(0)
        outs = fleet.serve(prompts, decode_tokens=4)
        st = fleet.last_fleet_stats
        assert st["outcomes"] == {"ok": len(prompts)}
        assert _parity(outs, base)
        drain = next(e for e in st["events"] if e["kind"] == "drain")
        assert drain["host"] == 0
        assert drain["finished"] >= 1       # in-flight cohort completed
        assert drain["handoff"] >= 1        # waiting queue went to peers
        assert not any(r.host == 0 and r.alive for r in fleet.replicas)
        assert "spare_in" in [e["kind"] for e in st["events"]]

    def test_injected_drain_join_point(self, factory, baseline):
        prompts, base = baseline
        inj = FaultInjector.single("drain", "raise", at=0)
        fleet = ServingFleet(factory, replicas=2, wave_size=4,
                             injector=inj)
        outs = fleet.serve(prompts, decode_tokens=4)
        st = fleet.last_fleet_stats
        assert st["outcomes"] == {"ok": len(prompts)}
        assert _parity(outs, base)
        assert any(e["kind"] == "drain" for e in st["events"])
        assert any(e["point"] == "drain" for e in st["injected_events"])

    def test_route_fault_degrades_to_least_loaded(self, factory, baseline):
        prompts, base = baseline
        inj = FaultInjector([FaultSpec("route", "raise", at=0, repeat=3)])
        fleet = ServingFleet(factory, replicas=2, wave_size=3,
                             injector=inj)
        outs = fleet.serve(prompts, decode_tokens=4)
        st = fleet.last_fleet_stats
        # a routing fault never loses the request
        assert st["outcomes"] == {"ok": len(prompts)}
        assert _parity(outs, base)
        assert sum(1 for e in st["injected_events"]
                   if e["point"] == "route") == 3

    def test_fleet_deadline_retires_with_partial(self, factory):
        prompts = _fleet_prompts()
        inj = FaultInjector.single("replica_loss", "raise", at=1)
        fleet = ServingFleet(factory, replicas=2, wave_size=3,
                             injector=inj, deadline_s=0.0)
        outs = fleet.serve(prompts, decode_tokens=4)
        st = fleet.last_fleet_stats
        assert st["outcomes"].get("deadline_exceeded", 0) >= 1
        assert st["outcomes"].get("ok", 0) >= 1   # completed work kept
        overdue = [o for o in fleet.last_outcomes
                   if o["status"] == "deadline_exceeded"]
        # partial output rides out with the structured outcome
        assert all(o["tokens"] == len(outs[o["rid"]]) for o in overdue)

    def test_retry_budget_exhaustion_fails_structurally(self, factory):
        # every dispatch kills the serving replica; one replica, no
        # spares: the victim request exhausts its re-dispatch budget and
        # fails *structurally*, the fleet never raises
        inj = FaultInjector([FaultSpec("replica_loss", "raise",
                                       at=0, repeat=64)])
        fleet = ServingFleet(factory, replicas=1, wave_size=2,
                             injector=inj, retries=1, kill_step=0)
        prompts = _fleet_prompts(2)
        fleet.serve(prompts, decode_tokens=4)
        st = fleet.last_fleet_stats
        assert st["outcomes"].get("failed", 0) >= 1

    def test_affinity_off_still_serves_with_parity(self, factory, baseline):
        prompts, base = baseline
        fleet = ServingFleet(factory, replicas=2, wave_size=3,
                             affinity=False)
        outs = fleet.serve(prompts, decode_tokens=4)
        assert fleet.last_fleet_stats["affinity_hits"] == 0
        assert _parity(outs, base)

    def test_poll_preemption_semantics(self):
        pre = _PollPreemption(after=1)
        assert pre.pending is False
        assert pre.pending is True and pre.pending is True
