"""QoS-adaptive streaming serving (the PR 10 layer).

Covers:
  - `serve_stream` event-loop parity: token outputs bit-identical to
    `serve_continuous` (which wraps it) and to `serve_batch`, events
    reconstruct the outputs exactly, chunked prefill and logical-clock
    arrivals preserve parity;
  - chunked-prefill no-starvation (hypothesis property, seeded fallback):
    interleaved admissions never stall in-flight decodes — every wave
    with a live batch emits, and per-request token waves stay contiguous;
  - `QoSGovernor` units: knob grids, load-dependent OP selection (the
    proactive feature KBs), wave observation / energy ledger, power-cap
    reconfiguration, woven `QoSAspect` resolution, governed-serve parity
    and OP switching under a load ramp;
  - `Margot.observe` sliding window (bounded history, non-finite guard,
    live window resize) — the long-session memory-leak regression;
  - `PowerCapper.snapshot`/`set_cap` under concurrent `report` storms.
"""

import sys
import threading

import numpy as np
import pytest

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.autotune.margot import Margot, KnowledgeBase, State
from repro.power.capper import PowerCapper
from repro.power.rapl import RAPLModel
from repro.runtime.qos import DEFAULT_QOS_POLICY, QoSGovernor


def _server(arch="yi-6b", *, extra_aspects=None, **cfg_kw):
    from repro.configs.base import SHAPES
    from repro.core.program import Program
    from repro.launch.weave import default_weave
    from repro.runtime.server import Server, ServerConfig

    program = Program.from_arch(arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {},
                          extra_aspects=extra_aspects or [])
    cfg_kw.setdefault("max_cache_len", 40)
    cfg_kw.setdefault("decode_tokens", 4)
    return Server(woven, ServerConfig(**cfg_kw))


_RNG = np.random.default_rng(7)
PROMPTS = [_RNG.integers(1, 50, (21,)).astype(np.int32),
           _RNG.integers(1, 50, (5,)).astype(np.int32),
           _RNG.integers(1, 50, (17,)).astype(np.int32)]


def _drain(gen, events=None):
    while True:
        try:
            ev = next(gen)
        except StopIteration as stop:
            return stop.value
        if events is not None:
            events.append(ev)


# ---------------------------------------------------------------------------
# serve_stream: event-loop parity + event-stream structure
# ---------------------------------------------------------------------------


class TestServeStream:
    def test_stream_equals_continuous_and_batch(self):
        srv = _server()
        batched = srv.serve_batch(PROMPTS)
        cont = srv.serve_continuous(PROMPTS, page_size=8)
        events = []
        streamed = _drain(srv.serve_stream(PROMPTS, page_size=8), events)
        for b, c, s in zip(batched, cont, streamed):
            np.testing.assert_array_equal(b, c)
            np.testing.assert_array_equal(c, s)
        # the token events alone reconstruct every output, in order
        toks: dict[int, list] = {}
        for ev in events:
            if ev["event"] == "token":
                assert ev["index"] == len(toks.setdefault(ev["rid"], []))
                toks[ev["rid"]].append(ev["token"])
        for r, out in enumerate(streamed):
            assert toks[r] == list(out)

    def test_outcome_rows_carry_latency_columns(self):
        srv = _server()
        srv.serve_continuous(PROMPTS, page_size=8, max_batch=2)
        for o in srv.last_outcomes:
            assert o["status"] == "ok"
            assert o["ttft_s"] is not None and o["ttft_s"] >= 0
            assert o["ttft_waves"] is not None and o["ttft_waves"] >= 0
            assert o["tok_gap_max_s"] is not None

    def test_chunked_prefill_parity_and_interleave(self):
        srv = _server()
        base = srv.serve_continuous(PROMPTS, page_size=4)
        events = []
        chunked = srv.serve_continuous(PROMPTS, page_size=4,
                                       prefill_chunk=8,
                                       on_event=events.append)
        for b, c in zip(base, chunked):
            np.testing.assert_array_equal(b, c)
        kinds = [e["event"] for e in events]
        assert "prefill_chunk" in kinds  # the chunked path actually ran
        # resident length grows monotonically per request, page-aligned
        res: dict[int, int] = {}
        for ev in events:
            if ev["event"] == "prefill_chunk":
                assert ev["resident"] > res.get(ev["rid"], 0)
                assert ev["resident"] % 4 == 0
                res[ev["rid"]] = ev["resident"]

    def test_arrival_waves_parity(self):
        srv = _server()
        base = srv.serve_continuous(PROMPTS, page_size=4)
        arr = srv.serve_continuous(PROMPTS, page_size=4,
                                   arrival_waves=[0, 3, 6])
        for b, c in zip(base, arr):
            np.testing.assert_array_equal(b, c)

    def test_arrival_waves_length_mismatch_raises(self):
        srv = _server()
        with pytest.raises(ValueError):
            _drain(srv.serve_stream(PROMPTS, page_size=4,
                                    arrival_waves=[0, 1]))

    def test_empty_prompts(self):
        srv = _server()
        assert _drain(srv.serve_stream([])) == []
        assert srv.serve_continuous([]) == []

    def test_speculative_stream_parity(self):
        srv = _server()
        base = srv.serve_continuous(PROMPTS, page_size=8)
        spec = _drain(srv.serve_stream(PROMPTS, page_size=8, draft_len=2))
        for b, s in zip(base, spec):
            np.testing.assert_array_equal(b, s)
        assert srv.last_spec_stats["verify_steps"] > 0


# ---------------------------------------------------------------------------
# chunked-prefill no-starvation + churn parity (property)
# ---------------------------------------------------------------------------


_CHURN_SRV = {}


def _churn_server():
    if "srv" not in _CHURN_SRV:
        _CHURN_SRV["srv"] = _server()
    return _CHURN_SRV["srv"]


def _assert_chunk_no_starvation(seed, chunk, max_batch, stagger):
    """Random admit/retire churn with chunked prefill interleaved: (1)
    outputs bit-identical to the one-shot serve, (2) no wave with a live
    decode batch emits zero tokens, (3) each request's token stream never
    skips more than one wave while it is active (admissions stream beside
    decodes, they never park them)."""
    srv = _churn_server()
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, 50, (int(rng.integers(3, 25)),))
               .astype(np.int32) for _ in range(4)]
    arrival = [int(rng.integers(0, 4)) if stagger else 0
               for _ in range(len(prompts))]
    base = srv.serve_continuous(prompts, page_size=4)
    events = []
    out = _drain(srv.serve_stream(
        prompts, page_size=4, prefill_chunk=chunk, max_batch=max_batch,
        arrival_waves=arrival), events)
    for b, c in zip(base, out):
        np.testing.assert_array_equal(b, c)
    tok_waves: dict[int, list] = {}
    for ev in events:
        if ev["event"] == "wave" and ev["batch"] > 0:
            assert ev["emitted"] >= 1, \
                f"wave {ev['wave']} had a live batch but emitted nothing"
        if ev["event"] == "token":
            tok_waves.setdefault(ev["rid"], []).append(ev["wave"])
    for r, waves in tok_waves.items():
        gaps = np.diff(waves)
        assert (gaps <= 2).all(), \
            f"request {r} starved: token wave gaps {gaps}"


if HAS_HYPOTHESIS:
    @given(seed=st.integers(0, 10_000),
           chunk=st.sampled_from([4, 8, 12]),
           max_batch=st.integers(2, 4),
           stagger=st.booleans())
    @settings(max_examples=8, deadline=None)
    def test_chunked_churn_property(seed, chunk, max_batch, stagger):
        _assert_chunk_no_starvation(seed, chunk, max_batch, stagger)
else:  # seeded fallback: a fixed sample of the same space
    @pytest.mark.parametrize("case", range(4))
    def test_chunked_churn_property(case):
        rng = np.random.default_rng(555 + case)
        _assert_chunk_no_starvation(int(rng.integers(10_000)),
                                    int(rng.choice([4, 8, 12])),
                                    int(rng.integers(2, 5)),
                                    bool(rng.integers(2)))


# ---------------------------------------------------------------------------
# QoSGovernor units
# ---------------------------------------------------------------------------


class TestQoSGovernor:
    def test_knob_values_filters_ungoverned(self):
        gov = QoSGovernor({"max_batch": (1, 4), "draft_len": None})
        assert gov.knob_values("max_batch") == (1, 4)
        assert gov.knob_values("draft_len") == ()
        assert gov.knob_values("prefill_chunk") == \
            tuple(DEFAULT_QOS_POLICY["prefill_chunk"])

    def test_decide_reselects_with_load(self):
        gov = QoSGovernor({"slo_tok_s": 0.05})
        low = gov.decide(wave=0, waiting=0, active=1)
        high = gov.decide(wave=4, waiting=30, active=8)
        assert low["max_batch"] in DEFAULT_QOS_POLICY["max_batch"]
        assert high["max_batch"] >= low["max_batch"]
        assert high["max_batch"] == max(DEFAULT_QOS_POLICY["max_batch"])
        assert gov.stats()["distinct_ops"] >= 2
        assert gov.margot.switches >= 2  # initial pick counts as one

    def test_observe_wave_energy_and_capper(self):
        gov = QoSGovernor({"power_cap_w": 150.0, "freq": (0.5, 1.0)})
        gov.decide(wave=0, waiting=0, active=2)
        for w in range(8):
            gov.observe_wave(0.01, batch=2, emitted=2, wave=w)
        s = gov.stats()
        assert s["tokens"] == 16 and s["waves"] == 8
        assert s["energy_j"] > 0
        assert s["tokens_per_joule"] == pytest.approx(16 / s["energy_j"])
        assert s["power"] is not None and len(s["power"]) == 1
        # non-finite / negative observations are dropped, not accounted
        gov.observe_wave(float("nan"), batch=2, emitted=99)
        gov.observe_wave(-1.0, batch=2, emitted=99)
        assert gov.stats()["tokens"] == 16

    def test_set_power_cap_moves_goal_and_capper(self):
        gov = QoSGovernor({"power_cap_w": 500.0})
        gov.set_power_cap(120.0)
        assert gov.capper.cap_watts == 120.0
        for state in gov.margot.states.values():
            caps = [g for g in state.constraints if g.name == "power_cap"]
            assert len(caps) == 1 and caps[0].value == 120.0

    def test_capper_frequency_clamps_planned_freq(self):
        capper = PowerCapper(10.0, model=RAPLModel())  # tiny budget
        gov = QoSGovernor({"freq": (1.0,)}, capper=capper)
        gov.decide(wave=0, waiting=0, active=1)
        # hammer reports over budget: the capper throttles the task
        for w in range(30):
            gov.observe_wave(0.01, batch=1, emitted=1, wave=w)
        knobs = gov.decide(wave=30, waiting=0, active=1)
        assert knobs["freq"] < 1.0  # the node budget won over the plan

    def test_governed_serve_parity_and_switches(self):
        srv = _server()
        base = srv.serve_continuous(PROMPTS, page_size=4)
        out = srv.serve_continuous(
            PROMPTS, page_size=4, qos={"reselect_every": 1},
            slo_ttft_s=0.5, slo_tok_s=0.05,
            arrival_waves=[0, 2, 4])
        for b, c in zip(base, out):
            np.testing.assert_array_equal(b, c)
        q = srv.last_qos_stats
        assert q is not None and q["waves"] > 0
        assert q["switches"] >= 1 and q["op_history"]
        assert q["energy_j"] > 0

    def test_qos_false_forces_off_and_stats_none(self):
        srv = _server()
        srv.serve_continuous(PROMPTS, page_size=8, qos=False)
        assert srv.last_qos_stats is None

    def test_woven_qos_aspect_resolves(self):
        from repro.core.strategies.qos import QoSAspect

        srv = _server(extra_aspects=[
            QoSAspect({"reselect_every": 2}, slo_tok_s=0.05)])
        base = _server().serve_continuous(PROMPTS, page_size=4)
        out = srv.serve_continuous(PROMPTS, page_size=4)
        for b, c in zip(base, out):
            np.testing.assert_array_equal(b, c)
        assert srv.last_qos_stats is not None
        assert srv.last_qos_stats["waves"] > 0


# ---------------------------------------------------------------------------
# Margot.observe sliding window (regression: unbounded history)
# ---------------------------------------------------------------------------


class TestMargotWindow:
    def _margot(self, window=32):
        return Margot(KnowledgeBase([]), [State("s", "m")], window=window)

    def test_history_is_bounded(self):
        m = self._margot(window=32)
        for i in range(1000):
            m.observe("latency", float(i))
        assert len(m._obs["latency"]) == 32
        assert list(m._obs["latency"])[0] == 968.0  # recent tail kept

    def test_non_finite_dropped(self):
        m = self._margot()
        m.observe("latency", 1.0)
        m.observe("latency", float("nan"))
        m.observe("latency", float("inf"))
        assert list(m._obs["latency"]) == [1.0]

    def test_live_window_resize_keeps_recent_tail(self):
        m = self._margot(window=8)
        for i in range(8):
            m.observe("latency", float(i))
        m.window = 4
        m.observe("latency", 8.0)
        assert list(m._obs["latency"]) == [5.0, 6.0, 7.0, 8.0]


# ---------------------------------------------------------------------------
# PowerCapper: snapshot / set_cap vs concurrent reports
# ---------------------------------------------------------------------------


class TestCapperConcurrency:
    def test_snapshot_consistent_under_report_storm(self):
        capper = PowerCapper(100.0, model=RAPLModel())
        tids = [capper.register(f"t{i}", priority=i) for i in range(4)]
        stop = threading.Event()
        errors: list[BaseException] = []

        def hammer(tid, seed):
            rng = np.random.default_rng(seed)
            try:
                while not stop.is_set():
                    capper.report(tid, float(rng.uniform(10.0, 80.0)))
            except BaseException as e:  # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=hammer, args=(t, i), daemon=True)
                   for i, t in enumerate(tids)]
        for t in threads:
            t.start()
        model = capper.model
        try:
            for i in range(300):
                snap = capper.snapshot()
                assert len(snap) == 4  # never a half-registered table
                for row in snap:
                    # never a half-applied throttle order
                    assert model.f_min <= row["freq"] <= model.f_max
                if i % 50 == 25:
                    capper.set_cap(60.0 if i % 100 == 25 else 140.0)
        finally:
            stop.set()
            for t in threads:
                t.join(timeout=5)
        assert not errors

    def test_set_cap_rebalances_immediately(self):
        capper = PowerCapper(1000.0, model=RAPLModel(), step=0.5)
        lo = capper.register("lo", priority=0)
        hi = capper.register("hi", priority=9)
        capper.report(lo, 100.0)
        capper.report(hi, 100.0)
        assert capper.frequency(lo) == capper.model.f_max
        capper.set_cap(50.0)  # over budget now: lowest priority throttles
        assert capper.frequency(lo) < capper.model.f_max
        assert capper.frequency(hi) == capper.model.f_max
