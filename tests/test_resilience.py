"""Fault-tolerant continuous serving: the woven resilience layer.

Covers the tentpole acceptance sweep — every serving join point x fault
kind, injected one at a time, must never escape `serve_continuous` as a
raw exception, survivors must stay bit-identical to the fault-free serve,
and victims must get structured outcomes — plus the FaultInjector's
determinism, the PoolAuditor's corruption detection, the single-thread
Watchdog rewrite, and the fault-churn property test (hypothesis with the
seeded fallback of `_hypothesis_compat`).
"""

import threading
import time

import numpy as np
import pytest

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st
from repro.core.strategies.resilience import (
    DEFAULT_POLICY,
    FAULT_KINDS,
    JOIN_POINTS,
    FaultInjector,
    FaultSpec,
    InjectedFault,
    ResilienceAspect,
)
from repro.distributed.fault import Watchdog
from repro.runtime.pages import (
    PagedCacheManager,
    PagePool,
    PoolAuditor,
    PoolExhausted,
    PoolInvariantError,
    audit_pool,
)


def _server(arch="yi-6b", *, extra_aspects=None, **cfg_kw):
    from repro.configs.base import SHAPES
    from repro.core.program import Program
    from repro.launch.weave import default_weave
    from repro.runtime.server import Server, ServerConfig

    program = Program.from_arch(arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {},
                          extra_aspects=extra_aspects or [])
    return Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4,
                                      **cfg_kw))


PROMPTS = [np.ones((5,), np.int32),
           (np.arange(7) % 13 + 1).astype(np.int32),
           (np.arange(4) % 11 + 2).astype(np.int32)]


def _statuses(srv):
    return {o["rid"]: o["status"] for o in srv.last_outcomes}


# ---------------------------------------------------------------------------
# FaultInjector: determinism + schedule semantics
# ---------------------------------------------------------------------------


class TestFaultInjector:
    def test_scheduled_fires_on_exact_visit(self):
        inj = FaultInjector([FaultSpec("decode_step", "raise", at=2)])
        assert inj.fire("decode_step") is None
        assert inj.fire("decode_step") is None
        with pytest.raises(InjectedFault):
            inj.fire("decode_step")
        assert inj.fire("decode_step") is None  # one-shot: consumed
        assert not inj.armed

    def test_returned_kinds_resolve_victim(self):
        inj = FaultInjector([FaultSpec("verify_step", "nan_logits")])
        spec = inj.fire("verify_step", rids=[7, 8])
        assert spec.kind == "nan_logits" and spec.rid == 7
        inj = FaultInjector([FaultSpec("admit", "deadline", rid=9)])
        spec = inj.fire("admit", rid=3)
        assert spec.rid == 9  # pinned victim wins over the call-site rid

    def test_pool_exhausted_kind_raises_pool_error(self):
        inj = FaultInjector.single("cow", "pool_exhausted")
        with pytest.raises(PoolExhausted):
            inj.fire("cow")

    def test_seeded_random_stream_is_deterministic(self):
        a = FaultInjector(seed=7, rate=0.5, kinds=("nan_logits",))
        b = FaultInjector(seed=7, rate=0.5, kinds=("nan_logits",))
        seq_a = [a.fire("decode_step") is not None for _ in range(32)]
        seq_b = [b.fire("decode_step") is not None for _ in range(32)]
        assert seq_a == seq_b and any(seq_a) and not all(seq_a)
        a.reset()
        assert [a.fire("decode_step") is not None
                for _ in range(32)] == seq_a

    def test_events_and_stats(self):
        inj = FaultInjector([FaultSpec("retire", "deadline", at=1)])
        inj.fire("retire", rid=0)
        inj.fire("retire", rid=1)
        s = inj.stats()
        assert s["fired"] == 1 and s["by_point"] == {"retire": 1}
        assert inj.events[0]["rid"] == 1

    def test_validation(self):
        with pytest.raises(ValueError):
            FaultSpec("nope", "raise")
        with pytest.raises(ValueError):
            FaultSpec("admit", "nope")
        with pytest.raises(ValueError):
            FaultInjector(rate=0.1, kinds=("bogus",))


# ---------------------------------------------------------------------------
# Watchdog: single reused timer thread
# ---------------------------------------------------------------------------


class TestWatchdog:
    def test_single_thread_across_beats(self):
        fired = []
        wd = Watchdog(10.0, lambda: fired.append(1))
        before = threading.active_count()
        for _ in range(50):
            wd.beat()
        assert threading.active_count() <= before + 1  # one reused thread
        wd.cancel()
        wd.close()
        assert not fired and wd.timeouts == 0

    def test_fires_after_deadline_and_rearms(self):
        fired = []
        wd = Watchdog(0.05, lambda: fired.append(1))
        wd.beat()
        time.sleep(0.15)
        assert wd.timeouts == 1 and fired == [1]
        wd.beat()          # re-arm on the same thread
        time.sleep(0.15)
        assert wd.timeouts == 2
        wd.close()

    def test_cancel_before_deadline_never_counts(self):
        wd = Watchdog(0.08, lambda: None)
        for _ in range(5):
            wd.beat()
            wd.cancel()
        time.sleep(0.2)
        assert wd.timeouts == 0
        wd.close()

    def test_close_is_idempotent_and_rejects_beat(self):
        wd = Watchdog(1.0, lambda: None)
        wd.beat()
        wd.close()
        wd.close()
        with pytest.raises(RuntimeError):
            wd.beat()


# ---------------------------------------------------------------------------
# PoolAuditor: invariants hold on real flows, corruption is caught
# ---------------------------------------------------------------------------


class TestPoolAuditor:
    def test_clean_pool_and_manager_pass(self):
        pool = PagePool(8, 4)
        pool.alloc("a", 3)
        pool.alloc("b", 2, shared=pool.tables["a"][:2])
        summary = audit_pool(pool)
        assert summary["requests"] == 2 and summary["live_pages"] == 3

    def test_refcount_corruption_detected(self):
        pool = PagePool(8, 4)
        pool.alloc("a", 2)
        pool._refs[pool.tables["a"][0]] += 1  # phantom reference
        with pytest.raises(PoolInvariantError, match="refcount"):
            audit_pool(pool)

    def test_double_free_detected(self):
        pool = PagePool(8, 4)
        pool.alloc("a", 2)
        pool._free.append(pool.tables["a"][0])  # freed while referenced
        with pytest.raises(PoolInvariantError, match="free and referenced"):
            audit_pool(pool)

    def test_leak_detected(self):
        pool = PagePool(8, 4)
        pool.alloc("a", 2)
        page = pool.tables["a"].pop()  # entry lost, refcount stays
        pool._refs[page] = 0           # ...then the refcount is zeroed too
        with pytest.raises(PoolInvariantError, match="leak|conservation"):
            audit_pool(pool)

    def test_manager_meta_mismatch_detected(self):
        mgr = PagedCacheManager(4, 8, max_len=24)
        mgr.pool.alloc("ghost", 1)  # table with no admission meta
        with pytest.raises(PoolInvariantError):
            PoolAuditor(mgr).audit()

    def test_abort_is_idempotent_and_restores_free_pages(self):
        mgr = PagedCacheManager(4, 8, max_len=24)
        mgr.pool.alloc("r", 2)
        mgr._meta["r"] = {"length": 8, "final_len": 16}
        mgr.abort("r")
        mgr.abort("r")  # second abort is a no-op
        assert len(mgr.pool._free) == 4 and not mgr.pool.tables
        audit_pool(mgr)


# ---------------------------------------------------------------------------
# Serving fault sweep: the acceptance-criteria matrix
# ---------------------------------------------------------------------------


class TestServingFaultSweep:
    @pytest.fixture(scope="class")
    def swept(self):
        """One server + its fault-free baseline, shared across the sweep
        (compilation dominates; the pools are rebuilt per serve)."""
        srv = _server(retries=2, pool_audit=True)
        srv.draft = _server("gemma-2b")
        baseline = srv.serve_continuous(PROMPTS, page_size=8, draft_len=2)
        return srv, baseline

    @pytest.mark.parametrize("point", JOIN_POINTS)
    @pytest.mark.parametrize("kind", FAULT_KINDS)
    def test_single_fault_never_escapes_and_survivors_match(
            self, swept, point, kind):
        srv, baseline = swept
        inj = FaultInjector.single(point, kind, at=1)
        out = srv.serve_continuous(PROMPTS, page_size=8, draft_len=2,
                                   fault_injector=inj)
        fs = srv.last_fault_stats
        statuses = _statuses(srv)
        # recovery: the serve completed; any non-ok request carries a
        # structured outcome, and survivors are bit-identical
        assert set(statuses) == {0, 1, 2}
        for o in srv.last_outcomes:
            assert o["status"] in ("ok", "rejected", "quarantined",
                                   "deadline_exceeded", "failed",
                                   "oversized")
        for r, s in statuses.items():
            if s == "ok":
                np.testing.assert_array_equal(out[r], baseline[r])
            else:
                # victims keep a (possibly empty) prefix of the baseline
                np.testing.assert_array_equal(
                    out[r], baseline[r][:out[r].size])
        if fs["events"]:  # the scheduled fault fired
            assert fs["events"] == 1
            assert fs["injected_events"][0]["point"] == point
        # the PoolAuditor ran at every post-fault barrier and passed
        assert fs["audits"] >= 1

    def test_sweep_covers_all_points(self, swept):
        """Spec serving + plain serving together visit every join point,
        so `at=1` exists for each (admit/paged_prefill/retire fire once
        per request, steps once per round; decode_step only fires on
        plain rounds, which speculation replaces entirely)."""
        srv, _ = swept
        inj = FaultInjector()  # unarmed: pure visit counter
        srv.serve_continuous(PROMPTS, page_size=8, draft_len=2,
                             fault_injector=inj)
        draft, srv.draft = srv.draft, None
        try:
            srv.serve_continuous(PROMPTS, page_size=8, fault_injector=inj)
        finally:
            srv.draft = draft
        assert all(inj.visits[p] >= 2 for p in JOIN_POINTS), inj.visits


# ---------------------------------------------------------------------------
# Recovery policies
# ---------------------------------------------------------------------------


class TestRecoveryPolicies:
    def test_injection_off_is_bit_identical_with_zero_events(self):
        srv = _server()
        baseline = srv.serve_continuous(PROMPTS, page_size=8)
        fs = srv.last_fault_stats
        assert fs["events"] == 0 and not fs["actions"]
        assert fs["outcomes"] == {"ok": 3}
        again = srv.serve_continuous(PROMPTS, page_size=8,
                                     fault_injector=FaultInjector())
        for a, b in zip(baseline, again):
            np.testing.assert_array_equal(a, b)
        assert srv.last_fault_stats["events"] == 0

    def test_transient_raise_is_retried_to_full_output(self):
        srv = _server()
        baseline = srv.serve_continuous(PROMPTS, page_size=8)
        inj = FaultInjector.single("decode_step", "raise", at=1)
        out = srv.serve_continuous(PROMPTS, page_size=8, fault_injector=inj)
        for a, b in zip(baseline, out):
            np.testing.assert_array_equal(a, b)
        fs = srv.last_fault_stats
        assert fs["retries"] == 1 and fs["outcomes"] == {"ok": 3}

    def test_retry_budget_exhaustion_fails_structurally(self):
        srv = _server(retries=1)
        inj = FaultInjector([FaultSpec("decode_step", "raise", at=1,
                                       repeat=10)])
        out = srv.serve_continuous(PROMPTS, page_size=8, fault_injector=inj)
        fs = srv.last_fault_stats
        assert fs["failed"] == 3 and all(o.size >= 1 for o in out)
        assert all(s == "failed" for s in _statuses(srv).values())
        # the pools were drained, not leaked
        assert srv.last_pool_stats["live_pages"] == 0

    def test_nan_quarantines_only_victim(self):
        srv = _server(pool_audit=True)
        baseline = srv.serve_continuous(PROMPTS, page_size=8)
        inj = FaultInjector.single("decode_step", "nan_logits", at=1)
        out = srv.serve_continuous(PROMPTS, page_size=8, fault_injector=inj)
        statuses = _statuses(srv)
        victims = [r for r, s in statuses.items() if s == "quarantined"]
        assert len(victims) == 1
        for r in statuses:
            if r in victims:
                np.testing.assert_array_equal(
                    out[r], baseline[r][:out[r].size])
            else:
                np.testing.assert_array_equal(out[r], baseline[r])

    def test_injected_deadline_retires_with_partial_output(self):
        srv = _server()
        baseline = srv.serve_continuous(PROMPTS, page_size=8)
        inj = FaultInjector.single("decode_step", "deadline", at=1, rid=1)
        out = srv.serve_continuous(PROMPTS, page_size=8, fault_injector=inj)
        assert _statuses(srv)[1] == "deadline_exceeded"
        assert 0 < out[1].size < baseline[1].size
        np.testing.assert_array_equal(out[1], baseline[1][:out[1].size])
        for r in (0, 2):
            np.testing.assert_array_equal(out[r], baseline[r])

    def test_wall_clock_deadline_marks_overdue(self):
        srv = _server()
        out = srv.serve_continuous(PROMPTS, page_size=8, deadline_s=0.0)
        # a 0-second SLO: every request is overdue after its first round
        assert all(s == "deadline_exceeded"
                   for s in _statuses(srv).values())
        assert all(o.size >= 1 for o in out)  # partial output survives

    def test_draft_fault_degrades_to_plain_decode(self):
        srv = _server()
        srv.draft = _server("gemma-2b")
        baseline = srv.serve_continuous(PROMPTS, page_size=8)
        inj = FaultInjector.single("draft_step", "raise", at=0, )
        out = srv.serve_continuous(PROMPTS, page_size=8, draft_len=2,
                                   fault_injector=inj)
        for a, b in zip(baseline, out):
            np.testing.assert_array_equal(a, b)
        fs = srv.last_fault_stats
        assert fs["degraded"] and fs["outcomes"] == {"ok": 3}
        assert srv.last_spec_stats["decode_steps"] > 0  # plain rounds ran

    def test_repeated_mismatch_degrades_under_patience_policy(self):
        srv = _server("yi-6b")
        srv.draft = _server("gemma-2b")
        baseline = srv.serve_continuous(PROMPTS, page_size=8,
                                        decode_tokens=8)
        srv.woven.state.extra["serve_resilience"] = dict(
            DEFAULT_POLICY, spec_patience=1)
        out = srv.serve_continuous(PROMPTS, page_size=8, draft_len=2,
                                   decode_tokens=8)
        for a, b in zip(baseline, out):
            np.testing.assert_array_equal(a, b)
        # a foreign draft that all-rejects a round trips patience=1 and
        # the serve finishes on plain rounds; parity held either way
        if srv.last_fault_stats["degraded"]:
            assert srv.last_spec_stats["decode_steps"] > 0

    def test_woven_resilience_aspect_carries_policy_and_injector(self):
        inj = FaultInjector.single("decode_step", "nan_logits", at=1)
        srv = _server(extra_aspects=[
            ResilienceAspect(inj, retries=5, pool_audit=True)])
        srv.serve_continuous(PROMPTS, page_size=8)
        fs = srv.last_fault_stats
        assert fs["events"] == 1 and fs["quarantined"] == 1
        assert fs["audits"] >= 1  # the woven pool_audit knob was honored

    def test_examon_fault_topics_published(self):
        from repro.monitor.examon import ExamonBroker

        broker = ExamonBroker()
        seen = []
        broker.subscribe("serve/fault/*", lambda t, v, ts: seen.append(t))
        srv = _server()
        srv.broker = broker
        inj = FaultInjector.single("decode_step", "raise", at=1)
        srv.serve_continuous(PROMPTS, page_size=8, fault_injector=inj)
        assert any(t.startswith("serve/fault/decode_step/raise")
                   for t in seen)

    def test_armed_injector_bypasses_memo(self):
        from repro.memo.table import MemoTable

        srv = _server()
        srv.memo = MemoTable(size=8)
        a = srv.serve_continuous(PROMPTS[:2], page_size=8)
        inj = FaultInjector.single("decode_step", "raise", at=1)
        b = srv.serve_continuous(PROMPTS[:2], page_size=8,
                                 fault_injector=inj)
        # the armed serve really ran (memo hit would clear fault stats)
        assert srv.last_fault_stats is not None
        assert srv.last_fault_stats["events"] == 1
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_oversized_prompt_rejected_up_front(self):
        srv = _server()
        big = (np.arange(30) % 9 + 1).astype(np.int32)  # > max_cache_len=24
        out = srv.serve_continuous([big] + PROMPTS[:1], page_size=8)
        assert _statuses(srv)[0] == "oversized" and out[0].size == 0
        assert _statuses(srv)[1] == "ok"

    def test_draft_admission_fault_keeps_target_request(self):
        """Regression (satellite): a draft-pool admission throw used to
        strand the target's pages and `active`/`outputs` entries; now it
        degrades speculation and the request serves plain, with no page
        leak."""
        srv = _server()
        srv.draft = _server("gemma-2b")
        baseline = srv.serve_continuous(PROMPTS, page_size=8)
        # draft admits in lockstep right after its target: visit 0 is
        # request 0's target admission, visit 1 its draft admission
        inj = FaultInjector.single("paged_prefill", "raise", at=1)
        out = srv.serve_continuous(PROMPTS, page_size=8, draft_len=2,
                                   fault_injector=inj, pool_audit=True)
        fs = srv.last_fault_stats
        assert fs["degraded"], fs
        assert _statuses(srv) == {0: "ok", 1: "ok", 2: "ok"}
        for a, b in zip(baseline, out):
            np.testing.assert_array_equal(a, b)
        assert srv.last_pool_stats["live_pages"] == 0


# ---------------------------------------------------------------------------
# Property test: one random fault, invariants always hold
# ---------------------------------------------------------------------------


_SRV_CACHE = {}


def _churn_server():
    if "srv" not in _SRV_CACHE:
        srv = _server(pool_audit=True)
        srv.draft = _server("gemma-2b")
        _SRV_CACHE["srv"] = srv
        _SRV_CACHE["plain"] = srv.serve_continuous(PROMPTS, page_size=8)
        _SRV_CACHE["spec"] = srv.serve_continuous(PROMPTS, page_size=8,
                                                  draft_len=2)
    return _SRV_CACHE["srv"], _SRV_CACHE["plain"], _SRV_CACHE["spec"]


def _assert_fault_churn(point_i: int, kind_i: int, at: int, spec_on: bool):
    """One fault at a random join point/visit: pool conservation + no
    double-free (PoolAuditor barriers are armed), survivor bit-parity,
    and clean structured outcomes for any victim."""
    srv, plain, specb = _churn_server()
    baseline = specb if spec_on else plain
    inj = FaultInjector.single(JOIN_POINTS[point_i], FAULT_KINDS[kind_i],
                               at=at)
    out = srv.serve_continuous(PROMPTS, page_size=8,
                               draft_len=2 if spec_on else 0,
                               fault_injector=inj)
    statuses = _statuses(srv)
    for r, s in statuses.items():
        if s == "ok":
            np.testing.assert_array_equal(out[r], baseline[r])
        else:
            assert s in ("rejected", "quarantined", "deadline_exceeded",
                         "failed", "oversized")
            np.testing.assert_array_equal(out[r],
                                          baseline[r][:out[r].size])
    # every page came home: conservation + no double-free held at every
    # barrier (pool_audit raised otherwise), and the drained pool is empty
    assert srv.last_pool_stats["live_pages"] == 0
    assert srv.last_fault_stats["audits"] >= 1


if HAS_HYPOTHESIS:
    @given(point_i=st.integers(0, len(JOIN_POINTS) - 1),
           kind_i=st.integers(0, len(FAULT_KINDS) - 1),
           at=st.integers(0, 6),
           spec_on=st.booleans())
    @settings(max_examples=25, deadline=None)
    def test_fault_churn_property(point_i, kind_i, at, spec_on):
        _assert_fault_churn(point_i, kind_i, at, spec_on)
else:  # seeded fallback: a fixed sample of the same space
    @pytest.mark.parametrize("case", range(12))
    def test_fault_churn_property(case):
        rng = np.random.default_rng(1234 + case)
        _assert_fault_churn(int(rng.integers(len(JOIN_POINTS))),
                            int(rng.integers(len(FAULT_KINDS))),
                            int(rng.integers(7)),
                            bool(rng.integers(2)))
