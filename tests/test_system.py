"""End-to-end behaviour tests for the woven system (deliverable c):
train loss decreases, checkpoint/restart resumes exactly, serving with
memoization + mARGOt adaptation, elastic resharding, multi-device lowering
(subprocess), weaving metrics stability."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.core.strategies.memoization import MemoizeStep
from repro.core.strategies.monitoring import ExamonMonitor
from repro.core.strategies.precision import CreateLowPrecVersion
from repro.core.strategies.versioning import Multiversion
from repro.core.weaver import weave
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.launch.weave import default_weave
from repro.monitor.examon import ExamonBroker, ExamonCollector
from repro.runtime.server import Server, ServerConfig
from repro.runtime.trainer import Trainer, TrainerConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _trainer(tmp_path=None, steps=30, arch="yi-6b", margot=None, broker=None):
    program = Program.from_arch(arch, kind="train", reduced=True)
    woven = default_weave(program, SHAPES["train_4k"], {},
                          overrides={"accum_steps": 1},
                          extra_aspects=[ExamonMonitor("train", broker=broker)])
    pipeline = TokenPipeline(PipelineConfig(
        vocab=program.cfg.vocab, seq_len=32, global_batch=8, noise=0.02))
    cfg = TrainerConfig(steps=steps, log_every=0,
                        ckpt_dir=str(tmp_path) if tmp_path else None,
                        ckpt_every=10)
    return Trainer(woven, pipeline, cfg, margot=margot, broker=broker)


class TestTraining:
    def test_loss_decreases(self):
        trainer = _trainer(steps=40)
        history = trainer.run()
        first = np.mean([h["loss"] for h in history[:5]])
        last = np.mean([h["loss"] for h in history[-5:]])
        assert last < first - 0.2, (first, last)

    def test_checkpoint_restart_exact_resume(self, tmp_path):
        t1 = _trainer(tmp_path, steps=20)
        t1.run()
        t1.save(blocking=True)
        # fresh trainer restores and continues identically to a straight run
        t2 = _trainer(tmp_path, steps=0)
        assert t2.maybe_restore()
        assert t2.step == 20
        assert t2.pipeline.step == t1.pipeline.step
        h2 = t2.run(10)
        t3 = _trainer(steps=30)
        h3 = t3.run()
        assert h2[-1]["loss"] == pytest.approx(h3[-1]["loss"], rel=0.02)

    def test_preemption_checkpoints_and_stops(self, tmp_path):
        t = _trainer(tmp_path, steps=1000)
        t.preemption.request()
        t.run()
        assert t.step <= 1
        assert t.watchdog_timeouts == 0


class TestServing:
    def _server(self, memo=True, margot=None):
        program = Program.from_arch("yi-6b", kind="serve", reduced=True)
        aspects = []
        if memo:
            aspects.append(MemoizeStep(tsize=64))
        woven = default_weave(program, SHAPES["prefill_32k"], {},
                              extra_aspects=aspects)
        return Server(woven, ServerConfig(max_cache_len=24, decode_tokens=4),
                      margot=margot)

    def test_serve_greedy_and_memo(self):
        server = self._server(memo=True)
        prompt = np.ones((2, 8), np.int32)
        out1 = server.serve(prompt)
        out2 = server.serve(prompt)
        assert out1.shape == (2, 4)
        np.testing.assert_array_equal(out1, out2)
        assert server.memo.hits >= 1

    def test_decode_is_deterministic_across_instances(self):
        a = self._server(memo=False).serve(np.ones((1, 8), np.int32))
        b = self._server(memo=False).serve(np.ones((1, 8), np.int32))
        np.testing.assert_array_equal(a, b)


class TestVariantSwitching:
    def test_libvc_variant_switch_in_trainer(self):
        program = Program.from_arch("yi-6b", kind="train", reduced=True)
        woven = default_weave(
            program, SHAPES["train_4k"], {}, overrides={"accum_steps": 1},
            extra_aspects=[CreateLowPrecVersion("*", "half", "_f"),
                           Multiversion("version")],
        )
        pipeline = TokenPipeline(PipelineConfig(
            vocab=program.cfg.vocab, seq_len=16, global_batch=4))
        trainer = Trainer(woven, pipeline, TrainerConfig(steps=2, log_every=0))
        trainer.init_state()
        batch = jax.tree.map(jnp.asarray, next(pipeline))
        step = jnp.zeros((), jnp.int32)
        p1, o1, m1 = trainer.libvc(None, trainer.params, trainer.opt_state,
                                   batch, step)
        p2, o2, m2 = trainer.libvc("f", p1, o1, batch, step)
        assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
        assert set(trainer.libvc.versions) == {"__default__", "f"}


class TestElastic:
    def test_reshard_across_device_counts(self, tmp_path):
        """Save on 1 device; restore onto a 4-device mesh in a subprocess."""
        script = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, {SRC!r})
            import jax, jax.numpy as jnp, numpy as np
            from repro.checkpoint.checkpointer import Checkpointer
            from repro.core.program import Program
            from repro.distributed.elastic import plan_rescale, reshard_params
            from repro.launch.mesh import make_test_mesh
            from repro.nn.module import init_params

            program = Program.from_arch("yi-6b", reduced=True)
            params = init_params(program.model, jax.random.PRNGKey(0))
            ckpt = Checkpointer({str(tmp_path)!r}, async_save=False)
            ckpt.save(5, params)
            mesh = make_test_mesh((2, 2), ("data", "model"))
            rules = {{"batch": ("data",), "heads": "model", "mlp": "model",
                     "vocab": "model", "embed": None, "kv_heads": "model"}}
            info = plan_rescale(8, mesh, rules)
            assert info["dp"] == 2, info
            placed, manifest = reshard_params(program.model, ckpt, mesh, rules,
                                              params)
            assert manifest["step"] == 5
            total = sum(np.prod(l.shape) for l in jax.tree.leaves(placed))
            orig = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
            assert total == orig
            print("ELASTIC_OK")
        """)
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=300)
        assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


class TestMultiDeviceLowering:
    def test_tiny_mesh_train_lowering_has_collectives(self):
        """4-device (2,2) mesh: megatron rules produce all-reduces."""
        script = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, {SRC!r})
            import jax, jax.numpy as jnp
            from repro.configs.base import SHAPES
            from repro.core.program import Program
            from repro.launch.mesh import make_test_mesh
            from repro.launch.weave import default_weave
            from repro.distributed.sharding import param_shardings, input_shardings
            from repro.nn.module import abstract_params
            from repro.optim import adamw
            from repro.optim.adamw import AdamWConfig
            from repro.runtime.steps import build_train_step
            from repro.roofline.analysis import parse_collectives
            from jax.sharding import NamedSharding, PartitionSpec as P

            mesh = make_test_mesh((2, 2), ("data", "model"))
            program = Program.from_arch("yi-6b", reduced=True)
            woven = default_weave(program, SHAPES["train_4k"], dict(mesh.shape),
                                  overrides={{"accum_steps": 2}})
            params_sds = abstract_params(program.model, woven.state.policies)
            ps = param_shardings(program.model, mesh, woven.state.rules)
            opt_cfg = AdamWConfig()
            opt_sds = adamw.abstract_state(params_sds, opt_cfg)
            repl = NamedSharding(mesh, P())
            ps_opt = {{"master": ps, "m": ps, "v": ps, "count": repl}}
            sds = jax.ShapeDtypeStruct
            batch = {{"tokens": sds((8, 32), jnp.int32),
                      "labels": sds((8, 32), jnp.int32)}}
            ps_b = input_shardings(batch, mesh, woven.state.rules)
            step = build_train_step(woven, mesh=mesh, opt_cfg=opt_cfg)
            c = jax.jit(step, in_shardings=(ps, ps_opt, ps_b, repl),
                        donate_argnums=(0, 1)).lower(
                params_sds, opt_sds, batch, sds((), jnp.int32)).compile()
            colls = parse_collectives(c.as_text())
            assert colls.counts.get("all-reduce", 0) > 0, colls.counts
            print("LOWERING_OK", colls.counts)
        """)
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=600)
        assert "LOWERING_OK" in out.stdout, out.stderr[-2000:]

    def test_flash_attention_shard_map(self):
        """Pallas flash attention under shard_map on a (2,2) mesh."""
        script = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, {SRC!r})
            import jax, jax.numpy as jnp, numpy as np
            from repro.kernels.flash_attention.ops import flash_attention
            from repro.kernels.flash_attention.ref import attention_ref
            from repro.launch.mesh import make_test_mesh

            mesh = make_test_mesh((2, 2), ("data", "model"))
            B, S, H, K, D = 2, 128, 4, 2, 64
            ks = jax.random.split(jax.random.PRNGKey(0), 3)
            q = jax.random.normal(ks[0], (B, S, H, D))
            k = jax.random.normal(ks[1], (B, S, K, D))
            v = jax.random.normal(ks[2], (B, S, K, D))
            out = flash_attention(q, k, v, causal=True, block_q=64,
                                  block_kv=64, interpret=True, mesh=mesh,
                                  rules={{"batch": ("data",), "heads": "model"}})
            ref = attention_ref(q, k, v, causal=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-4, atol=1e-4)
            print("SHARDMAP_OK")
        """)
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=600)
        assert "SHARDMAP_OK" in out.stdout, out.stderr[-2000:]


class TestMonitoringIntegration:
    def test_sensors_publish_during_training(self):
        broker = ExamonBroker()
        coll = ExamonCollector("c", "train/step_time/*").init(broker)
        coll.start()
        trainer = _trainer(steps=5, broker=broker)
        trainer.run()
        assert coll.count() == 5
        assert coll.get_mean() > 0
