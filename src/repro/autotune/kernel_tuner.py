"""DSE-driven kernel block autotuning — closing the paper's Fig. 13–14 loop.

The Lat DSE (paper §4.1) explores per-kernel block knobs, the results become
a mARGOt `KnowledgeBase` (paper §2.5), and the best operating point persists
in an on-disk cache keyed by the kernel's problem signature.  Entry points
(`repro.kernels.*.ops`) and the weaver (`TunedKernelAspect`) consult the
cache, so woven programs and the serving runtime pick tuned blocks
automatically — the DSE output is literally "fed to the autotuner".

Layout of the cache file (JSON):

    {"<signature key>": {"knobs": {...best...},
                         "metrics": {"latency_s": [mean, std], ...},
                         "ops": [{"knobs": ..., "metrics": ...}, ...]}}

For flash attention the knob dict covers both directions:
`block_q` / `block_kv` tile the forward kernel and `block_q_bwd` /
`block_kv_bwd` tile the fused backward passes (dq and dk/dv); the default
measurement times a full fwd+grad step so the DSE optimizes training-step
latency, and the VMEM constraint is the max of the forward
(`vmem_bytes`) and backward (`vmem_bytes_bwd`) analytic working sets.
Entries written before the backward knobs existed simply lack the `_bwd`
keys — consumers (`ops._resolve_blocks`, `TunedKernelAspect`) fall back to
the forward blocks.

Tuning is always *explicit* (benchmarks, launch tooling, tests); lookups on
the hot path are cheap dict reads and never trigger measurement.  The one
sanctioned *implicit* write path is `refine_from_runtime`: serving traffic's
observed latencies (mARGOt error coefficients) rescale the cached operating
points and re-select the knobs under the adjusted constraints — the paper's
"runtime observations as feedback information" closed over the persistent
knowledge base.  The `paged_decode` space adds the serving pool geometry:
`page_size` (allocation quantum of the paged KV cache) jointly explored
with `block_kv_dec` (clamped to a page divisor); its DSE rows also record
`pool_hbm_bytes`, the shared-prefix HBM model (`prefix_shared_pool_bytes`)
— prefix caching shares full prompt pages across requests, and smaller
pages share a longer page-aligned prefix, the capacity counterweight to
large pages' smaller block tables.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
import uuid
from typing import Any, Callable, Mapping

from repro.autotune.dse import Lat
from repro.autotune.margot import LE, Goal, KnowledgeBase, Margot, OperatingPoint, State
from repro.kernels.flash_attention.decode import page_block_kv, vmem_bytes_dec
from repro.kernels.flash_attention.kernel import cdiv, vmem_bytes, vmem_bytes_bwd

DEFAULT_VMEM_BUDGET = 16 * 2**20  # bytes per TPU core

_DTYPE_BYTES = {
    "float32": 4, "f32": 4, "bfloat16": 2, "bf16": 2, "float16": 2,
    "int8": 1, "float8_e4m3fn": 1, "float8_e5m2": 1,
}


def dtype_bytes(dtype: Any) -> int:
    name = getattr(dtype, "name", None) or str(dtype)
    return _DTYPE_BYTES.get(name, 4)


# ---------------------------------------------------------------------------
# Signatures
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelSignature:
    """Everything that changes which block configuration is optimal."""

    kernel: str               # flash_attention | rwkv6 | rglru | rmsnorm
    shape: tuple[int, ...]    # problem shape (kernel-specific, see helpers)
    dtype: str = "bfloat16"
    causal: bool = False
    window: int | None = None
    gqa: int = 1              # q heads per kv head

    def key(self) -> str:
        shp = "x".join(str(s) for s in self.shape)
        mask = "c" if self.causal else "f"
        win = str(self.window) if self.window is not None else "-"
        return f"{self.kernel}/{shp}/{self.dtype}/{mask}/w{win}/g{self.gqa}"


def flash_signature(q_shape, kv_heads: int, dtype, *, causal: bool,
                    window: int | None = None) -> KernelSignature:
    """q_shape is the model layout (B, S, H, D)."""
    B, S, H, D = q_shape
    return KernelSignature(
        kernel="flash_attention", shape=(B, S, H, kv_heads, D),
        dtype=str(getattr(dtype, "name", dtype)), causal=causal,
        window=window, gqa=H // max(kv_heads, 1),
    )


def flash_decode_signature(batch: int, cache_len: int, n_heads: int,
                           kv_heads: int, head_dim: int, dtype="bfloat16",
                           *, window: int | None = None) -> KernelSignature:
    """One-token decode against a length-`cache_len` cache.  A separate
    kernel space from `flash_attention`: the knob (`block_kv_dec`) tiles the
    cache stream and the measurement is a full cached-decode step, not a
    training fwd+grad."""
    return KernelSignature(
        kernel="flash_decode",
        shape=(batch, cache_len, n_heads, kv_heads, head_dim),
        dtype=str(getattr(dtype, "name", dtype)), causal=True,
        window=window, gqa=n_heads // max(kv_heads, 1),
    )


def paged_decode_signature(batch: int, cache_len: int, n_heads: int,
                           kv_heads: int, head_dim: int, dtype="bfloat16",
                           *, window: int | None = None) -> KernelSignature:
    """Block-table decode against a shared page pool.  Its own kernel space
    because the pool geometry adds a knob: `page_size` fixes the physical
    block granularity (allocation quantum AND the ceiling of the streamed
    block — `block_kv_dec` is clamped to a divisor of it, the knob
    interaction the DSE explores jointly)."""
    return KernelSignature(
        kernel="paged_decode",
        shape=(batch, cache_len, n_heads, kv_heads, head_dim),
        dtype=str(getattr(dtype, "name", dtype)), causal=True,
        window=window, gqa=n_heads // max(kv_heads, 1),
    )


def quantized_cache_signature(batch: int, cache_len: int, n_heads: int,
                              kv_heads: int, head_dim: int, dtype="bfloat16",
                              *, window: int | None = None) -> KernelSignature:
    """Accuracy-constrained dtype×geometry DSE for the quantized page pool.
    Its own kernel space because the objective flips: instead of minimizing
    latency under VMEM, it maximizes tokens-per-HBM-byte (serving capacity)
    subject to a *measured* logits-error constraint against the fp cache —
    the paper's precision-autotuning shape (DSE over precision versions
    under an accuracy goal).  `dtype` keys the fp *reference* pool; the
    explored `cache_dtype` knob includes fp names as the accuracy-fallback
    arm (meaning: keep the fp pool)."""
    return KernelSignature(
        kernel="quantized_cache",
        shape=(batch, cache_len, n_heads, kv_heads, head_dim),
        dtype=str(getattr(dtype, "name", dtype)), causal=True,
        window=window, gqa=n_heads // max(kv_heads, 1),
    )


def speculative_signature(batch: int, cache_len: int, n_heads: int,
                          kv_heads: int, head_dim: int, dtype="bfloat16",
                          *, window: int | None = None) -> KernelSignature:
    """Speculative-decoding verify step: a widened-q flash_decode call
    scoring `draft_len + 1` tokens per request in one kernel instance.
    Its own kernel space because the governing knob is the draft span
    itself — `draft_len` scales the q tile (rows = (draft_len+1)·group)
    and the per-step work, while the *useful* tokens per step scale with
    the draft's acceptance rate, which only serving traffic can observe
    (`Server.refine_speculative` feeds it back as `tokens_per_step`)."""
    return KernelSignature(
        kernel="speculative",
        shape=(batch, cache_len, n_heads, kv_heads, head_dim),
        dtype=str(getattr(dtype, "name", dtype)), causal=True,
        window=window, gqa=n_heads // max(kv_heads, 1),
    )


def rmsnorm_signature(rows: int, dim: int, dtype="bfloat16") -> KernelSignature:
    """Fused RMSNorm problem: (rows, d) with rows = batch * seq."""
    return KernelSignature(
        kernel="rmsnorm", shape=(rows, dim),
        dtype=str(getattr(dtype, "name", dtype)),
    )


def rwkv6_signature(batch: int, seq_len: int, d_model: int,
                    head_dim: int = 64, dtype="float32") -> KernelSignature:
    """WKV problem signature: (B, S, H, C) with H = d_model // head_dim."""
    return KernelSignature(
        kernel="rwkv6",
        shape=(batch, seq_len, d_model // max(head_dim, 1), head_dim),
        dtype=str(getattr(dtype, "name", dtype)),
    )


def rglru_signature(batch: int, seq_len: int, width: int,
                    dtype="float32") -> KernelSignature:
    """RG-LRU problem signature: (B, S, D) with D the lru width."""
    return KernelSignature(
        kernel="rglru", shape=(batch, seq_len, width),
        dtype=str(getattr(dtype, "name", dtype)),
    )


# ---------------------------------------------------------------------------
# Design spaces + constraints
# ---------------------------------------------------------------------------

KERNEL_SPACES: dict[str, dict[str, tuple[int, ...]]] = {
    "flash_attention": {
        "block_q": (128, 256, 512, 1024),
        "block_kv": (128, 256, 512, 1024),
        "block_q_bwd": (128, 256, 512, 1024),
        "block_kv_bwd": (128, 256, 512, 1024),
    },
    "flash_decode": {"block_kv_dec": (128, 256, 512, 1024)},
    "paged_decode": {
        "page_size": (64, 128, 256, 512),
        "block_kv_dec": (128, 256, 512, 1024),
    },
    "speculative": {
        "draft_len": (1, 2, 4, 8),
        "block_kv_dec": (128, 256, 512, 1024),
    },
    "quantized_cache": {
        # categorical dtype knob: fp16 is the accuracy-fallback arm (keep
        # the fp pool); fp8 arms appear only where the platform has them
        "cache_dtype": ("float16", "int8"),
        "page_size": (64, 128, 256, 512),
        "block_kv_dec": (128, 256, 512, 1024),
    },
    "rwkv6": {"chunk": (16, 32, 64, 128)},
    "rglru": {"block_d": (128, 256, 512, 1024), "chunk": (64, 128, 256)},
    "rmsnorm": {"block_rows": (64, 128, 256, 512)},
}

import jax.numpy as _jnp  # noqa: E402  (fp8 arms are platform-gated)

if hasattr(_jnp, "float8_e4m3fn"):
    KERNEL_SPACES["quantized_cache"]["cache_dtype"] += ("float8_e4m3fn",)


def config_vmem_bytes(sig: KernelSignature, knobs: Mapping[str, int]) -> int:
    """Analytic VMEM working set of one configuration (the LE constraint)."""
    b = dtype_bytes(sig.dtype)
    if sig.kernel == "flash_attention":
        B, S, H, K, D = sig.shape
        fwd = vmem_bytes(
            min(int(knobs["block_q"]), S), min(int(knobs["block_kv"]), S),
            D, b, kv_dtype_bytes=b,
        )
        bqb = int(knobs.get("block_q_bwd", knobs["block_q"]))
        bkvb = int(knobs.get("block_kv_bwd", knobs["block_kv"]))
        bwd = vmem_bytes_bwd(min(bqb, S), min(bkvb, S), D, b,
                             kv_dtype_bytes=b)
        return max(fwd, bwd)
    if sig.kernel == "flash_decode":
        B, T, H, K, D = sig.shape
        return vmem_bytes_dec(
            H // max(K, 1), min(int(knobs["block_kv_dec"]), max(T, 128)),
            D, b, kv_dtype_bytes=b,
        )
    if sig.kernel == "paged_decode":
        B, T, H, K, D = sig.shape
        ps = int(knobs["page_size"])
        eff = page_block_kv(int(knobs["block_kv_dec"]), ps)
        return vmem_bytes_dec(
            H // max(K, 1), min(eff, max(T, 128)), D, b, kv_dtype_bytes=b,
        ) + 4 * cdiv(max(T, 1), ps)  # + the SMEM block-table row
    if sig.kernel == "quantized_cache":
        B, T, H, K, D = sig.shape
        ps = int(knobs["page_size"])
        eff = page_block_kv(int(knobs["block_kv_dec"]), ps)
        # the kernel streams the pool's storage dtype and dequantizes
        # in-register: K/V tiles shrink with the quantized dtype
        qb = _DTYPE_BYTES.get(str(knobs["cache_dtype"]), b)
        return vmem_bytes_dec(
            H // max(K, 1), min(eff, max(T, 128)), D, b, kv_dtype_bytes=qb,
        ) + 4 * cdiv(max(T, 1), ps)  # + the SMEM block-table row
    if sig.kernel == "speculative":
        B, T, H, K, D = sig.shape
        return vmem_bytes_dec(
            H // max(K, 1), min(int(knobs["block_kv_dec"]), max(T, 128)),
            D, b, kv_dtype_bytes=b, q_span=int(knobs["draft_len"]) + 1,
        )
    if sig.kernel == "rwkv6":
        B, S, H, C = sig.shape
        L = int(knobs["chunk"])
        # 4 chunk blocks + pairwise decay (L,L,C) + state (C,C), fp32 math
        return (4 * L * C + L * L * C + C * C) * 4
    if sig.kernel == "rglru":
        B, S, D = sig.shape
        L, Db = int(knobs["chunk"]), int(knobs["block_d"])
        return 3 * L * min(Db, D) * 4
    if sig.kernel == "rmsnorm":
        rows, d = sig.shape
        return 2 * min(int(knobs["block_rows"]), rows) * d * 4
    raise KeyError(sig.kernel)


def prefix_shared_pool_bytes(sig: KernelSignature, knobs: Mapping[str, int],
                             *, prefix_len: int | None = None) -> int:
    """HBM a prefix-shared pool allocates for the signature's batch at the
    knob's pool geometry: full prefix pages are stored *once* (refcounted
    copy-on-write sharing in `repro.runtime.pages`), each request adds only
    its suffix pages plus the prefix/suffix straddling partial.

    This is the shared-page HBM model the pool-geometry DSE weighs against
    block-stream efficiency: sharing rounds the prefix *down* to a page
    boundary, so smaller pages share more of it — the counterweight to
    large pages' smaller tables.  `prefix_len` defaults to half the cache
    (the serving-mix assumption recorded with the DSE rows); callers with a
    known system-prompt length pass it explicitly.
    """
    B, T, H, K, D = sig.shape
    ps = int(knobs["page_size"])
    prefix = min(T // 2 if prefix_len is None else int(prefix_len), T)
    shared_full = prefix // ps           # stored once, every table maps them
    per_request = cdiv(T, ps) - shared_full
    pages = shared_full + B * per_request
    return pages * ps * K * D * 2 * dtype_bytes(sig.dtype)


def quantized_pool_bytes(sig: KernelSignature, knobs: Mapping[str, Any]) -> int:
    """HBM the pool allocates for the signature's batch at the knob's
    dtype×geometry: quantized payload at `cache_dtype` plus the per-page
    fp32 scale sidecars (2 rows of K scales per page: k and v).  Fp dtype
    values model the unquantized pool (no sidecars).  This is the
    denominator of the `tokens_per_hbm_byte` objective."""
    B, T, H, K, D = sig.shape
    ps = int(knobs["page_size"])
    name = str(knobs["cache_dtype"])
    qb = _DTYPE_BYTES.get(name, dtype_bytes(sig.dtype))
    pages = B * cdiv(max(T, 1), ps)
    per_page = 2 * ps * K * D * qb
    if qb == 1:  # quantized formats carry the fp32 scale sidecars
        per_page += 2 * K * 4
    return pages * per_page


def design_space(sig: KernelSignature, *,
                 vmem_budget: int = DEFAULT_VMEM_BUDGET) -> dict[str, list[int]]:
    """Per-kernel knob values, pre-filtered so every value is feasible for
    the signature's shape on its own (cross-knob VMEM feasibility is the
    tuner's point-level constraint)."""
    space = {k: list(v) for k, v in KERNEL_SPACES[sig.kernel].items()}
    if sig.kernel == "flash_attention":
        B, S, H, K, D = sig.shape
        for name in ("block_q", "block_kv", "block_q_bwd", "block_kv_bwd"):
            space[name] = [v for v in space[name] if v <= max(S, 128)]
    elif sig.kernel == "flash_decode":
        T = sig.shape[1]
        space["block_kv_dec"] = [
            v for v in space["block_kv_dec"] if v <= max(T, 128)
        ]
    elif sig.kernel == "paged_decode":
        T = sig.shape[1]
        space["page_size"] = [v for v in space["page_size"] if v <= max(T, 64)]
        space["block_kv_dec"] = [
            v for v in space["block_kv_dec"] if v <= max(T, 128)
        ]
    elif sig.kernel == "quantized_cache":
        T = sig.shape[1]
        space["page_size"] = [v for v in space["page_size"] if v <= max(T, 64)]
        space["block_kv_dec"] = [
            v for v in space["block_kv_dec"] if v <= max(T, 128)
        ]
    elif sig.kernel == "speculative":
        T = sig.shape[1]
        space["block_kv_dec"] = [
            v for v in space["block_kv_dec"] if v <= max(T, 128)
        ]
        # the draft block must fit under the request's decode budget slack
        space["draft_len"] = [v for v in space["draft_len"]
                              if v < max(T, 2)]
    elif sig.kernel == "rwkv6":
        S = sig.shape[1]
        space["chunk"] = [v for v in space["chunk"] if v <= max(S, 16)]
    elif sig.kernel == "rglru":
        B, S, D = sig.shape
        space["block_d"] = [v for v in space["block_d"] if v <= max(D, 128)]
        space["chunk"] = [v for v in space["chunk"] if v <= max(S, 64)]
    # drop single-knob values that can never fit the VMEM budget
    for name in list(space):
        feasible = []
        for v in space[name]:
            probe = {n: min(vals) for n, vals in space.items()}
            probe[name] = v
            if config_vmem_bytes(sig, probe) <= vmem_budget:
                feasible.append(v)
        space[name] = feasible or [min(space[name])]
    return space


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------


def default_cache_path() -> str:
    return os.environ.get(
        "REPRO_TUNER_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "kernel_tuner.json"),
    )


class TunerCache:
    """Tiny JSON-backed store: signature key -> best knobs + DSE rows."""

    def __init__(self, path: str | None = None):
        self.path = path or default_cache_path()
        self._data: dict[str, dict] | None = None
        self.hits = 0
        self.misses = 0

    def _load(self) -> dict[str, dict]:
        if self._data is None:
            try:
                with open(self.path) as f:
                    self._data = json.load(f)
            except (OSError, ValueError):
                self._data = {}
        return self._data

    def get(self, key: str) -> dict | None:
        entry = self._load().get(key)
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
        return entry

    def put(self, key: str, entry: dict) -> None:
        data = self._load()
        data[key] = entry
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        # unique tmp per writer: concurrent puts must not interleave bytes
        tmp = f"{self.path}.tmp.{os.getpid()}.{uuid.uuid4().hex[:8]}"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    def __len__(self) -> int:
        return len(self._load())


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------


def _device_tag() -> str:
    """Measurement substrate of this process's tuner rows.  Interpret-mode
    measurements (the CPU-CI default) are what most entries hold; with
    REPRO_TUNER_ON_DEVICE=1 the tag is the real jax backend, so on-device
    rows key separately and never cross-contaminate interpret lookups."""
    if os.environ.get("REPRO_TUNER_ON_DEVICE") == "1":
        import jax

        return str(jax.default_backend())
    return "interpret"


class KernelTuner:
    """Lat DSE over kernel block knobs, constrained by the analytic VMEM
    model, persisted through a TunerCache."""

    def __init__(self, cache: TunerCache | str | None = None, *,
                 vmem_budget: int = DEFAULT_VMEM_BUDGET):
        if isinstance(cache, TunerCache):
            self.cache = cache
        else:
            self.cache = TunerCache(cache)
        self.vmem_budget = vmem_budget
        self.tuned = 0  # DSE runs performed (cache misses that measured)

    # -- lookup ----------------------------------------------------------------

    def _key(self, sig: KernelSignature) -> str:
        """Cache key for this process's measurement substrate: interpret
        rows keep the bare signature key (every pre-existing entry), while
        on-device rows (REPRO_TUNER_ON_DEVICE=1) append "@<backend>"."""
        dev = _device_tag()
        return sig.key() if dev == "interpret" else f"{sig.key()}@{dev}"

    def lookup(self, sig: KernelSignature) -> dict[str, int] | None:
        entry = self.cache.get(self._key(sig))
        if entry is None:
            return None
        return dict(entry["knobs"])

    def knowledge_base(self, sig: KernelSignature) -> KnowledgeBase | None:
        """Rebuild the mARGOt KnowledgeBase from the cached DSE rows."""
        entry = self.cache.get(self._key(sig))
        if entry is None:
            return None
        ops = [
            OperatingPoint(
                knobs=dict(row["knobs"]),
                metrics={m: tuple(v) for m, v in row["metrics"].items()},
            )
            for row in entry.get("ops", [])
        ]
        return KnowledgeBase(ops)

    # -- tuning ----------------------------------------------------------------

    def tune(
        self,
        sig: KernelSignature,
        measure: Callable[..., float] | None = None,
        *,
        sample: int | None = None,
        num_tests: int = 1,
        seed: int = 0,
    ) -> dict[str, int]:
        """Run the DSE and persist best knobs + the full operating-point set.

        `measure(**knobs) -> latency_s` defaults to timing the real kernel on
        inputs shaped like the signature (interpret mode off-TPU)."""
        if measure is None:
            measure = _default_measure(sig)
        space = design_space(sig, vmem_budget=self.vmem_budget)

        lat = Lat(sig.key()).set_num_tests(num_tests)
        for name, values in space.items():
            lat.add_var(name, values)
        lat.add_metric("latency_s", measure)
        lat.add_metric(
            "vmem_bytes", lambda **knobs: config_vmem_bytes(sig, knobs)
        )
        if sig.kernel == "paged_decode":
            # pool-geometry DSE also records the shared-prefix HBM model:
            # the rows let refine_from_runtime / offline analysis trade the
            # page_size knob against prefix-cache capacity, not just VMEM
            lat.add_metric(
                "pool_hbm_bytes",
                lambda **knobs: float(prefix_shared_pool_bytes(sig, knobs)),
            )
        if sig.kernel == "speculative":
            # expected useful tokens per verify step under the acceptance-1
            # prior; serving traffic's observed mean (acceptance < 1)
            # rescales these expectations through refine_from_runtime
            lat.add_metric(
                "tokens_per_step",
                lambda **knobs: float(int(knobs["draft_len"]) + 1),
            )
        results = lat.tune(sample=sample, seed=seed)

        feasible = [
            r for r in results
            if r["metrics"]["vmem_bytes"][0] <= self.vmem_budget
        ]
        pool = feasible or results
        best = min(pool, key=lambda r: r["metrics"]["latency_s"][0])
        entry = {
            "knobs": {k: v for k, v in best["knobs"].items()},
            "metrics": {m: list(v) for m, v in best["metrics"].items()},
            "ops": [
                {"knobs": r["knobs"],
                 "metrics": {m: list(v) for m, v in r["metrics"].items()}}
                for r in results
            ],
            "device": _device_tag(),
        }
        self.cache.put(self._key(sig), entry)
        self.tuned += 1
        return dict(best["knobs"])

    def get(self, sig: KernelSignature,
            measure: Callable[..., float] | None = None,
            **tune_kw) -> dict[str, int]:
        """Cached best knobs, tuning on first miss."""
        knobs = self.lookup(sig)
        if knobs is not None:
            return knobs
        return self.tune(sig, measure, **tune_kw)


# ---------------------------------------------------------------------------
# Default measurement (the real kernel, small reps)
# ---------------------------------------------------------------------------


def _default_measure(sig: KernelSignature) -> Callable[..., float]:
    import jax
    import jax.numpy as jnp

    dt = {"bfloat16": jnp.bfloat16, "float16": jnp.float16}.get(
        sig.dtype, jnp.float32
    )

    if sig.kernel == "flash_attention":
        from repro.kernels.flash_attention.ops import flash_attention

        B, S, H, K, D = sig.shape
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, S, H, D), dt)
        k = jax.random.normal(ks[1], (B, S, K, D), dt)
        v = jax.random.normal(ks[2], (B, S, K, D), dt)
        g = jax.random.normal(ks[3], (B, S, H, D), jnp.float32)

        def measure(**knobs):
            # training-step latency: forward + fused backward, so the DSE
            # sees both the fwd and the bwd block knobs.
            def loss(q, k, v):
                out = flash_attention(
                    q, k, v, causal=sig.causal, window=sig.window,
                    block_q=int(knobs["block_q"]),
                    block_kv=int(knobs["block_kv"]),
                    block_q_bwd=int(knobs.get("block_q_bwd",
                                              knobs["block_q"])),
                    block_kv_bwd=int(knobs.get("block_kv_bwd",
                                               knobs["block_kv"])),
                )
                return jnp.sum(out.astype(jnp.float32) * g)

            fn = jax.grad(loss, argnums=(0, 1, 2))
            jax.block_until_ready(fn(q, k, v))  # compile
            t0 = time.perf_counter()
            jax.block_until_ready(fn(q, k, v))
            return time.perf_counter() - t0

        return measure

    if sig.kernel == "flash_decode":
        from repro.kernels.flash_attention.ops import flash_decode

        B, T, H, K, D = sig.shape
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, 1, H, D), dt)
        k = jax.random.normal(ks[1], (B, T, K, D), dt)
        v = jax.random.normal(ks[2], (B, T, K, D), dt)
        kv_new = jax.random.normal(ks[3], (B, 1, K, D), dt)
        index = jnp.full((B,), T - 1, jnp.int32)  # worst case: full cache

        def measure(**knobs):
            # a full cached-decode step: in-place cache update + attention,
            # so the DSE optimizes what serving actually pays per token.
            @jax.jit
            def step(q, k, v, kv_new, index):
                bidx = jnp.arange(B)
                k = k.at[bidx, index].set(kv_new[:, 0])
                v = v.at[bidx, index].set(kv_new[:, 0])
                return flash_decode(
                    q, k, v, index, window=sig.window,
                    block_kv=int(knobs["block_kv_dec"]),
                )

            jax.block_until_ready(step(q, k, v, kv_new, index))  # compile
            t0 = time.perf_counter()
            jax.block_until_ready(step(q, k, v, kv_new, index))
            return time.perf_counter() - t0

        return measure

    if sig.kernel == "paged_decode":
        from repro.kernels.flash_attention.ops import flash_decode

        B, T, H, K, D = sig.shape
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, 1, H, D), dt)
        kv_new = jax.random.normal(ks[3], (B, 1, K, D), dt)
        index = jnp.full((B,), T - 1, jnp.int32)  # worst case: full cache

        def measure(**knobs):
            # a full *paged* decode step at the knob's pool geometry: the
            # page write + block-table-resolved attention, so the DSE sees
            # the page_size x block_kv_dec interaction end to end.
            ps = int(knobs["page_size"])
            nb = cdiv(T, ps)
            pool = B * nb
            k = jax.random.normal(ks[1], (pool, ps, K, D), dt)
            v = jax.random.normal(ks[2], (pool, ps, K, D), dt)
            tables = jnp.arange(pool, dtype=jnp.int32).reshape(B, nb)

            @jax.jit
            def step(q, k, v, kv_new, index, tables):
                bidx = jnp.arange(B)
                page = tables[bidx, index // ps]
                k = k.at[page, index % ps].set(kv_new[:, 0])
                v = v.at[page, index % ps].set(kv_new[:, 0])
                return flash_decode(
                    q, k, v, index, window=sig.window,
                    tables=tables, kv_len=T,
                    block_kv=int(knobs["block_kv_dec"]),
                )

            args = (q, k, v, kv_new, index, tables)
            jax.block_until_ready(step(*args))  # compile
            t0 = time.perf_counter()
            jax.block_until_ready(step(*args))
            return time.perf_counter() - t0

        return measure

    if sig.kernel == "speculative":
        from repro.kernels.flash_attention.ops import flash_decode

        B, T, H, K, D = sig.shape
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        kv_full = jax.random.normal(ks[1], (B, T, K, D), dt)

        def measure(**knobs):
            # one widened-q verify step: write the draft block in place,
            # then score all draft_len+1 positions in a single kernel call
            # — what a speculative serving round pays on the target model.
            S = int(knobs["draft_len"]) + 1
            q = jax.random.normal(ks[0], (B, S, H, D), dt)
            kv_new = jax.random.normal(ks[3], (B, S, K, D), dt)
            index = jnp.full((B,), T - S, jnp.int32)  # worst case: near-full

            @jax.jit
            def step(q, k, v, kv_new, index):
                bidx = jnp.arange(B)
                slots = index[:, None] + jnp.arange(S)
                k = k.at[bidx[:, None], slots].set(kv_new)
                v = v.at[bidx[:, None], slots].set(kv_new)
                return flash_decode(
                    q, k, v, index, window=sig.window,
                    block_kv=int(knobs["block_kv_dec"]),
                )

            args = (q, kv_full, kv_full, kv_new, index)
            jax.block_until_ready(step(*args))  # compile
            t0 = time.perf_counter()
            jax.block_until_ready(step(*args))
            return time.perf_counter() - t0

        return measure

    if sig.kernel == "quantized_cache":
        return _quantized_cache_measures(sig)[0]

    if sig.kernel == "rwkv6":
        from repro.kernels.rwkv6.ops import wkv_pallas

        B, S, H, C = sig.shape
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        r, k, v = (jax.random.normal(ks[i], (B, S, H, C)) for i in range(3))
        w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, C))))
        u = jax.random.normal(ks[4], (H, C))
        s0 = jnp.zeros((B, H, C, C))

        def measure(**knobs):
            fn = lambda: wkv_pallas(r, k, v, w, u, s0, chunk=int(knobs["chunk"]))[0]
            jax.block_until_ready(fn())
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            return time.perf_counter() - t0

        return measure

    if sig.kernel == "rglru":
        from repro.kernels.rglru.ops import rglru_pallas

        B, S, D = sig.shape
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, D)))
        b = jax.random.normal(ks[1], (B, S, D))
        h0 = jax.random.normal(ks[2], (B, D))

        def measure(**knobs):
            fn = lambda: rglru_pallas(
                a, b, h0, block_d=int(knobs["block_d"]), chunk=int(knobs["chunk"])
            )[0]
            jax.block_until_ready(fn())
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            return time.perf_counter() - t0

        return measure

    if sig.kernel == "rmsnorm":
        from repro.kernels.rmsnorm.ops import rmsnorm

        rows, d = sig.shape
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (rows, d), dt)
        w = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)

        def measure(**knobs):
            fn = lambda: rmsnorm(x, w, block_rows=int(knobs["block_rows"]))
            jax.block_until_ready(fn())
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            return time.perf_counter() - t0

        return measure

    raise KeyError(sig.kernel)


def _quantized_cache_measures(sig: KernelSignature):
    """(latency, error) measures for the quantized-cache DSE.

    Both run the real paged `flash_decode` over pools packed at the knob's
    geometry (interpret mode off-TPU).  The error measure is the mARGOt
    error model's ground truth: the max-abs deviation of the decode
    attention output between the quantized pool (per-page scales,
    in-kernel dequant) and the same values served fp — exactly what the
    serving path changes, so the accuracy Goal constrains what users see.
    Fp dtype arms score 0.0 by construction.  Per-geometry fp pools are
    memoized so every dtype arm compares against identical values."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention.ops import CACHE_QMAX, flash_decode
    from repro.runtime.pages import quantize_linear_pool

    B, T, H, K, D = sig.shape
    dt = {"bfloat16": jnp.bfloat16, "float16": jnp.float16}.get(
        sig.dtype, jnp.float32
    )
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (B, 1, H, D), dt)
    index = jnp.full((B,), T - 1, jnp.int32)  # worst case: full cache
    fp_pools: dict[int, tuple] = {}

    def fp_pool(ps):
        if ps not in fp_pools:
            nb = cdiv(T, ps)
            pool = B * nb
            k = jax.random.normal(keys[1], (pool, ps, K, D), dt)
            v = jax.random.normal(keys[2], (pool, ps, K, D), dt)
            tables = jnp.arange(pool, dtype=jnp.int32).reshape(B, nb)
            fp_pools[ps] = (k, v, tables)
        return fp_pools[ps]

    def call(k, v, tables, blk, scales=None):
        ksc, vsc = scales if scales is not None else (None, None)
        return flash_decode(q, k, v, index, tables=tables, kv_len=T,
                            block_kv=blk, k_scale=ksc, v_scale=vsc)

    def latency(**knobs):
        ps, blk = int(knobs["page_size"]), int(knobs["block_kv_dec"])
        name = str(knobs["cache_dtype"])
        k, v, tables = fp_pool(ps)
        scales = None
        if name in CACHE_QMAX:
            k, v, ksc, vsc = quantize_linear_pool(k, v, name)
            scales = (ksc, vsc)
        fn = jax.jit(lambda: call(k, v, tables, blk, scales))
        jax.block_until_ready(fn())  # compile
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        return time.perf_counter() - t0

    def error(**knobs):
        ps, blk = int(knobs["page_size"]), int(knobs["block_kv_dec"])
        name = str(knobs["cache_dtype"])
        if name not in CACHE_QMAX:
            return 0.0
        k, v, tables = fp_pool(ps)
        ref = call(k, v, tables, blk)
        qk, qv, ksc, vsc = quantize_linear_pool(k, v, name)
        out = call(qk, qv, tables, blk, (ksc, vsc))
        return float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                     - ref.astype(jnp.float32))))

    return latency, error


# ---------------------------------------------------------------------------
# Process-wide default tuner (hot-path lookups)
# ---------------------------------------------------------------------------

_default_tuner: KernelTuner | None = None
_default_tuner_path: str | None = None


def default_tuner() -> KernelTuner:
    """Singleton over the default cache path (re-created if REPRO_TUNER_CACHE
    changes, so tests can redirect it)."""
    global _default_tuner, _default_tuner_path
    path = default_cache_path()
    if _default_tuner is None or _default_tuner_path != path:
        _default_tuner = KernelTuner(path)
        _default_tuner_path = path
    return _default_tuner


def tuned_flash_blocks(q_shape, kv_heads: int, dtype, *, causal: bool,
                       window: int | None = None) -> dict[str, int]:
    """Non-failing hot-path lookup used by ops.py: {} when untuned."""
    try:
        sig = flash_signature(q_shape, kv_heads, dtype, causal=causal,
                              window=window)
        return default_tuner().lookup(sig) or {}
    except Exception:  # pragma: no cover - never break the kernel call
        return {}


def tuned_decode_blocks(q_shape, cache_len: int, kv_heads: int, dtype, *,
                        window: int | None = None) -> dict[str, int]:
    """Non-failing decode-knob lookup used by ops.flash_decode: {} when
    untuned.  q_shape is the model layout (B, 1, H, D)."""
    try:
        B, _, H, D = q_shape
        sig = flash_decode_signature(B, cache_len, H, kv_heads, D, dtype,
                                     window=window)
        return default_tuner().lookup(sig) or {}
    except Exception:  # pragma: no cover - never break the kernel call
        return {}


def tuned_paged_blocks(q_shape, cache_len: int, kv_heads: int, dtype, *,
                       window: int | None = None) -> dict[str, int]:
    """Non-failing paged-decode knob lookup: {} when untuned.  Falls back
    to the un-paged `flash_decode` entry's block so a pool built before
    paged tuning ran still streams tuned-size blocks."""
    try:
        B, _, H, D = q_shape
        sig = paged_decode_signature(B, cache_len, H, kv_heads, D, dtype,
                                     window=window)
        knobs = default_tuner().lookup(sig)
        if knobs:
            return knobs
        return tuned_decode_blocks(q_shape, cache_len, kv_heads, dtype,
                                   window=window)
    except Exception:  # pragma: no cover - never break the kernel call
        return {}


def tuned_speculative_knobs(batch: int, cache_len: int, n_heads: int,
                            kv_heads: int, head_dim: int, dtype, *,
                            window: int | None = None) -> dict[str, int]:
    """Non-failing speculative-knob lookup (the serving runtime reads
    `draft_len` through the woven "speculative_draft_len" extra): {} when
    untuned — serving then falls back to plain one-token decode."""
    try:
        sig = speculative_signature(batch, cache_len, n_heads, kv_heads,
                                    head_dim, dtype, window=window)
        return default_tuner().lookup(sig) or {}
    except Exception:  # pragma: no cover - never break the serve path
        return {}


# ---------------------------------------------------------------------------
# Quantized-cache DSE: multi-objective (capacity under an accuracy goal)
# ---------------------------------------------------------------------------


def tune_quantized_cache(
    sig: KernelSignature,
    *,
    error_budget: float = 0.05,
    tuner: KernelTuner | None = None,
    measure: Callable[..., float] | None = None,
    error_measure: Callable[..., float] | None = None,
    sample: int | None = None,
    num_tests: int = 1,
    seed: int = 0,
) -> dict[str, Any]:
    """Run the quantized-cache DSE and persist best knobs + all rows.

    Multi-objective in the paper's precision-autotuning shape: every
    `cache_dtype × page_size × block_kv_dec` point records the analytic
    VMEM/HBM models, a measured decode latency AND a measured
    `max_logit_err` against the fp pool (the mARGOt error model); the
    selected point maximizes `tokens_per_hbm_byte` subject to the error
    staying under `error_budget` and VMEM under the tuner's budget.  The
    persisted entry records `error_budget` so `select_cache_knobs` can
    re-select under a tightened accuracy constraint without re-measuring.
    """
    tuner = tuner or default_tuner()
    if measure is None or error_measure is None:
        lat_m, err_m = _quantized_cache_measures(sig)
        measure = measure or lat_m
        error_measure = error_measure or err_m
    space = design_space(sig, vmem_budget=tuner.vmem_budget)
    B, T = sig.shape[0], sig.shape[1]

    lat = Lat(sig.key()).set_num_tests(num_tests)
    for name, values in space.items():
        lat.add_var(name, values)
    lat.add_metric("latency_s", measure)
    lat.add_metric("vmem_bytes", lambda **kn: config_vmem_bytes(sig, kn))
    lat.add_metric("pool_hbm_bytes",
                   lambda **kn: float(quantized_pool_bytes(sig, kn)))
    lat.add_metric(
        "tokens_per_hbm_byte",
        lambda **kn: float(B * T) / quantized_pool_bytes(sig, kn),
    )
    lat.add_metric("max_logit_err", error_measure)
    results = lat.tune(sample=sample, seed=seed)

    fits = [r for r in results
            if r["metrics"]["vmem_bytes"][0] <= tuner.vmem_budget]
    accurate = [r for r in fits
                if r["metrics"]["max_logit_err"][0] <= error_budget]
    pool = accurate or fits or results
    best = max(pool, key=lambda r: r["metrics"]["tokens_per_hbm_byte"][0])
    entry = {
        "knobs": dict(best["knobs"]),
        "metrics": {m: list(v) for m, v in best["metrics"].items()},
        "ops": [
            {"knobs": r["knobs"],
             "metrics": {m: list(v) for m, v in r["metrics"].items()}}
            for r in results
        ],
        "error_budget": float(error_budget),
        "device": _device_tag(),
    }
    tuner.cache.put(tuner._key(sig), entry)
    tuner.tuned += 1
    return dict(best["knobs"])


def select_cache_knobs(
    sig: KernelSignature,
    *,
    error_budget: float,
    tuner: KernelTuner | None = None,
) -> dict[str, Any] | None:
    """Re-select the quantized-cache knobs from the persisted DSE rows
    under a (possibly tightened) accuracy constraint — no re-measurement.

    A mARGOt State maximizes `tokens_per_hbm_byte` subject to
    `max_logit_err <= error_budget` and the VMEM budget; tightening the
    budget below the quantized arms' measured error forces the selection
    back onto the fp fallback arm.  The re-selected knobs and the new
    budget are persisted.  Returns None when the signature was never
    tuned."""
    tuner = tuner or default_tuner()
    entry = tuner.cache.get(tuner._key(sig))
    if entry is None or not entry.get("ops"):
        return None
    ops = [
        OperatingPoint(
            knobs=dict(row["knobs"]),
            metrics={m: tuple(v) for m, v in row["metrics"].items()},
        )
        for row in entry["ops"]
    ]
    state = State("cache", objective_metric="tokens_per_hbm_byte",
                  maximize=True)
    state.subject_to(Goal("vmem", "vmem_bytes", LE, float(tuner.vmem_budget)))
    state.subject_to(Goal("accuracy", "max_logit_err", LE,
                          float(error_budget)))
    best = Margot(KnowledgeBase(ops), [state]).update()
    knobs = {k: (v if isinstance(v, str) else int(v))
             for k, v in best.knobs.items()}
    new_entry = dict(entry)
    new_entry["knobs"] = knobs
    new_entry["metrics"] = {m: list(v) for m, v in best.metrics.items()}
    new_entry["error_budget"] = float(error_budget)
    tuner.cache.put(tuner._key(sig), new_entry)
    return knobs


# ---------------------------------------------------------------------------
# Runtime feedback: mARGOt observations refine the persisted DSE priors
# ---------------------------------------------------------------------------


def refine_from_runtime(
    sig: KernelSignature,
    observed: Mapping[str, float],
    *,
    tuner: KernelTuner | None = None,
    latency_budget: float | None = None,
    objective_knob: str | None = None,
) -> dict[str, int] | None:
    """Fold serving-time observations back into the persisted tuner cache.

    This is the paper's MAPE-K loop closed over the *persistent* knowledge
    base: the cached DSE rows become a mARGOt KnowledgeBase, the observed
    metric on the currently selected operating point yields an error
    coefficient (observed / expected) that rescales every expectation, and
    the operating point is re-selected — maximize the objective knob (by
    default the entry's largest-granularity knob, e.g. `page_size`:
    fewer, larger pages mean smaller tables and less fragmentation)
    subject to the adjusted latency staying under `latency_budget` and the
    analytic VMEM model under the tuner's budget.  The *adjusted* operating
    points and the re-selected knobs are persisted, so the next process
    serving this signature starts from traffic-refined priors.

    Returns the re-selected knobs, or None when the signature was never
    tuned (runtime feedback refines priors; it does not create them).
    """
    tuner = tuner or default_tuner()
    entry = tuner.cache.get(tuner._key(sig))
    if entry is None or not entry.get("ops"):
        return None
    if objective_knob is None:
        names = list(KERNEL_SPACES.get(sig.kernel, entry["knobs"]))
        # categorical knobs (cache_dtype) can't be a maximize objective —
        # default to the first numeric knob of the space
        numeric = [n for n in names
                   if not isinstance(entry["knobs"].get(n, 0), str)]
        objective_knob = (numeric or names)[0]

    ops = []
    for row in entry["ops"]:
        metrics = {m: tuple(v) for m, v in row["metrics"].items()}
        metrics[f"knob:{objective_knob}"] = (
            float(row["knobs"].get(objective_knob, 0)), 0.0)
        ops.append(OperatingPoint(knobs=dict(row["knobs"]), metrics=metrics))
    state = State("serve", objective_metric=f"knob:{objective_knob}",
                  maximize=True)
    state.subject_to(Goal("vmem", "vmem_bytes", LE, float(tuner.vmem_budget)))
    if latency_budget is not None:
        state.subject_to(Goal("latency", "latency_s", LE,
                              float(latency_budget)))
    margot = Margot(KnowledgeBase(ops), [state])
    current_key = tuple(sorted(entry["knobs"].items()))
    margot.current = next(
        (op for op in ops if op.key() == current_key), ops[0])
    for metric, value in observed.items():
        margot.observe(metric, float(value))
    best = margot.update()

    coefs = dict(margot._error_coef)
    adjusted_ops = []
    for row in entry["ops"]:
        metrics = {
            m: [v[0] * coefs.get(m, 1.0), v[1] * coefs.get(m, 1.0)]
            for m, v in row["metrics"].items()
        }
        adjusted_ops.append({"knobs": dict(row["knobs"]), "metrics": metrics})
    knobs = {k: (v if isinstance(v, str) else int(v))
             for k, v in best.knobs.items()}
    new_entry = dict(entry)  # keep error_budget / device / extra columns
    new_entry.update({
        "knobs": knobs,
        "metrics": {
            m: [v[0] * coefs.get(m, 1.0), v[1] * coefs.get(m, 1.0)]
            for m, v in best.metrics.items() if not m.startswith("knob:")
        },
        "ops": adjusted_ops,
        "runtime": {
            "error_coef": coefs,
            "observed": {m: float(v) for m, v in observed.items()},
            "latency_budget": latency_budget,
        },
    })
    tuner.cache.put(tuner._key(sig), new_entry)
    return knobs
