"""mARGOt dynamic autotuner (paper §2.5, Fig. 10) — MAPE-K over operating
points.

Knowledge: `OperatingPoint`s (knob values -> expected metric mean/std),
derived at deploy time (DSE) or refined at runtime.  Goals are LE/GE
constraints on metrics; a `State` is a constrained optimization problem
(maximize/minimize one metric subject to goals) that can be switched at
runtime.  Adaptation is both:

  reactive  — an error coefficient per metric (observed / expected on the
              current op point) rescales *all* expectations, so the tuner
              reacts to context drift (paper: "runtime observations as
              feedback information");
  proactive — optional input-feature clustering: per-feature knowledge
              bases selected by the nearest feature vector (paper: "features
              of the actual input to adapt in a more proactive fashion").
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any, Callable, Iterable

LE, GE = "le", "ge"


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    knobs: dict[str, Any]
    metrics: dict[str, tuple[float, float]]  # name -> (mean, std)

    def mean(self, metric: str) -> float:
        return self.metrics[metric][0]

    def key(self) -> tuple:
        return tuple(sorted(self.knobs.items()))


@dataclasses.dataclass(frozen=True)
class Goal:
    name: str
    metric: str
    op: str  # le | ge
    value: float
    confidence: float = 0.0  # sigmas of margin

    def satisfied(self, mean: float, std: float = 0.0) -> bool:
        margin = self.confidence * std
        if self.op == LE:
            return mean + margin <= self.value
        return mean - margin >= self.value

    def violation(self, mean: float, std: float = 0.0) -> float:
        margin = self.confidence * std
        if self.op == LE:
            return max(0.0, mean + margin - self.value)
        return max(0.0, self.value - (mean - margin))


@dataclasses.dataclass
class State:
    name: str
    objective_metric: str
    maximize: bool = True
    constraints: list[Goal] = dataclasses.field(default_factory=list)

    def subject_to(self, goal: Goal) -> "State":
        self.constraints.append(goal)
        return self


class KnowledgeBase:
    def __init__(self, ops: Iterable[OperatingPoint] = ()):
        self.ops: list[OperatingPoint] = list(ops)

    def add(self, op: OperatingPoint) -> None:
        self.ops = [o for o in self.ops if o.key() != op.key()] + [op]

    def __len__(self):
        return len(self.ops)

    @staticmethod
    def from_dse(results: list[dict], knob_names: list[str],
                 metric_names: list[str]) -> "KnowledgeBase":
        ops = []
        for row in results:
            knobs = {k: row["knobs"][k] for k in knob_names}
            metrics = {m: tuple(row["metrics"][m]) for m in metric_names}
            ops.append(OperatingPoint(knobs, metrics))
        return KnowledgeBase(ops)


class Margot:
    """The MAPE-K loop.  monitor: observe(); analyze+plan: inside update();
    execute: the caller applies the returned knob configuration."""

    def __init__(self, kb: KnowledgeBase, states: list[State],
                 active_state: str | None = None, *, window: int = 32,
                 feature_kbs: dict[tuple, KnowledgeBase] | None = None):
        self.kb = kb
        self.states = {s.name: s for s in states}
        self.active = active_state or next(iter(self.states))
        self.window = window
        self._obs: dict[str, deque] = {}
        self._error_coef: dict[str, float] = {}
        self.current: OperatingPoint | None = None
        self.feature_kbs = feature_kbs or {}
        self.switches = 0

    # -- Monitor ---------------------------------------------------------------

    def observe(self, metric: str, value: float) -> None:
        """Record one observation of `metric`.

        The per-metric history is a sliding window (`deque(maxlen=window)`),
        not an unbounded list: a long-running managed application — e.g. a
        `serve_stream` session observing every wave — stays O(window)
        memory, and the reactive error coefficient in `_analyze` tracks
        *recent* load instead of averaging the whole session's history.
        Non-finite values are dropped (a poisoned observation would wedge
        the error coefficient at NaN for a full window)."""
        value = float(value)
        if not math.isfinite(value):
            return
        window = self._obs.get(metric)
        if window is None or window.maxlen != self.window:
            # (re)build on first use or after a live `self.window` resize,
            # keeping the most recent tail of what was already observed
            window = deque(window or (), maxlen=self.window)
            self._obs[metric] = window
        window.append(value)

    # -- Analyze: reactive error coefficients -------------------------------------

    def _analyze(self) -> None:
        if self.current is None:
            return
        for metric, values in self._obs.items():
            if metric not in self.current.metrics or not values:
                continue
            expected = self.current.mean(metric)
            observed = sum(values) / len(values)
            if expected > 1e-12 and observed > 1e-12:
                self._error_coef[metric] = observed / expected

    def adjusted(self, op: OperatingPoint, metric: str) -> tuple[float, float]:
        mean, std = op.metrics[metric]
        coef = self._error_coef.get(metric, 1.0)
        return mean * coef, std * coef

    # -- Plan: constrained selection ------------------------------------------------

    def _select_kb(self, features: tuple | None) -> KnowledgeBase:
        if features is None or not self.feature_kbs:
            return self.kb
        best = min(
            self.feature_kbs,
            key=lambda f: sum((a - b) ** 2 for a, b in zip(f, features)),
        )
        return self.feature_kbs[best]

    def update(self, features: tuple | None = None) -> OperatingPoint:
        self._analyze()
        state = self.states[self.active]
        kb = self._select_kb(features)
        valid: list[OperatingPoint] = []
        for op in kb.ops:
            ok = all(
                g.satisfied(*self.adjusted(op, g.metric)) for g in state.constraints
                if g.metric in op.metrics
            )
            if ok:
                valid.append(op)
        if valid:
            sign = 1.0 if state.maximize else -1.0
            best = max(valid, key=lambda op: sign * self.adjusted(op, state.objective_metric)[0])
        else:  # relax: minimize total violation (paper: requirements may be unsatisfiable)
            best = min(
                kb.ops,
                key=lambda op: sum(
                    g.violation(*self.adjusted(op, g.metric))
                    for g in state.constraints
                    if g.metric in op.metrics
                ),
            )
        if self.current is None or best.key() != self.current.key():
            self.switches += 1
        self.current = best
        return best

    def switch_state(self, name: str) -> None:
        if name not in self.states:
            raise KeyError(name)
        self.active = name
