"""LAT-style design-space exploration (paper §4.1, Fig. 13).

Explores knob combinations (full grid or random sample), evaluates each with
user-provided metric callables, repeats `num_tests` times, aggregates
mean/std, and exports CSV — the exploration whose output "can be fed to the
autotuner" (paper Fig. 14), via KnowledgeBase.from_dse.
"""

from __future__ import annotations

import csv
import random
import time
from typing import Any, Callable, Mapping, Sequence

from repro.core.knob import KnobSpace


class Lat:
    def __init__(self, name: str):
        self.name = name
        self.num_tests = 1
        self._vars: dict[str, Sequence[Any]] = {}
        self._metrics: dict[str, Callable[..., float]] = {}
        self.results: list[dict] = []

    # -- design space -----------------------------------------------------------

    def add_var(self, name: str, values: Sequence[Any]) -> "Lat":
        self._vars[name] = list(values)
        return self

    def add_var_range(self, name: str, start: int, stop: int, step: int = 1,
                      transform: Callable[[int], Any] | None = None) -> "Lat":
        vals = [transform(x) if transform else x for x in range(start, stop, step)]
        self._vars[name] = vals
        return self

    def from_knob_space(self, space: KnobSpace) -> "Lat":
        for k in space:
            self._vars[k.name] = list(k.values)
        return self

    # -- metrics -----------------------------------------------------------------

    def add_metric(self, name: str, fn: Callable[..., float]) -> "Lat":
        """fn(**knobs) -> value; called num_tests times per point."""
        self._metrics[name] = fn
        return self

    def set_num_tests(self, n: int) -> "Lat":
        self.num_tests = n
        return self

    # -- exploration -----------------------------------------------------------------

    def _points(self, sample: int | None, seed: int) -> list[dict]:
        names = list(self._vars)
        grid: list[dict] = [{}]
        for n in names:
            grid = [dict(p, **{n: v}) for p in grid for v in self._vars[n]]
        if sample is not None and sample < len(grid):
            rng = random.Random(seed)
            grid = rng.sample(grid, sample)
        return grid

    def tune(self, *, sample: int | None = None, seed: int = 0) -> list[dict]:
        self.results = []
        for point in self._points(sample, seed):
            metrics: dict[str, tuple[float, float]] = {}
            for mname, fn in self._metrics.items():
                vals = [float(fn(**point)) for _ in range(self.num_tests)]
                mean = sum(vals) / len(vals)
                var = sum((v - mean) ** 2 for v in vals) / max(len(vals) - 1, 1)
                metrics[mname] = (mean, var**0.5)
            self.results.append({"knobs": point, "metrics": metrics})
        return self.results

    # -- export -----------------------------------------------------------------------

    def to_csv(self, path: str) -> None:
        if not self.results:
            return
        knob_names = list(self.results[0]["knobs"])
        metric_names = list(self.results[0]["metrics"])
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(knob_names + [f"{m}_{s}" for m in metric_names for s in ("mean", "std")])
            for row in self.results:
                vals = [row["knobs"][k] for k in knob_names]
                for m in metric_names:
                    vals += list(row["metrics"][m])
                w.writerow(vals)
