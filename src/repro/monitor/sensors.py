"""Host-level step sensors + the step-wrapper combinators the aspects weave.

A step wrapper has signature  wrap(step_fn, info) -> step_fn  where `info`
is a mutable dict the runtime shares with wrappers and the autotuner:
  tokens_per_step, flops_per_step, knobs (current values), timings, ...
Wrappers compose in weave order (innermost first).
"""

from __future__ import annotations

import time
from typing import Any, Callable

import jax


def _block(out):
    try:
        return jax.block_until_ready(out)
    except Exception:
        return out


def sensor_wrapper(broker, topic: str, sensors=("time", "throughput", "power")):
    """Publish step time / throughput / modeled power to the ExaMon broker."""

    def wrap(step_fn: Callable, info: dict[str, Any]) -> Callable:
        from repro.power.rapl import RAPLModel

        model: RAPLModel = info.setdefault("rapl_model", RAPLModel())

        def wrapped(*args, **kw):
            t0 = time.perf_counter()
            out = _block(step_fn(*args, **kw))
            dt = time.perf_counter() - t0
            host = info.get("host", 0)
            if "time" in sensors:
                broker.publish(f"{topic}/step_time/@host{host}", dt)
            if "throughput" in sensors and info.get("tokens_per_step"):
                broker.publish(f"{topic}/throughput/@host{host}",
                               info["tokens_per_step"] / dt)
            if "power" in sensors:
                flops = info.get("flops_per_step", 0.0)
                util = min((flops / dt) / model.peak_flops, 1.0) if flops else 0.3
                freq = float(info.get("freq", 1.0))
                broker.publish(f"{topic}/power/@host{host}", model.power(util, freq))
            info["last_step_time"] = dt
            return out

        return wrapped

    return wrap


def timing_wrapper(label_from_knob: str | None = None):
    """Per-version timing (the paper's Timer.time around each switch case)."""

    def wrap(step_fn: Callable, info: dict[str, Any]) -> Callable:
        timings = info.setdefault("timings", {})

        def wrapped(*args, **kw):
            t0 = time.perf_counter()
            out = _block(step_fn(*args, **kw))
            dt = time.perf_counter() - t0
            label = "step"
            if label_from_knob:
                label = str(info.get("knobs", {}).get(label_from_knob, "__default__"))
            timings.setdefault(label, []).append(dt)
            return out

        return wrapped

    return wrap


def memo_wrapper(table):
    """Request-level memoization for pure serve steps (paper Fig. 8)."""

    def wrap(step_fn: Callable, info: dict[str, Any]) -> Callable:
        def wrapped(*args, **kw):
            if not table.running:
                return step_fn(*args, **kw)
            key = (args, tuple(sorted(kw.items())) if kw else ())
            hit, value = table.lookup(key)
            if hit:
                info["memo_hit"] = True
                return value
            info["memo_hit"] = False
            out = step_fn(*args, **kw)
            table.update(key, out)
            return out

        return wrapped

    return wrap


def powercap_wrapper(capper, priority: int):
    """Register with the PowerCapper; apply its frequency decision as a
    modeled slowdown (CPU container: DVFS is simulated, control loop real)."""

    def wrap(step_fn: Callable, info: dict[str, Any]) -> Callable:
        task_id = capper.register(info.get("task_name", "step"), priority)

        def wrapped(*args, **kw):
            freq = capper.frequency(task_id)
            info["freq"] = freq
            t0 = time.perf_counter()
            out = _block(step_fn(*args, **kw))
            dt = (time.perf_counter() - t0) / max(freq, 1e-3)  # modeled DVFS slowdown
            from repro.power.rapl import RAPLModel

            model: RAPLModel = info.setdefault("rapl_model", RAPLModel())
            flops = info.get("flops_per_step", 0.0)
            util = min((flops / dt) / model.peak_flops, 1.0) if flops else 0.3
            capper.report(task_id, model.power(util, freq))
            info["modeled_step_time"] = dt
            return out

        return wrapped

    return wrap


def apply_wrappers(step_fn: Callable, wrappers, info: dict[str, Any]) -> Callable:
    for w in wrappers:
        step_fn = w(step_fn, info)
    return step_fn
