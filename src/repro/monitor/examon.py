"""ExaMon (paper §2.6): pub/sub monitoring broker.

Sensors publish (topic, value, timestamp); the broker fans messages out to
subscribers; `ExamonCollector` keeps a windowed internal state queryable
asynchronously (get / mean / max / p50 / p95) — the Collector API the LARA
aspects embed.  Multi-host aggregation tags topics with the process index
(`topic/@hostN`), mirroring the paper's sensing agents + broker topology.
"""

from __future__ import annotations

import fnmatch
import threading
import time
from collections import deque
from typing import Any, Callable


class ExamonBroker:
    def __init__(self):
        self._subs: list[tuple[str, Callable[[str, float, float], None]]] = []
        self._lock = threading.Lock()
        self.messages = 0

    def publish(self, topic: str, value: float, timestamp: float | None = None) -> None:
        ts = time.monotonic() if timestamp is None else timestamp
        with self._lock:
            subs = list(self._subs)
            self.messages += 1
        for pattern, cb in subs:
            if fnmatch.fnmatch(topic, pattern):
                cb(topic, float(value), ts)

    def subscribe(self, pattern: str, callback: Callable[[str, float, float], None]) -> None:
        with self._lock:
            self._subs.append((pattern, callback))

    def unsubscribe(self, callback) -> None:
        with self._lock:
            self._subs = [(p, cb) for p, cb in self._subs if cb is not callback]


_DEFAULT_BROKER: ExamonBroker | None = None


def get_default_broker() -> ExamonBroker:
    global _DEFAULT_BROKER
    if _DEFAULT_BROKER is None:
        _DEFAULT_BROKER = ExamonBroker()
    return _DEFAULT_BROKER


class ExamonCollector:
    """Windowed stats over one topic pattern (the Collector API)."""

    def __init__(self, name: str, topic: str, *, window: int = 256):
        self.name = name
        self.topic = topic
        self.window = window
        self._values: deque[float] = deque(maxlen=window)
        self._times: deque[float] = deque(maxlen=window)
        self._lock = threading.Lock()
        self._broker: ExamonBroker | None = None
        self._cb = self._on_message  # stable bound-method identity

    # lifecycle (paper: init/start/end/clean woven around the function body)
    def init(self, broker: ExamonBroker) -> "ExamonCollector":
        self._broker = broker
        return self

    def start(self) -> None:
        assert self._broker is not None, "init() first"
        self._broker.subscribe(self.topic, self._cb)

    def end(self) -> None:
        if self._broker is not None:
            self._broker.unsubscribe(self._cb)

    def clean(self) -> None:
        with self._lock:
            self._values.clear()
            self._times.clear()

    def _on_message(self, topic: str, value: float, ts: float) -> None:
        with self._lock:
            self._values.append(value)
            self._times.append(ts)

    # queries
    def get(self, default: float = 0.0) -> float:
        with self._lock:
            return self._values[-1] if self._values else default

    def get_mean(self) -> float:
        with self._lock:
            return sum(self._values) / len(self._values) if self._values else 0.0

    def get_max(self) -> float:
        with self._lock:
            return max(self._values) if self._values else 0.0

    def get_percentile(self, q: float) -> float:
        with self._lock:
            if not self._values:
                return 0.0
            vals = sorted(self._values)
            idx = min(int(q / 100.0 * len(vals)), len(vals) - 1)
            return vals[idx]

    def count(self) -> int:
        with self._lock:
            return len(self._values)
