"""Fault-tolerant checkpointing: sharded, async, atomic, reshardable.

Layout:  <dir>/ckpt_<step>/ {manifest.json, arrays/<flatkey>.npy}
Atomicity: writes land in ckpt_<step>.tmp.<pid>, manifest last, then one
os.replace — a crash mid-write can never corrupt the latest checkpoint.
Async: values are device_get-snapshotted synchronously (consistency), disk
I/O happens on a daemon thread (training continues).
Elasticity: arrays are stored unsharded per host slice; `restore` returns
numpy and `place` device_puts onto *any* mesh/sharding — restoring onto a
different mesh shape is the elastic-rescale path (tested).
Multi-host: each process writes arrays/<key>.proc<k>.npy for its addressable
shards; at process_count==1 this degenerates to full arrays.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import uuid
from typing import Any, Callable

import jax
import ml_dtypes
import numpy as np

_SEP = "::"

# numpy cannot natively serialize bfloat16 (np.save round-trips it as raw
# void bytes) — store a uint16 view + the dtype name in the manifest.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _to_savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name if arr.dtype.names is None else str(arr.dtype)
    for dname, (dt, view) in _EXOTIC.items():
        if arr.dtype == dt:
            return arr.view(view), dname
    return arr, name


def _from_savable(arr: np.ndarray, dname: str) -> np.ndarray:
    if dname in _EXOTIC:
        return arr.view(_EXOTIC[dname][0])
    return arr


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        flat[key] = leaf
    return flat


def _unflatten(template, flat: dict[str, Any]):
    def rebuild(path, _leaf):
        key = _SEP.join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        return flat[key]

    return jax.tree_util.tree_map_with_path(rebuild, template)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------------

    def save(self, step: int, tree: Any, *, meta: dict | None = None,
             blocking: bool = False) -> None:
        """Snapshot now; write async unless blocking."""
        host = {}
        dtypes = {}
        for k, v in _flatten(tree).items():
            arr, dname = _to_savable(np.asarray(jax.device_get(v)))
            host[k] = arr
            dtypes[k] = dname
        manifest = {
            "step": int(step),
            "time": time.time(),
            "process_index": jax.process_index(),
            "process_count": jax.process_count(),
            "keys": sorted(host),
            "dtypes": dtypes,
            "meta": meta or {},
        }
        self.wait()
        if self.async_save and not blocking:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, manifest), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host, manifest)

    def _write(self, step: int, host: dict, manifest: dict) -> None:
        try:
            final = os.path.join(self.directory, f"ckpt_{step}")
            # pid alone is not unique: two writers in one process (e.g. an
            # async save overlapping a blocking one) must not share a tmp dir
            tmp = f"{final}.tmp.{os.getpid()}.{uuid.uuid4().hex[:8]}"
            arrays = os.path.join(tmp, "arrays")
            os.makedirs(arrays, exist_ok=True)
            suffix = (
                f".proc{manifest['process_index']}"
                if manifest["process_count"] > 1
                else ""
            )
            for key, arr in host.items():
                np.save(os.path.join(arrays, f"{key}{suffix}.npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()
        except Exception as e:  # pragma: no cover - surfaced via last_error
            self.last_error = e

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"ckpt_{s}"), ignore_errors=True)

    # -- restore -----------------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("ckpt_") and ".tmp" not in name:
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None) -> tuple[Any, dict]:
        """Returns (numpy pytree shaped like template, manifest meta)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        base = os.path.join(self.directory, f"ckpt_{step}")
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = os.path.join(base, "arrays")
        flat = {}
        dtypes = manifest.get("dtypes", {})
        for key in _flatten(template):
            path = os.path.join(arrays, f"{key}.npy")
            if not os.path.exists(path):
                path = os.path.join(arrays, f"{key}.proc{jax.process_index()}.npy")
            flat[key] = _from_savable(np.load(path), dtypes.get(key, ""))
        return _unflatten(template, flat), manifest

    @staticmethod
    def place(tree_np: Any, shardings: Any):
        """Elastic placement: device_put numpy onto any mesh/shardings."""
        return jax.tree.map(
            lambda arr, sh: jax.device_put(arr, sh), tree_np, shardings
        )
