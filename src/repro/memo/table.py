"""Memoization table (paper §2.4, Figs. 8–9).

Faithful to the paper's surface: bounded table, replacement policy on
collision (Replace flag), approximate float keys (drop `approx` mantissa
bits), persistence (fileToLoad/FileToSave), a fully-offline mode (lookup
only, never update), and a runtime stop/run toggle exposed to the autotuner.

Keys may be scalars, strings, tuples, numpy arrays or jax arrays; values are
arbitrary pytrees (stored by reference; callers must not mutate).
"""

from __future__ import annotations

import hashlib
import pickle
from collections import OrderedDict
from typing import Any

import numpy as np


def _quantize(x: np.ndarray, approx_bits: int) -> np.ndarray:
    """Drop `approx_bits` mantissa bits of float32 keys (paper's 'approx')."""
    if approx_bits <= 0 or not np.issubdtype(x.dtype, np.floating):
        return x
    xi = np.ascontiguousarray(x, dtype=np.float32).view(np.uint32)
    mask = np.uint32(0xFFFFFFFF) << np.uint32(approx_bits)
    return (xi & mask).view(np.float32)


class MemoTable:
    def __init__(
        self,
        *,
        size: int = 65536,
        replace: bool = True,
        approx_bits: int = 0,
        load_path: str | None = None,
        save_path: str | None = None,
        full_offline: bool = False,
    ):
        self.size = size
        self.replace = replace
        self.approx_bits = approx_bits
        self.save_path = save_path
        self.full_offline = full_offline
        self.running = True  # the paper's dynamic stop/run knob
        self.hits = 0
        self.misses = 0
        self._data: OrderedDict[str, Any] = OrderedDict()
        if load_path:
            self.load(load_path)

    # -- keys -----------------------------------------------------------------

    def key_of(self, key: Any) -> str:
        h = hashlib.blake2b(digest_size=16)

        def feed(obj):
            if isinstance(obj, (bytes, str)):
                h.update(obj.encode() if isinstance(obj, str) else obj)
            elif isinstance(obj, (int, bool)):
                h.update(str(obj).encode())
            elif isinstance(obj, float):
                h.update(_quantize(np.asarray(obj, np.float32), self.approx_bits).tobytes())
            elif isinstance(obj, (tuple, list)):
                for o in obj:
                    feed(o)
            elif isinstance(obj, dict):
                for k in sorted(obj):
                    feed(k)
                    feed(obj[k])
            elif obj is None:
                h.update(b"\0")
            else:  # array-like (numpy / jax)
                arr = np.asarray(obj)
                h.update(str(arr.dtype).encode() + str(arr.shape).encode())
                h.update(_quantize(arr, self.approx_bits).tobytes())

        feed(key)
        return h.hexdigest()

    # -- core ops ----------------------------------------------------------------

    def lookup(self, key: Any) -> tuple[bool, Any]:
        k = self.key_of(key)
        if k in self._data:
            self.hits += 1
            self._data.move_to_end(k)  # LRU refresh
            return True, self._data[k]
        self.misses += 1
        return False, None

    def update(self, key: Any, value: Any) -> None:
        if self.full_offline or not self.running:
            return
        k = self.key_of(key)
        if k in self._data:
            if self.replace:
                self._data[k] = value
                self._data.move_to_end(k)
            return
        if len(self._data) >= self.size:
            if not self.replace:
                return
            self._data.popitem(last=False)  # evict LRU
        self._data[k] = value

    def wrap(self, fn):
        """The paper's foo_wrapper (Fig. 8)."""

        def wrapper(*args):
            if not self.running:
                return fn(*args)
            hit, value = self.lookup(args)
            if hit:
                return value
            value = fn(*args)
            self.update(args, value)
            return value

        wrapper.__wrapped__ = fn
        wrapper.table = self
        return wrapper

    # -- stats / persistence --------------------------------------------------------

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self):
        return len(self._data)

    def save(self, path: str | None = None) -> None:
        path = path or self.save_path
        if not path:
            return
        with open(path, "wb") as f:
            pickle.dump(dict(self._data), f)

    def load(self, path: str) -> None:
        try:
            with open(path, "rb") as f:
                self._data = OrderedDict(pickle.load(f))
        except FileNotFoundError:
            pass
