"""libVC-JAX (paper §2.3, [14]): dynamic generation, versioning and dispatch
of multiple compiled versions of the same compute kernel/step.

A `Version` = (variant name -> builder) AOT-compiled via
jit(...).lower(specs).compile() and cached by (variant, shape-key).  The
dispatcher switches versions at call time from a knob value — the woven
replacement for the paper's generated C switch (Fig. 6) — with no
recompilation on the hot path.  Error strategies mirror libVC:
"exit" raises, "fallback" silently uses the default version.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable


@dataclasses.dataclass
class CompiledVersion:
    name: str
    fn: Callable
    compile_seconds: float
    meta: dict = dataclasses.field(default_factory=dict)


class LibVC:
    def __init__(
        self,
        builder: Callable[[str], Callable],
        *,
        default: str = "__default__",
        error_strategy: str = "exit",  # exit | fallback
        log: Callable[[str], None] | None = None,
    ):
        """builder(variant_name) -> ready-to-call (already compiled) callable,
        or a callable to be wrapped lazily."""
        self._builder = builder
        self.default = default
        self.error_strategy = error_strategy
        self._log = log or (lambda msg: None)
        self.versions: dict[str, CompiledVersion] = {}
        self.dispatch_counts: dict[str, int] = {}

    # -- compilation --------------------------------------------------------------

    def compile(self, name: str) -> CompiledVersion:
        if name in self.versions:
            return self.versions[name]
        t0 = time.perf_counter()
        try:
            fn = self._builder(name)
        except Exception as e:
            self._log(f"libvc: compile failed for {name!r}: {e}")
            if self.error_strategy == "fallback" and name != self.default:
                return self.compile(self.default)
            raise
        dt = time.perf_counter() - t0
        cv = CompiledVersion(name, fn, dt)
        self.versions[name] = cv
        self._log(f"libvc: compiled {name!r} in {dt:.2f}s")
        return cv

    def compile_all(self, names) -> None:
        for n in names:
            self.compile(n)

    # -- dispatch --------------------------------------------------------------------

    def __call__(self, version: str | None, *args, **kw):
        name = version or self.default
        if name not in self.versions:
            cv = self.compile(name)
        else:
            cv = self.versions[name]
        self.dispatch_counts[cv.name] = self.dispatch_counts.get(cv.name, 0) + 1
        return cv.fn(*args, **kw)

    def get(self, version: str | None) -> Callable:
        return self.compile(version or self.default).fn

    def stats(self) -> dict:
        return {
            "versions": {n: v.compile_seconds for n, v in self.versions.items()},
            "dispatch_counts": dict(self.dispatch_counts),
        }
