"""Fleet launcher: N serving replicas + hot spares on one process
(reduced-config CPU demo of `runtime/fleet.ServingFleet`).

    PYTHONPATH=src python -m repro.launch.fleet --replicas 2 --spares 1 \
        --requests 8 --shared-prefix 8

Scenario flags drive the resilience machinery end to end:
  --kill-at V    schedule a replica loss at the V-th wave dispatch (the
                 victim's incomplete requests re-dispatch to survivors);
  --drain HOST   SIGTERM-drain replica HOST mid-wave (in-flight work
                 finishes, the waiting queue hands off to peers);
  --deadline-s   per-request fleet deadline (overdue requests retire
                 with partial output as deadline_exceeded).

A real SIGTERM to this process drains replica 0 gracefully before the
wave (PreemptionHandler), mirroring the per-replica drain path.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.core.strategies.resilience import FaultInjector
from repro.distributed.fault import PreemptionHandler
from repro.launch.weave import default_weave
from repro.models.registry import ARCHS
from repro.runtime.fleet import ServingFleet
from repro.runtime.server import Server, ServerConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="yi-6b")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--spares", type=int, default=1)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--shared-prefix", type=int, default=8,
                    help="tokens of shared system prompt (prefix-affinity "
                         "routing keys on these)")
    ap.add_argument("--decode-tokens", type=int, default=5)
    ap.add_argument("--wave-size", type=int, default=3)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--kill-at", type=int, default=None, metavar="V",
                    help="inject a replica loss at wave-dispatch visit V")
    ap.add_argument("--drain", type=int, default=None, metavar="HOST",
                    help="SIGTERM-drain replica HOST mid-wave")
    ap.add_argument("--deadline-s", type=float, default=None)
    args = ap.parse_args()

    program = Program.from_arch(args.arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    cfg = ServerConfig(
        max_cache_len=args.prompt_len + args.decode_tokens + 1,
        decode_tokens=args.decode_tokens, max_batch=args.max_batch,
        page_size=8,
    )

    injector = None
    if args.kill_at is not None:
        injector = FaultInjector.single("replica_loss", "raise",
                                        at=args.kill_at)
    fleet = ServingFleet(lambda: Server(woven, cfg),
                         replicas=args.replicas, spares=args.spares,
                         injector=injector, wave_size=args.wave_size,
                         deadline_s=args.deadline_s)
    if args.drain is not None:
        fleet.request_drain(args.drain)
    preempt = PreemptionHandler(install=True)

    rng = np.random.default_rng(0)
    shared = rng.integers(0, program.cfg.vocab, args.shared_prefix)
    tail = max(0, args.prompt_len - args.shared_prefix)
    prompts = [np.concatenate([
        shared, rng.integers(0, program.cfg.vocab, tail)]).astype(np.int64)
        for _ in range(args.requests)]

    if preempt.pending and args.replicas:
        fleet.request_drain(fleet.replicas[0].host)
    outs = fleet.serve(prompts, decode_tokens=args.decode_tokens)
    stats = fleet.last_fleet_stats

    print(f"fleet: {args.replicas} replica(s) + {args.spares} spare(s), "
          f"{stats['rounds']} round(s)")
    print(f"outcomes: {stats['outcomes']}  redispatched: "
          f"{stats['redispatched']}  affinity hits: "
          f"{stats['affinity_hits']}  prefix-hit replicas: "
          f"{stats['replicas_with_prefix_hits']}")
    for ev in stats["events"]:
        print(f"  event: {ev}")
    for o in fleet.last_outcomes:
        print(f"  rid {o['rid']}: {o['status']:<18} "
              f"replica={o['replica']} attempts={o['attempts']} "
              f"tokens={o['tokens']}"
              + (f"  ({o['reason']})" if o["reason"] else ""))
    n_tokens = sum(len(o) for o in outs)
    print(f"emitted {n_tokens} tokens across {len(outs)} requests")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
