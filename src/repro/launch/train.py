"""Training launcher with restart-on-failure (fault-tolerant outer loop).

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
        --steps 50 --ckpt-dir /tmp/ckpt

On a pod fleet this process runs per host (jax.distributed); here the outer
retry loop + checkpoint restore + elastic resharding are the same code the
fleet would run (exercised by tests/test_fault.py and the fleet simulator).
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.launch.weave import default_weave
from repro.models.registry import ARCHS
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="yi-6b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--mode", default="lcg", choices=("lcg", "uniform", "memmap"))
    ap.add_argument("--max-retries", type=int, default=2)
    ap.add_argument("--set", dest="sets", action="append", default=[])
    args = ap.parse_args()

    overrides = {}
    for kv in args.sets:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = json.loads(v)
        except json.JSONDecodeError:
            overrides[k] = v
    overrides.setdefault("accum_steps", 1)

    program = Program.from_arch(args.arch, kind="train", reduced=args.reduced)
    shape = SHAPES["train_4k"]
    woven = default_weave(program, shape, {}, overrides=overrides)
    pipeline = TokenPipeline(PipelineConfig(
        vocab=program.cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        mode=args.mode,
    ))
    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every)

    # fault-tolerant outer loop: any step-level failure restores the latest
    # checkpoint and resumes (bounded retries)
    attempts = 0
    while True:
        trainer = Trainer(woven, pipeline, tcfg)
        try:
            history = trainer.run(args.steps - trainer.step
                                  if trainer.maybe_restore() else args.steps)
            break
        except Exception as e:  # noqa: BLE001 - launcher-level barrier
            attempts += 1
            print(f"step failure ({e!r}); restart {attempts}/{args.max_retries}")
            if attempts > args.max_retries:
                raise
    if history:
        first, last = history[0], history[-1]
        print(f"loss {first.get('loss'):.4f} -> {last.get('loss'):.4f} over "
              f"{len(history)} steps")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
