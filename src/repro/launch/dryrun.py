import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input shape) cell, on the single-pod (16,16) and
multi-pod (2,16,16) meshes: build the paper-faithful default weave, lower
the step with explicit in_shardings, .compile(), print memory_analysis()
(proves the per-device footprint) and cost_analysis() FLOPs/bytes, parse
the collective schedule, and write the roofline artifact JSON that
EXPERIMENTS.md §Dry-run/§Roofline and benchmarks/roofline_report.py read.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --all --mesh pod # 40-cell baseline table
  ... --set accum_steps=8 --set opt_state_dtype=bfloat16   (hillclimb knobs)
"""

import argparse
import json
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.distributed.sharding import input_shardings, param_shardings
from repro.launch.mesh import make_production_mesh
from repro.launch.weave import default_weave
from repro.models.registry import ARCHS, cells, get_config, input_specs, skipped_cells
from repro.nn.module import abstract_params
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.roofline import analysis
from repro.runtime.steps import (
    build_decode_step,
    build_prefill_step,
    build_train_step,
    step_flops,
)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _parse_set(values: list[str]) -> dict[str, Any]:
    out: dict[str, Any] = {}
    for kv in values or []:
        k, v = kv.split("=", 1)
        try:
            out[k] = json.loads(v)
        except json.JSONDecodeError:
            out[k] = v
    return out


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               overrides: dict[str, Any] | None = None,
               artifact_suffix: str = "", verbose: bool = True,
               roofline: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    chips = mesh.devices.size

    program = Program.from_arch(arch, kind=shape.kind)
    woven = default_weave(program, shape, dict(mesh.shape), overrides=overrides)
    state = woven.state
    rules = state.rules

    params_sds = abstract_params(program.model, state.policies)
    ps_params = param_shardings(program.model, mesh, rules)
    specs = input_specs(cfg, shape)
    ps_inputs = input_shardings(specs["inputs"], mesh, rules)
    repl = NamedSharding(mesh, P())

    t0 = time.perf_counter()
    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            compression=bool(state.extra.get("grad_compression", False)),
            state_dtype=str(state.extra.get("opt_state_dtype", "float32")),
        )
        opt_sds = adamw.abstract_state(params_sds, opt_cfg)
        ps_opt = {
            "master": ps_params,
            "m": ps_params,
            "v": ps_params,
            "count": repl,
        }
        if opt_cfg.compression:
            ps_opt["ef"] = ps_params
        step_fn = build_train_step(woven, mesh=mesh, opt_cfg=opt_cfg)
        jitted = jax.jit(
            step_fn,
            in_shardings=(ps_params, ps_opt, ps_inputs, repl),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_sds, opt_sds, specs["inputs"],
                               jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.kind == "prefill":
        step_fn = build_prefill_step(woven, mesh=mesh)
        jitted = jax.jit(step_fn, in_shardings=(ps_params, ps_inputs))
        lowered = jitted.lower(params_sds, specs["inputs"])
    else:  # decode
        cache_sds = specs["cache"]
        ps_cache = input_shardings(cache_sds, mesh, rules)
        step_fn = build_decode_step(woven, mesh=mesh)
        jitted = jax.jit(
            step_fn,
            in_shardings=(ps_params, ps_inputs, ps_cache),
            donate_argnums=(2,),
        )
        lowered = jitted.lower(params_sds, specs["inputs"], cache_sds)
    lower_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] lower={lower_s:.1f}s "
              f"compile={compile_s:.1f}s")
        print(mem)
        cost = analysis.cost_properties(compiled)
        print({k: cost[k] for k in ("flops", "bytes accessed")
               if k in cost})

    roof = analysis.from_compiled(
        arch, shape_name, mesh_name, chips, compiled,
        model_flops=step_flops(cfg, shape),
    )
    hbm = analysis.hbm_per_device(roof)
    # Analytic TPU HBM estimate: the CPU backend's temp_size carries a
    # structural multiplier (bwd-loop state copies, double buffering, weak
    # elementwise fusion — measured ~10x the ideal boundary stack on a
    # minimal rematted scan), so the v5e fit verdict uses
    #   state (argument bytes, exact) + remat boundary stack + transients.
    accum_used = int(state.extra.get("accum_steps", 1))
    data_shards = 1
    batch_rule = rules.get("batch") or ()
    if isinstance(batch_rule, str):
        batch_rule = (batch_rule,)
    for a in batch_rule:
        if a in mesh.shape:
            data_shards *= mesh.shape[a]
    model_shards = mesh.shape.get("model", 1) if rules.get("res_seq") else 1
    if shape.kind == "train":
        tokens_micro = shape.global_batch * shape.seq_len / max(accum_used, 1)
        n_layers = cfg.num_layers + (cfg.enc_layers if cfg.family == "encdec" else 0)
        boundary = n_layers * tokens_micro * cfg.d_model * 2 / (
            min(data_shards, shape.global_batch // max(accum_used, 1) or 1)
            * model_shards
        )
    else:
        boundary = 0.0
    analytic_hbm = float(roof.memory_per_device["argument"] + boundary + 3 * 2**30)
    hbm_fits = analytic_hbm <= (16 << 30)
    record = roof.to_json()
    record.update({
        "lower_s": lower_s, "compile_s": compile_s,
        "hbm_per_device": hbm,
        "analytic_hbm_per_device": analytic_hbm,
        "hbm_fits_v5e": hbm_fits,
        "accum_steps": state.extra.get("accum_steps", 1),
        "remat": state.extra.get("remat"),
        "rules": {k: list(v) if isinstance(v, tuple) else v
                  for k, v in rules.items()},
        "overrides": overrides or {},
        "ok": True,
    })
    if verbose:
        print(f"  (raw HLO, loop-bodies-once) compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms; "
              f"hbm/dev cpu={hbm/2**30:.2f}GiB "
              f"analytic={analytic_hbm/2**30:.2f}GiB fits_v5e={hbm_fits}")

    if roofline:
        from repro.roofline.components import compose_cell

        record["roofline"] = compose_cell(
            arch, shape_name, multi_pod=multi_pod, overrides=overrides,
            verbose=verbose,
        )

    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}{artifact_suffix}.json"
    with open(os.path.join(ARTIFACT_DIR, fname), "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"), default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--set", dest="sets", action="append", default=[],
                    help="weave override key=value (JSON values)")
    ap.add_argument("--suffix", default="", help="artifact filename suffix")
    args = ap.parse_args()

    overrides = _parse_set(args.sets)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    todo: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCHS:
            for shape in cells(arch):
                todo.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        todo = [(args.arch, args.shape)]

    failures = []
    for arch, shape in todo:
        if shape not in cells(arch):
            print(f"SKIP {arch} x {shape}: not supported (see DESIGN.md §5)")
            continue
        for mp in meshes:
            try:
                lower_cell(arch, shape, multi_pod=mp,
                           overrides=dict(overrides),
                           artifact_suffix=args.suffix)
            except Exception as e:
                failures.append((arch, shape, mp, repr(e)))
                traceback.print_exc()
    for arch, shape, reason in (skipped_cells() if args.all else []):
        print(f"NOTED SKIP {arch} x {shape}: {reason}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print(f"\nDry-run green: {len(todo)} cells x meshes={args.mesh}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
