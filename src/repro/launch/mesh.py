"""Production meshes (assignment-mandated shapes).

single pod : (16, 16)      axes ("data", "model")      = 256 chips
multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

Defined as functions so importing this module never touches jax device
state (the dry-run forces 512 host devices; tests see 1).
"""

from __future__ import annotations

import numpy as np

import jax

try:  # jax >= 0.5 explicit-sharding API; older versions are Auto-only anyway
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _axis_types(n: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — run via "
            "launch/dryrun.py (which forces XLA_FLAGS host device count) or on a pod."
        )
    return jax.make_mesh(
        shape, axes, devices=devices[:need], **_axis_types(len(axes))
    )


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"test mesh {shape} needs {need} devices")
    return jax.make_mesh(
        shape, axes, devices=devices[:need], **_axis_types(len(axes))
    )
