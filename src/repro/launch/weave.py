"""Default (paper-faithful baseline) weave for each (arch x shape x mesh).

This is the aspect stack an ANTAREX HPC expert would start from:
auto-parallelization (AutoShard), remat + gradient accumulation sized for
v5e HBM, mixed bf16 precision, and monitoring.  Hillclimb variants override
pieces via `overrides` (CLI --set / EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Any, Mapping

from repro.configs.base import SHAPES, ShapeConfig
from repro.core.program import Program
from repro.core.strategies.kernels import BlockSizeAspect, TunedKernelAspect
from repro.core.strategies.parallelization import (
    AccumAspect,
    AutoShard,
    RematAspect,
    ShardingAspect,
)
from repro.core.strategies.precision import ChangePrecision
from repro.core.weaver import Aspect, WovenProgram, weave
from repro.runtime.steps import default_accum


def default_weave(
    program: Program,
    shape: ShapeConfig | str,
    mesh_axes: Mapping[str, int],
    *,
    overrides: Mapping[str, Any] | None = None,
    extra_aspects: list[Aspect] | None = None,
) -> WovenProgram:
    if isinstance(shape, str):
        shape = SHAPES[shape]
    overrides = dict(overrides or {})
    train = shape.kind == "train"

    accum = int(overrides.pop("accum_steps",
                              default_accum(program.cfg, shape.kind)))
    # microbatches must keep every data-parallel rank fed (B_micro >= DP)
    dp = 1
    for a in ("pod", "data"):
        dp *= int(mesh_axes.get(a, 1) or 1)
    if program.cfg.family in ("ssm", "hybrid"):
        dp *= int(mesh_axes.get("model", 1) or 1)
    if train and dp > 1:
        accum = max(1, min(accum, shape.global_batch // dp))
    aspects: list[Aspect] = [
        AutoShard(dict(mesh_axes), train=train),
        RematAspect(str(overrides.pop("remat", "full" if train else "none"))),
        AccumAspect(accum),
    ]
    policy = overrides.pop("precision", None)
    if policy:
        aspects.append(ChangePrecision("*", policy))
    rules_override = overrides.pop("rules", None)
    if rules_override:
        aspects.append(ShardingAspect(rules_override))
    # DSE-tuned blocks first (cache lookup only), explicit overrides win.
    if overrides.pop("tuned_kernels", True):
        aspects.append(TunedKernelAspect(shape.global_batch, shape.seq_len))
    block_sizes = {k: int(v) for k, v in list(overrides.items())
                   if k.startswith(("flash_block", "wkv_chunk"))}
    if block_sizes:
        aspects.append(BlockSizeAspect(**block_sizes))
        for k in block_sizes:
            overrides.pop(k)
    if extra_aspects:
        aspects.extend(extra_aspects)

    woven = weave(program, aspects)
    # remaining overrides land in extra verbatim (opt_state_dtype, moe_capacity_factor...)
    for k, v in overrides.items():
        woven.state.extra[k] = v
    return woven
