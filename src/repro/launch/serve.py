"""Serving launcher (reduced-config CPU demo of the serve path).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 4

Four modes:
  (default)     legacy solo `serve()` per request;
  --continuous  one `serve_continuous` wave over the whole request set
                (paged pool, prefix sharing), printing each request's
                structured outcome;
  --stream      drive the `serve_stream` event loop directly, printing
                per-token events with TTFT / inter-token latency columns
                (add --slo-ttft-s / --slo-tok-s to run under the QoS
                governor with those SLOs as mARGOt Goals);
  --fleet N     route the same wave across N `ServingFleet` replicas
                (prefix-affinity routing + replica-loss recovery), see
                `repro.launch.fleet` for the full fleet CLI.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.launch.weave import default_weave
from repro.models.registry import ARCHS
from repro.runtime.server import Server, ServerConfig


def _print_outcomes(outcomes, outputs) -> None:
    for o in outcomes:
        rep = f" replica={o['replica']}" if "replica" in o else ""
        print(f"  rid {o['rid']}: {o['status']:<18} tokens={o['tokens']}"
              f"{rep}" + (f"  ({o['reason']})" if o["reason"] else ""))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="yi-6b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--continuous", action="store_true",
                    help="serve all requests through one continuous-"
                         "batching wave and print structured outcomes")
    ap.add_argument("--stream", action="store_true",
                    help="drive the serve_stream event loop: print "
                         "per-token events with TTFT/inter-token latency")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill tokens per wave (stream mode)")
    ap.add_argument("--slo-ttft-s", type=float, default=None,
                    help="TTFT SLO — enables the QoS governor (stream)")
    ap.add_argument("--slo-tok-s", type=float, default=None,
                    help="inter-token SLO — enables the QoS governor")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="route the wave across N fleet replicas "
                         "(implies --continuous)")
    args = ap.parse_args()

    program = Program.from_arch(args.arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    cfg = ServerConfig(
        max_cache_len=args.prompt_len + args.decode_tokens + 1,
        decode_tokens=args.decode_tokens,
    )
    rng = np.random.default_rng(0)

    if args.fleet > 0:
        from repro.runtime.fleet import ServingFleet

        prompts = [rng.integers(0, program.cfg.vocab, args.prompt_len)
                   .astype(np.int64) for _ in range(args.requests)]
        fleet = ServingFleet(lambda: Server(woven, cfg),
                             replicas=args.fleet)
        outs = fleet.serve(prompts, decode_tokens=args.decode_tokens)
        stats = fleet.last_fleet_stats
        print(f"fleet of {args.fleet}: {stats['outcomes']} in "
              f"{stats['rounds']} round(s); affinity hits "
              f"{stats['affinity_hits']}")
        _print_outcomes(fleet.last_outcomes, outs)
        return 0

    server = Server(woven, cfg)
    if args.stream:
        prompts = [rng.integers(0, program.cfg.vocab, args.prompt_len)
                   .astype(np.int64) for _ in range(args.requests)]
        qos = None
        if args.slo_ttft_s is not None or args.slo_tok_s is not None:
            qos = {}  # governed under DEFAULT_QOS_POLICY + these SLOs
        gen = server.serve_stream(
            prompts, decode_tokens=args.decode_tokens,
            prefill_chunk=args.prefill_chunk, qos=qos,
            slo_ttft_s=args.slo_ttft_s, slo_tok_s=args.slo_tok_s)
        import time as _time

        t_start = _time.perf_counter()
        last_tok: dict[int, float] = {}
        print(f"{'wave':>5} {'event':<14} {'rid':>4} "
              f"{'ttft_ms':>8} {'gap_ms':>7}  detail")
        while True:
            try:
                ev = next(gen)
            except StopIteration as stop:
                outs = stop.value
                break
            kind, rid = ev["event"], ev.get("rid", -1)
            ttft = gap = ""
            if kind == "token":
                if ev["index"] == 0:
                    ttft = f"{1e3 * (ev['t'] - t_start):.1f}"
                elif rid in last_tok:
                    gap = f"{1e3 * (ev['t'] - last_tok[rid]):.1f}"
                last_tok[rid] = ev["t"]
                detail = f"token={ev['token']} index={ev['index']}"
            elif kind == "wave":
                detail = (f"batch={ev['batch']} emitted={ev['emitted']} "
                          f"prefill_tokens={ev['prefill_tokens']} "
                          f"op={ev['op']}")
            else:
                detail = " ".join(f"{k}={v}" for k, v in ev.items()
                                  if k not in ("event", "wave", "t", "rid"))
            print(f"{ev['wave']:>5} {kind:<14} "
                  f"{rid if rid >= 0 else '':>4} {ttft:>8} {gap:>7}  "
                  f"{detail}")
        for o in server.last_outcomes:
            ttft_ms = (f"{1e3 * o['ttft_s']:.1f}ms"
                       if o["ttft_s"] is not None else "-")
            gap_ms = (f"{1e3 * o['tok_gap_max_s']:.1f}ms"
                      if o["tok_gap_max_s"] is not None else "-")
            print(f"  rid {o['rid']}: {o['status']:<18} "
                  f"tokens={o['tokens']} ttft={ttft_ms} max_gap={gap_ms}")
        if server.last_qos_stats is not None:
            q = server.last_qos_stats
            print(f"qos: {q['switches']} OP switch(es), "
                  f"{q['distinct_ops']} distinct OP(s), "
                  f"objective={q['objective']}, "
                  f"energy={q['energy_j']:.1f}J")
        return 0

    if args.continuous:
        prompts = [rng.integers(0, program.cfg.vocab, args.prompt_len)
                   .astype(np.int64) for _ in range(args.requests)]
        outs = server.serve_continuous(
            prompts, decode_tokens=args.decode_tokens)
        print(f"continuous wave: {len(prompts)} request(s), pool "
              f"{server.last_pool_stats['live_pages']} live pages, "
              f"{server.last_pool_stats['prefix_hits']} prefix hits")
        _print_outcomes(server.last_outcomes, outs)
        return 0

    for i in range(args.requests):
        prompt = rng.integers(0, program.cfg.vocab,
                              (args.batch, args.prompt_len), dtype=np.int32)
        out = server.serve(prompt)
        print(f"request {i}: generated {out.shape} in {server.latencies[-1]*1e3:.0f}ms")
    print(f"served {server.served}; p50 latency "
          f"{sorted(server.latencies)[len(server.latencies)//2]*1e3:.0f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
