"""Serving launcher (reduced-config CPU demo of the serve path).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 4

Three modes:
  (default)     legacy solo `serve()` per request;
  --continuous  one `serve_continuous` wave over the whole request set
                (paged pool, prefix sharing), printing each request's
                structured outcome;
  --fleet N     route the same wave across N `ServingFleet` replicas
                (prefix-affinity routing + replica-loss recovery), see
                `repro.launch.fleet` for the full fleet CLI.
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.launch.weave import default_weave
from repro.models.registry import ARCHS
from repro.runtime.server import Server, ServerConfig


def _print_outcomes(outcomes, outputs) -> None:
    for o in outcomes:
        rep = f" replica={o['replica']}" if "replica" in o else ""
        print(f"  rid {o['rid']}: {o['status']:<18} tokens={o['tokens']}"
              f"{rep}" + (f"  ({o['reason']})" if o["reason"] else ""))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="yi-6b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--continuous", action="store_true",
                    help="serve all requests through one continuous-"
                         "batching wave and print structured outcomes")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="route the wave across N fleet replicas "
                         "(implies --continuous)")
    args = ap.parse_args()

    program = Program.from_arch(args.arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    cfg = ServerConfig(
        max_cache_len=args.prompt_len + args.decode_tokens + 1,
        decode_tokens=args.decode_tokens,
    )
    rng = np.random.default_rng(0)

    if args.fleet > 0:
        from repro.runtime.fleet import ServingFleet

        prompts = [rng.integers(0, program.cfg.vocab, args.prompt_len)
                   .astype(np.int64) for _ in range(args.requests)]
        fleet = ServingFleet(lambda: Server(woven, cfg),
                             replicas=args.fleet)
        outs = fleet.serve(prompts, decode_tokens=args.decode_tokens)
        stats = fleet.last_fleet_stats
        print(f"fleet of {args.fleet}: {stats['outcomes']} in "
              f"{stats['rounds']} round(s); affinity hits "
              f"{stats['affinity_hits']}")
        _print_outcomes(fleet.last_outcomes, outs)
        return 0

    server = Server(woven, cfg)
    if args.continuous:
        prompts = [rng.integers(0, program.cfg.vocab, args.prompt_len)
                   .astype(np.int64) for _ in range(args.requests)]
        outs = server.serve_continuous(
            prompts, decode_tokens=args.decode_tokens)
        print(f"continuous wave: {len(prompts)} request(s), pool "
              f"{server.last_pool_stats['live_pages']} live pages, "
              f"{server.last_pool_stats['prefix_hits']} prefix hits")
        _print_outcomes(server.last_outcomes, outs)
        return 0

    for i in range(args.requests):
        prompt = rng.integers(0, program.cfg.vocab,
                              (args.batch, args.prompt_len), dtype=np.int32)
        out = server.serve(prompt)
        print(f"request {i}: generated {out.shape} in {server.latencies[-1]*1e3:.0f}ms")
    print(f"served {server.served}; p50 latency "
          f"{sorted(server.latencies)[len(server.latencies)//2]*1e3:.0f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
