"""Serving launcher (reduced-config CPU demo of the serve path).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 4
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import SHAPES
from repro.core.program import Program
from repro.launch.weave import default_weave
from repro.models.registry import ARCHS
from repro.runtime.server import Server, ServerConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="yi-6b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-tokens", type=int, default=8)
    args = ap.parse_args()

    program = Program.from_arch(args.arch, kind="serve", reduced=True)
    woven = default_weave(program, SHAPES["prefill_32k"], {})
    server = Server(woven, ServerConfig(
        max_cache_len=args.prompt_len + args.decode_tokens + 1,
        decode_tokens=args.decode_tokens,
    ))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, program.cfg.vocab,
                              (args.batch, args.prompt_len), dtype=np.int32)
        out = server.serve(prompt)
        print(f"request {i}: generated {out.shape} in {server.latencies[-1]*1e3:.0f}ms")
    print(f"served {server.served}; p50 latency "
          f"{sorted(server.latencies)[len(server.latencies)//2]*1e3:.0f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
