"""Compositional roofline costing (assignment §Roofline).

XLA's cost_analysis counts while-loop bodies ONCE (verified empirically), so
the scanned production program cannot be costed directly.  Instead we lower
*loop-free components* at true shapes with true shardings and compose:

  train step  = accum x [ sum_b count_b x block_fwdbwd_b  +  outer_fwdbwd ]
                + optimizer_update
                + accum x sum_b count_b x analytic_core_b        (attention / WKV)
  prefill     = sum_b count_b x block_fwd_b + outer_fwd + analytic cores
  decode      = sum_b count_b x block_decode_b + outer_fwd(1 tok)   (no analytic:
                decode attention lowers loop-free and is costed exactly)

Analytic cores cover exactly the ops the woven Pallas kernels implement
(flash attention, WKV) — opaque to cost_analysis by nature, with FLOPs from
first principles and HBM bytes from the kernels' actual HBM traffic
(inputs+outputs only; everything else stays in VMEM).  Collective bytes per
component come from loop-free HLO text (exact), x trip counts.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.core.weaver import WovenProgram
from repro.distributed.sharding import input_shardings, logical_to_pspec, param_shardings
from repro.nn.module import abstract_params
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.roofline import analysis
from repro.roofline.hw import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class ComponentCost:
    name: str
    count: float  # executions per step
    flops: float  # per execution, per device
    bytes: float
    coll_bytes: float
    coll_ops: dict[str, int] = dataclasses.field(default_factory=dict)

    def total(self) -> tuple[float, float, float]:
        return self.count * self.flops, self.count * self.bytes, self.count * self.coll_bytes

    def to_json(self):
        return {
            "name": self.name, "count": self.count, "flops": self.flops,
            "bytes": self.bytes, "coll_bytes": self.coll_bytes,
            "coll_ops": self.coll_ops,
        }


def _cost_of(compiled) -> tuple[float, float, float, dict]:
    cost = analysis.cost_properties(compiled)
    colls = analysis.parse_collectives(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        colls.wire_bytes,
        colls.counts,
    )


def _batch_spec(mesh, rules, rank: int, shape):
    batch = rules.get("batch") or ()
    if isinstance(batch, str):
        batch = (batch,)
    spec = logical_to_pspec(("batch",) + (None,) * (rank - 1),
                            {"batch": tuple(batch)}, mesh, shape)
    return NamedSharding(mesh, spec if spec is not None else P())


def _remat_wrap(fn, extra):
    name = str(extra.get("remat", "none"))
    if name in ("none", None):
        return fn
    from repro.nn.stack import REMAT_POLICIES

    policy_name = REMAT_POLICIES.get(name, "nothing_saveable")
    policy = getattr(jax.checkpoint_policies, policy_name) if policy_name else None
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# Component lowerings
# ---------------------------------------------------------------------------


def block_component(block, mesh, woven, *, B, S, d_model, mode: str,
                    train: bool, cache_sds=None, kwargs_sds=None) -> tuple[float, float, float, dict]:
    state = woven.state
    # attention / wkv cores are costed analytically in dense modes
    impls = list(state.impls)
    if mode != "decode":
        impls += [("*", "attention", "proj_only"), ("*", "wkv", "proj_only")]

    def make_ctx():
        ctx = state.make_ctx(mesh=mesh)
        ctx.impls = impls
        return ctx

    params_sds = abstract_params(block, state.policies)
    ps_params = param_shardings(block, mesh, state.rules)
    x_sds = jax.ShapeDtypeStruct((B, S, d_model), jnp.bfloat16)
    # residual-stream sharding must match production (batch x res_seq)
    spec = logical_to_pspec(("batch", "res_seq", None), state.rules, mesh,
                            x_sds.shape)
    ps_x = NamedSharding(mesh, spec if spec is not None else P())
    pos_sds = jax.ShapeDtypeStruct((B, S), jnp.int32)
    ps_pos = _batch_spec(mesh, state.rules, 2, pos_sds.shape)
    kwargs_sds = kwargs_sds or {}
    ps_kwargs = {k: _batch_spec(mesh, state.rules, len(v.shape), v.shape)
                 for k, v in kwargs_sds.items()}

    if mode == "decode":
        assert cache_sds is not None
        ps_cache = input_shardings(cache_sds, mesh, state.rules)

        def fn(params, x, positions, cache, kw):
            out, new_cache = block(params, x, ctx=make_ctx(), mode="decode",
                                   cache=cache, positions=positions, **kw)
            return out, new_cache

        jitted = jax.jit(fn, in_shardings=(ps_params, ps_x, ps_pos, ps_cache,
                                           ps_kwargs), donate_argnums=(3,))
        lowered = jitted.lower(params_sds, x_sds, pos_sds, cache_sds, kwargs_sds)
    elif train:
        def fwd(params, x, positions, kw):
            out, _ = block(params, x, ctx=make_ctx(), mode="dense",
                           positions=positions, **kw)
            return jnp.sum(out.astype(jnp.float32))

        fwd = _remat_wrap(fwd, state.extra)
        grad_fn = jax.grad(fwd, argnums=(0, 1))
        jitted = jax.jit(grad_fn, in_shardings=(ps_params, ps_x, ps_pos, ps_kwargs))
        lowered = jitted.lower(params_sds, x_sds, pos_sds, kwargs_sds)
    else:  # prefill fwd
        def fn(params, x, positions, kw):
            out, cache = block(params, x, ctx=make_ctx(), mode="prefill",
                               positions=positions, **kw)
            return out, cache

        jitted = jax.jit(fn, in_shardings=(ps_params, ps_x, ps_pos, ps_kwargs))
        lowered = jitted.lower(params_sds, x_sds, pos_sds, kwargs_sds)
    return _cost_of(lowered.compile())


def outer_component(woven, mesh, specs, *, train: bool, mode: str) -> tuple:
    """Embed + final norm + head + loss, trunk skipped (skip_trunk)."""
    program = woven.program
    state = woven.state
    model = program.model

    def make_ctx():
        ctx = state.make_ctx(mesh=mesh)
        ctx.extra = dict(ctx.extra, skip_trunk=True)
        return ctx

    params_sds = abstract_params(model, state.policies)
    ps_params = param_shardings(model, mesh, state.rules)
    inputs_sds = specs["inputs"]
    ps_inputs = input_shardings(inputs_sds, mesh, state.rules)

    if train:
        from repro.runtime.steps import _cross_entropy

        def fwd(params, batch):
            logits, _ = model(params, batch, ctx=make_ctx(), mode="dense")
            loss, _ = _cross_entropy(logits, batch["labels"])
            return loss

        jitted = jax.jit(jax.grad(fwd), in_shardings=(ps_params, ps_inputs))
        lowered = jitted.lower(params_sds, inputs_sds)
    else:
        def fn(params, batch):
            logits, _ = model(params, batch, ctx=make_ctx(), mode=mode)
            return logits

        jitted = jax.jit(fn, in_shardings=(ps_params, ps_inputs))
        lowered = jitted.lower(params_sds, inputs_sds)
    return _cost_of(lowered.compile())


def optimizer_component(woven, mesh) -> tuple:
    state = woven.state
    model = woven.program.model
    opt_cfg = AdamWConfig(
        compression=bool(state.extra.get("grad_compression", False)),
        state_dtype=str(state.extra.get("opt_state_dtype", "float32")),
    )
    params_sds = abstract_params(model, state.policies)
    ps = param_shardings(model, mesh, state.rules)
    opt_sds = adamw.abstract_state(params_sds, opt_cfg)
    repl = NamedSharding(mesh, P())
    ps_opt = {"master": ps, "m": ps, "v": ps, "count": repl}
    if opt_cfg.compression:
        ps_opt["ef"] = ps
    grads_sds = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_sds
    )

    def fn(params, grads, opt_state):
        p, s, _ = adamw.apply_updates(params, grads, opt_state, opt_cfg,
                                      jnp.asarray(1e-4, jnp.float32))
        return p, s

    jitted = jax.jit(fn, in_shardings=(ps, ps, ps_opt), donate_argnums=(0, 2))
    lowered = jitted.lower(params_sds, grads_sds, opt_sds)
    return _cost_of(lowered.compile())


# ---------------------------------------------------------------------------
# Analytic kernel cores (per layer, GLOBAL numbers)
# ---------------------------------------------------------------------------


def _causal_context(S: int, window: int | None) -> float:
    """Mean #KV positions attended per query under causal(+window) masking."""
    if window is None or window >= S:
        return (S + 1) / 2.0
    # positions < window see t+1; the rest see `window`
    head = window * (window + 1) / 2.0
    return (head + (S - window) * window) / S


def attention_core_global(cfg: ModelConfig, B: int, S: int, *, train: bool,
                          mask: str, window: int | None, kv_heads: int | None = None,
                          n_heads: int | None = None) -> tuple[float, float]:
    """(flops, hbm_bytes) global, one layer, dense mode (flash-kernel shape)."""
    H = n_heads or cfg.n_heads
    K = kv_heads or cfg.kv_heads
    D = cfg.resolved_head_dim
    t_eff = _causal_context(S, window) if mask != "full" else float(S)
    fwd_flops = 2 * 2 * B * H * S * t_eff * D  # QK^T + PV
    fwd_bytes = 2 * (2 * B * S * H * D + 2 * B * S * K * D)  # q,o + k,v (bf16)
    if train:  # bwd ~2.5x fwd + full-remat recompute 1x
        return 4.5 * fwd_flops, 4.0 * fwd_bytes
    return fwd_flops, fwd_bytes


def wkv_core_global(cfg: ModelConfig, B: int, S: int, *, train: bool,
                    chunk: int = 32) -> tuple[float, float]:
    H = cfg.d_model // cfg.rwkv_head_dim
    C = cfg.rwkv_head_dim
    tokens = B * S
    fwd_flops = tokens * H * (6 * C * C + 4 * chunk * C)
    fwd_bytes = 2 * 5 * tokens * cfg.d_model  # r,k,v,w in + y out (bf16)
    if train:
        return 4.5 * fwd_flops, 4.0 * fwd_bytes
    return fwd_flops, fwd_bytes


# ---------------------------------------------------------------------------
# Cell composition
# ---------------------------------------------------------------------------


def compose_cell(arch: str, shape_name: str, *, multi_pod: bool,
                 overrides: dict | None = None, verbose: bool = True) -> dict:
    from repro.core.program import Program
    from repro.launch.mesh import make_production_mesh
    from repro.launch.weave import default_weave
    from repro.models.registry import get_config, input_specs
    from repro.runtime.steps import step_flops

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    chips = mesh.devices.size

    program = Program.from_arch(arch, kind=shape.kind)
    woven = default_weave(program, shape, dict(mesh.shape), overrides=overrides)
    state = woven.state
    model = program.model
    train = shape.kind == "train"
    accum = int(state.extra.get("accum_steps", 1)) if train else 1
    B_micro = shape.global_batch // accum
    S = shape.seq_len
    mode = shape.kind if shape.kind != "train" else "dense"
    dec = shape.kind == "decode"
    B_blk, S_blk = (shape.global_batch, 1) if dec else (B_micro, S)

    comps: list[ComponentCost] = []
    blocks = model.component_blocks(shape.global_batch, S)
    for name, block, count, cache_sds, kwargs in blocks:
        if dec and cache_sds is None:
            continue  # cache-less blocks (enc-dec encoder) do not run at decode
        # kwargs leaves (e.g. enc-dec kv_src) follow the block's batch dim
        kwargs_sds = {
            k: jax.ShapeDtypeStruct((B_blk,) + v.shape[1:], v.dtype)
            for k, v in dict(kwargs).items()
        }
        f, b, c, ops = block_component(
            block, mesh, woven, B=B_blk, S=S_blk, d_model=cfg.d_model,
            mode="decode" if dec else ("dense" if train else "prefill"),
            train=train, cache_sds=cache_sds if dec else None,
            kwargs_sds=kwargs_sds,
        )
        # loop-free lowerings let XLA CSE the remat recompute away; the
        # production scan re-executes the forward during backward, so apply
        # the analytic remat factor (fwd+bwd 6 units -> +2 recompute = 8/6).
        if train and str(state.extra.get("remat", "full")) == "full":
            f *= 8.0 / 6.0
            b *= 8.0 / 6.0
            c *= 8.0 / 6.0
        comps.append(ComponentCost(name, count * accum, f, b, c, ops))

    spec_shape = shape
    specs = input_specs(cfg, spec_shape)
    if train and accum > 1:
        micro = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((B_micro,) + s.shape[1:], s.dtype),
            specs["inputs"],
        )
        specs = {"inputs": micro, "cache": None}
    f, b, c, ops = outer_component(woven, mesh, specs, train=train,
                                   mode="decode" if dec else mode)
    comps.append(ComponentCost("outer", accum, f, b, c, ops))

    if train:
        f, b, c, ops = optimizer_component(woven, mesh)
        comps.append(ComponentCost("optimizer", 1, f, b, c, ops))

    # analytic kernel cores (global -> per device)
    if not dec:
        if cfg.family in ("dense", "moe", "vlm"):
            fl, by = attention_core_global(
                cfg, shape.global_batch, S, train=train,
                mask="causal", window=cfg.attn_window,
            )
            comps.append(ComponentCost("attn_core", cfg.num_layers,
                                       fl / chips, by / chips, 0.0))
        elif cfg.family == "hybrid":
            pat = cfg.block_pattern or ("rec", "rec", "attn")
            n_att = sum(1 for i in range(cfg.num_layers)
                        if pat[i % len(pat)] == "attn")
            fl, by = attention_core_global(cfg, shape.global_batch, S,
                                           train=train, mask="local",
                                           window=cfg.local_window)
            comps.append(ComponentCost("attn_core", n_att, fl / chips,
                                       by / chips, 0.0))
        elif cfg.family == "encdec":
            fl_e, by_e = attention_core_global(cfg, shape.global_batch, S,
                                               train=train, mask="full",
                                               window=None)
            fl_s, by_s = attention_core_global(cfg, shape.global_batch, S,
                                               train=train, mask="causal",
                                               window=None)
            n = cfg.enc_layers or cfg.num_layers
            comps.append(ComponentCost("enc_attn_core", n, fl_e / chips,
                                       by_e / chips, 0.0))
            # decoder: causal self + full cross
            comps.append(ComponentCost("dec_attn_core", cfg.num_layers,
                                       (fl_s + fl_e) / chips,
                                       (by_s + by_e) / chips, 0.0))
        elif cfg.family == "ssm":
            fl, by = wkv_core_global(cfg, shape.global_batch, S, train=train,
                                     chunk=int(state.extra.get("wkv_chunk", 32)))
            comps.append(ComponentCost("wkv_core", cfg.num_layers, fl / chips,
                                       by / chips, 0.0))

    tot_f = sum(c.total()[0] for c in comps)
    tot_b = sum(c.total()[1] for c in comps)
    tot_c = sum(c.total()[2] for c in comps)
    model_flops = step_flops(cfg, shape)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "method": "compositional",
        "components": [c.to_json() for c in comps],
        "flops_per_device": tot_f,
        "bytes_per_device": tot_b,
        "collective_bytes_per_device": tot_c,
        "model_flops": model_flops,
        "compute_s": tot_f / PEAK_FLOPS_BF16,
        "memory_s": tot_b / HBM_BW,
        "collective_s": tot_c / ICI_LINK_BW,
        "accum_steps": accum,
        "overrides": overrides or {},
    }
    terms = {"compute": result["compute_s"], "memory": result["memory_s"],
             "collective": result["collective_s"]}
    result["bottleneck"] = max(terms, key=terms.get)
    result["step_s"] = max(terms.values())
    hlo_global = tot_f * chips
    result["useful_ratio"] = model_flops / hlo_global if hlo_global else 0.0
    result["roofline_fraction"] = (
        model_flops / (chips * PEAK_FLOPS_BF16 * result["step_s"])
        if result["step_s"] else 0.0
    )
    if verbose:
        print(f"[roofline {arch} x {shape_name} x {mesh_name}] "
              f"compute={result['compute_s']*1e3:.2f}ms "
              f"memory={result['memory_s']*1e3:.2f}ms "
              f"collective={result['collective_s']*1e3:.2f}ms "
              f"-> {result['bottleneck']}-bound "
              f"useful={result['useful_ratio']:.2f} "
              f"frac={result['roofline_fraction']:.3f}")
    return result
