"""Roofline analysis from compiled dry-run artifacts (assignment §Roofline).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = global_HLO_FLOPs / (chips x 197 TF/s)
  memory     = global_HLO_bytes / (chips x 819 GB/s)
  collective = per-chip collective wire bytes / 50 GB/s/link

`cost_analysis()` reports the per-device SPMD program, so global = x chips.
Collective bytes are NOT in cost_analysis: we parse the optimized HLO text
and apply standard ring-algorithm wire accounting per op:

  all-reduce        2 * size * (n-1)/n
  all-gather        out_size * (n-1)/n
  reduce-scatter    out_size * (n-1)
  all-to-all        size * (n-1)/n
  collective-permute size

where n is the replica-group size (both explicit {{...}} and iota [g,n]<=[N]
formats are parsed).  Async -start/-done pairs are counted once.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

from repro.roofline.hw import DTYPE_BYTES, HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16

def cost_properties(compiled) -> dict:
    """Normalized `compiled.cost_analysis()`: newer jax returns a dict,
    older versions a one-element list of dicts."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")


def _tensor_bytes(result: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2  # conservative default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    wire_bytes: float  # per device
    by_op: dict[str, float]

    def total_ops(self) -> int:
        return sum(self.counts.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    by_op: dict[str, float] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if f"{m.group('op')}-done" in line:
            continue
        op = m.group("op")
        size = _tensor_bytes(m.group("result"))
        n = _group_size(line)
        if n <= 1:
            continue
        if op == "all-reduce":
            wire = 2.0 * size * (n - 1) / n
        elif op == "all-gather":
            wire = size * (n - 1) / n
        elif op == "reduce-scatter":
            wire = size * (n - 1)
        elif op == "all-to-all":
            wire = size * (n - 1) / n
        else:  # collective-permute
            wire = float(size)
        counts[op] = counts.get(op, 0) + 1
        by_op[op] = by_op.get(op, 0.0) + wire
        total += wire
    return CollectiveStats(counts, total, by_op)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_counts: dict[str, int]
    collective_by_op: dict[str, float]
    model_flops: float  # 6·N_active·D (global, per step)
    memory_per_device: dict[str, float]  # from memory_analysis

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (remat/dispatch waste detector)."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the bound: useful
        FLOPs / (chips x peak x step_s)."""
        denom = self.chips * PEAK_FLOPS_BF16 * self.step_s
        return self.model_flops / denom if denom else 0.0

    def to_json(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_counts": self.collective_counts,
            "collective_by_op": self.collective_by_op,
            "model_flops": self.model_flops,
            "memory_per_device": self.memory_per_device,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck, "step_s": self.step_s,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(arch: str, shape: str, mesh_name: str, chips: int,
                  compiled, model_flops: float) -> Roofline:
    cost = cost_properties(compiled)
    mem = compiled.memory_analysis()
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=colls.wire_bytes,
        collective_counts=colls.counts,
        collective_by_op=colls.by_op,
        model_flops=model_flops,
        memory_per_device={
            "argument": float(mem.argument_size_in_bytes),
            "output": float(mem.output_size_in_bytes),
            "temp": float(mem.temp_size_in_bytes),
            "alias": float(mem.alias_size_in_bytes),
            "code": float(mem.generated_code_size_in_bytes),
        },
    )


def hbm_per_device(r: Roofline) -> float:
    m = r.memory_per_device
    return m["argument"] + m["output"] + m["temp"] - m["alias"]
