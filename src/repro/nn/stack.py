"""Scan-over-layers stack: O(1) HLO size in depth, weavable per group.

`ScannedStack` stacks a homogeneous block's parameters with a leading
"layers" axis and applies them with `lax.scan`, optionally under
`jax.checkpoint` (the woven remat policy).  Decode caches / recurrent states
ride along as per-layer scan inputs/outputs.

Joinpoint view: the stack exposes its *template* block (one joinpoint stands
for all layers in the group).  Models that need per-layer-group weaving
split the trunk into several ScannedStack groups (see configs.layer_groups).
"""

from __future__ import annotations

from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.nn.module import Ctx, Module, ParamSpec, _walk_spec

REMAT_POLICIES = {
    "none": None,  # no remat
    "full": "nothing_saveable",
    "dots": "dots_saveable",
    "dots_no_batch": "dots_with_no_batch_dims_saveable",
}


def _stack_specs(tree: Any, n: int) -> Any:
    """Add a leading (n, ...) 'layers' dim to every ParamSpec leaf."""

    def leaf(spec: ParamSpec, path: str) -> ParamSpec:
        return ParamSpec(
            shape=(n, *spec.shape),
            axes=("layers", *spec.axes),
            init=spec.init,
            scale=spec.scale,
            dtype=spec.dtype,
        )

    return _walk_spec(tree, "", leaf)


class ScannedStack(Module):
    kind = "stack"

    def __init__(self, name: str, block: Module, n_layers: int):
        self.name = name
        self.block = block
        self.n_layers = n_layers

    def spec(self):
        return {self.block.name: _stack_specs(self.block, self.n_layers)}

    def walk(self, prefix: str = "") -> Iterator[tuple[str, Module]]:
        path = f"{prefix}/{self.name}" if prefix else self.name
        yield path, self
        yield from self.block.walk(path)

    def __call__(
        self,
        params,
        x,
        *,
        ctx: Ctx,
        mode: str = "dense",
        cache: Any = None,  # per-layer pytree with leading n_layers dim
        positions=None,
        block_kwargs: dict | None = None,
    ):
        with ctx.scope(self.name):
            stacked = params[self.block.name]
            block_kwargs = dict(block_kwargs or {})

            # Taps inside a scan body would leak tracers — disable within.
            saved_taps = ctx.taps_enabled
            ctx.taps_enabled = []

            # Pin each iteration's layer params to their sharded layout so
            # GSPMD keeps FSDP all-gathers *inside* the loop (otherwise XLA
            # hoists a loop-invariant gather of the whole stacked params —
            # bf16_params/TP bytes of HBM, fatal for the >=70B trains).
            layer_shardings = None
            if ctx.mesh is not None and ctx.rules:
                from repro.distributed.sharding import param_shardings

                layer_shardings = param_shardings(self.block, ctx.mesh, ctx.rules)

            remat_name = str(ctx.extra.get("remat", "full" if mode == "dense" else "none"))
            use_remat = mode == "dense" and remat_name != "none"

            def body(carry, layer_in):
                h = carry
                if use_remat and remat_name == "full":
                    # name the (bf16) boundary so save_only_these_names keeps
                    # exactly this tensor — without it, partial-eval saves a
                    # post-upcast fp32 copy of the residual per layer (2x the
                    # boundary memory; observed on the 72B train cell)
                    from jax.ad_checkpoint import checkpoint_name

                    h = checkpoint_name(h, "layer_boundary")
                layer_params, layer_cache = layer_in
                if layer_shardings is not None:
                    layer_params = jax.tree.map(
                        jax.lax.with_sharding_constraint, layer_params,
                        layer_shardings,
                    )
                out, new_cache = self.block(
                    layer_params, h, ctx=ctx, mode=mode, cache=layer_cache,
                    positions=positions, **block_kwargs,
                )
                # per-layer precision mixes may upcast the block output; the
                # scan carry dtype is pinned by the embedding policy
                return out.astype(carry.dtype), new_cache

            if use_remat:
                if remat_name == "full":
                    policy = jax.checkpoint_policies.save_only_these_names(
                        "layer_boundary"
                    )
                else:
                    policy_name = REMAT_POLICIES.get(remat_name, "nothing_saveable")
                    policy = (
                        getattr(jax.checkpoint_policies, policy_name)
                        if policy_name
                        else None
                    )
                body = jax.checkpoint(body, policy=policy)

            xs = (stacked, cache)
            if cache is None:
                xs = (stacked, None)
            x_out, new_cache = jax.lax.scan(body, x, xs, length=self.n_layers)
            ctx.taps_enabled = saved_taps
            return x_out, new_cache
