"""Grouped-query attention with KV caches, sliding windows and impl weaving.

Supports every attention flavour the assigned architectures need:
  - GQA / MQA / MHA (kv_heads in {1..n_heads}),
  - causal, bidirectional (encoder), sliding-window (mixtral), local
    (recurrentgemma) masks, optional logit soft-capping (grok),
  - QKV bias (qwen2), RoPE with configurable theta,
  - cross-attention (whisper decoder),
  - dense mode (train / prefill, optionally emitting a KV cache) and decode
    mode (single new token against a linear or ring cache).

The *implementation* (XLA einsum reference vs Pallas flash kernel) is chosen
by the woven Ctx — this is the ANTAREX code-versioning / kernel-substitution
aspect acting on the attention joinpoint.  The XLA path is also the roofline
path (Pallas custom calls are opaque to cost_analysis; see DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.blocks import apply_rope, rope_angles
from repro.nn.module import Ctx, Module, ParamSpec, cast

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# KV caches (plain pytrees)
# ---------------------------------------------------------------------------


def init_cache(batch: int, max_len: int, kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
    """Linear cache: slot s holds absolute position s."""
    return {
        "k": jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, kv_heads, head_dim), dtype),
        "index": jnp.zeros((), jnp.int32),  # number of valid tokens
    }


def init_ring_cache(batch: int, window: int, kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
    """Ring cache for windowed attention: slot = pos % window.

    This is what makes `long_500k` decode O(window) for SWA/local archs.
    """
    return {
        "k": jnp.zeros((batch, window, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, window, kv_heads, head_dim), dtype),
        "pos": jnp.full((window,), -1, jnp.int32),  # absolute position per slot
        "index": jnp.zeros((), jnp.int32),
    }


def cache_spec(batch, max_len, kv_heads, head_dim, dtype=jnp.bfloat16, *, ring=False):
    """ShapeDtypeStruct pytree for dry-run input_specs."""
    sds = jax.ShapeDtypeStruct
    out = {
        "k": sds((batch, max_len, kv_heads, head_dim), dtype),
        "v": sds((batch, max_len, kv_heads, head_dim), dtype),
        "index": sds((), jnp.int32),
    }
    if ring:
        out["pos"] = sds((max_len,), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Reference (XLA) attention math
# ---------------------------------------------------------------------------


def _mask_dense(q_pos, kv_pos, mask_kind: str, window: int | None):
    """(..., S, T) boolean mask from absolute positions."""
    qp = q_pos[..., :, None].astype(jnp.int32)
    kp = kv_pos[..., None, :].astype(jnp.int32)
    valid = kp >= 0
    if mask_kind in ("causal", "sliding", "local"):
        valid = valid & (kp <= qp)
    if mask_kind in ("sliding", "local") and window is not None:
        valid = valid & (kp > qp - window)
    return valid


def xla_attention(q, k, v, mask, *, softcap=None, accum_dtype=jnp.float32,
                  constrain=None):
    """q:(B,S,H,D) k,v:(B,T,K,D) mask:bool broadcastable to (B,K,G,S,T)."""
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.astype(accum_dtype).reshape(B, S, K, G, D)
    kf = k.astype(accum_dtype)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, kf) / np.sqrt(D)
    if constrain is not None:
        scores = constrain(scores)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(B, S, H, D)


def xla_attention_blocked(
    q, k, v, q_pos, kv_pos, *, mask_kind: str, window: int | None,
    softcap=None, block: int = 1024, constrain=None,
):
    """Online-softmax attention, lax.scan over KV blocks ("flash in XLA").

    Bounds live memory to one (B,K,G,S,block) score tile instead of the full
    (B,K,G,S,T) tensor — the production path for long sequences when the
    Pallas kernel is not woven (and the dry-run's memory-fit path).
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    block = min(block, T)
    pad = (-T) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    nb = (T + pad) // block
    qf = (q.astype(jnp.float32) / np.sqrt(D)).reshape(B, S, K, G, D)
    ks = jnp.moveaxis(k.reshape(B, nb, block, K, D), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nb, block, K, D), 1, 0)
    ps = jnp.moveaxis(kv_pos.reshape(B, nb, block), 1, 0)

    def body(carry, blk):
        m, l, acc = carry
        k_b, v_b, p_b = blk
        s = jnp.einsum("bskgd,btkd->bkgst", qf, k_b.astype(jnp.float32))
        if constrain is not None:
            s = constrain(s)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        mask = _mask_dense(q_pos, p_b, mask_kind, window)  # (B, S, block)
        mask = mask[:, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha[..., 0][..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, v_b.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, S, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S, 1), jnp.float32)
    a0 = jnp.zeros((B, K, G, S, D), jnp.float32)
    if constrain is not None:
        a0 = constrain(a0)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, ps))
    out = acc / jnp.maximum(l, 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, H, D)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention module
# ---------------------------------------------------------------------------


class Attention(Module):
    kind = "attention"

    def __init__(
        self,
        name: str,
        d_model: int,
        n_heads: int,
        kv_heads: int,
        head_dim: int,
        *,
        bias: bool = False,
        use_rope: bool = True,
        rope_theta: float = 10000.0,
        mask: str = "causal",  # causal | full | sliding | local
        window: int | None = None,
        softcap: float | None = None,
        cross: bool = False,
    ):
        self.name = name
        self.d_model = d_model
        self.n_heads, self.kv_heads, self.head_dim = n_heads, kv_heads, head_dim
        self.bias = bias
        self.use_rope = use_rope
        self.rope_theta = rope_theta
        self.mask = mask
        self.window = window
        self.softcap = softcap
        self.cross = cross
        H, K, D = n_heads, kv_heads, head_dim
        self.wq = ParamSpec((d_model, H * D), ("embed", "heads"), init="scaled", scale=d_model)
        self.wk = ParamSpec((d_model, K * D), ("embed", "kv_heads"), init="scaled", scale=d_model)
        self.wv = ParamSpec((d_model, K * D), ("embed", "kv_heads"), init="scaled", scale=d_model)
        self.wo = ParamSpec((H * D, d_model), ("heads", "embed"), init="scaled", scale=H * D)

    def spec(self):
        s: dict[str, Any] = {"wq": self.wq, "wk": self.wk, "wv": self.wv, "wo": self.wo}
        if self.bias:
            s["bq"] = ParamSpec((self.n_heads * self.head_dim,), ("heads",), init="zeros")
            s["bk"] = ParamSpec((self.kv_heads * self.head_dim,), ("kv_heads",), init="zeros")
            s["bv"] = ParamSpec((self.kv_heads * self.head_dim,), ("kv_heads",), init="zeros")
        return s

    # -- projections -----------------------------------------------------------

    def _proj(self, params, x, which: str, heads: int, policy):
        w = cast(params[f"w{which}"], policy.compute_dtype)
        y = jnp.dot(cast(x, policy.compute_dtype), w, preferred_element_type=policy.accum_dtype)
        if self.bias and which in ("q", "k", "v"):
            y = y + cast(params[f"b{which}"], policy.accum_dtype)
        y = cast(y, policy.compute_dtype)
        return y.reshape(*x.shape[:-1], heads, self.head_dim)

    # -- main entry -------------------------------------------------------------

    def __call__(
        self,
        params,
        x,
        *,
        ctx: Ctx,
        positions: jax.Array | None = None,
        mode: str = "dense",  # dense | prefill | decode
        cache: dict | None = None,
        kv_src: jax.Array | None = None,  # cross-attention source (B,T,d)
        kv_pos: jax.Array | None = None,  # hoisted (B,T) decode positions
        block_tables: jax.Array | None = None,  # paged caches: (B, NB) pages
        prefix_len: int = 0,  # paged prefill: shared-prefix slots (static)
        skip_cache_write: bool = False,  # paged re-score: no cache mutation
    ):
        with ctx.scope(self.name):
            policy = ctx.policy()
            B, S, _ = x.shape
            if positions is None:
                positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

            q = self._proj(params, x, "q", self.n_heads, policy)
            q = ctx.constrain(q, ("batch", "seq_act", "heads", None))

            if self.cross:
                out, new_cache = self._cross(params, q, ctx, policy, cache,
                                             kv_src, mode)
            elif mode == "decode":
                out, new_cache = self._decode(params, q, x, positions, ctx, policy,
                                              cache, kv_pos, block_tables,
                                              skip_write=skip_cache_write)
            elif mode == "prefill" and cache is not None and "pk" in cache:
                out, new_cache = self._prefill_paged(
                    params, q, x, positions, ctx, policy, cache, block_tables,
                    prefix_len)
            else:
                out, new_cache = self._dense(params, q, x, positions, ctx, policy, mode, cache)

            wo = cast(params["wo"], policy.compute_dtype)
            y = jnp.dot(
                out.reshape(B, S, self.n_heads * self.head_dim),
                wo,
                preferred_element_type=policy.accum_dtype,
            )
            y = cast(y, policy.compute_dtype)
            y = ctx.constrain(y, ("batch", "res_seq", "embed"))
            ctx.tap("out_absmax", jnp.max(jnp.abs(y)))
            return y, new_cache

    # -- dense (train / prefill) -------------------------------------------------

    def _dense(self, params, q, x, positions, ctx, policy, mode, cache):
        k = self._proj(params, x, "k", self.kv_heads, policy)
        v = self._proj(params, x, "v", self.kv_heads, policy)
        k = ctx.constrain(k, ("batch", "seq_act", "kv_heads", None))
        v = ctx.constrain(v, ("batch", "seq_act", "kv_heads", None))
        if self.use_rope:
            sin, cos = rope_angles(positions, self.head_dim, self.rope_theta)
            q = apply_rope(q, sin, cos)
            k = apply_rope(k, sin, cos)

        k_cache, v_cache = k, v  # cache stores true KV heads, pre-expansion
        out = self._attend_dense(q, k, v, positions, ctx, policy)

        new_cache = None
        if mode == "prefill":
            new_cache = self._build_cache(k_cache, v_cache, positions, ctx, policy)
        return out, new_cache

    def _attend_dense(self, q, k, v, positions, ctx, policy):
        """Self-aligned (q_pos == kv_pos) attention through the woven impl
        dispatch — shared verbatim by the dense/prefill path and the paged
        prefill's full-prompt and ring branches, so direct-to-pool prefill
        stays bit-identical to the dense transient it replaces."""
        S = q.shape[1]
        impl = ctx.impl("attention", "xla")
        if impl == "proj_only":
            # roofline component mode: keep the projection FLOPs (and the
            # K/V gather collectives — tie k,v into the output so DCE keeps
            # them), skip the S x T core (costed analytically — the Pallas
            # kernel is opaque to cost_analysis anyway; DESIGN.md §7)
            out = q + (jnp.mean(k, axis=2, keepdims=True)
                       + jnp.mean(v, axis=2, keepdims=True)).astype(q.dtype)
        elif impl == "pallas" and self._pallas_ok():
            from repro.kernels.flash_attention.ops import flash_attention

            # Woven extras win; unset blocks fall through to the kernel-tuner
            # cache lookup inside flash_attention (None -> tuned or default).
            blocks = {
                name: int(ctx.extra[key]) if ctx.extra.get(key) is not None
                else None
                for name, key in (
                    ("block_q", "flash_block_q"),
                    ("block_kv", "flash_block_kv"),
                    ("block_q_bwd", "flash_block_q_bwd"),
                    ("block_kv_bwd", "flash_block_kv_bwd"),
                )
            }
            out = flash_attention(
                q, k, v,
                causal=self.mask in ("causal", "sliding", "local"),
                window=self.window if self.mask in ("sliding", "local") else None,
                softcap=self.softcap,
                pruned=bool(ctx.extra.get("flash_pruned", True)),
                mesh=ctx.mesh,
                rules=ctx.rules,
                **blocks,
            )
        else:
            k, v, kv_axis = self._maybe_expand_kv(k, v, ctx)
            constrain = self._score_constrain(ctx, kv_axis)
            block = int(ctx.extra.get("xla_attn_block", 1024))
            if S > 2 * block:  # long sequences: bounded-memory blocked path
                out = xla_attention_blocked(
                    q, k, v, positions, positions, mask_kind=self.mask,
                    window=self.window, softcap=self.softcap, block=block,
                    constrain=constrain,
                )
            else:
                mask = _mask_dense(positions, positions, self.mask, self.window)
                mask = mask[:, None, None]  # (B,1,1,S,T)
                out = xla_attention(q, k, v, mask, softcap=self.softcap,
                                    accum_dtype=policy.accum_dtype,
                                    constrain=constrain)
        return out

    def _maybe_expand_kv(self, k, v, ctx: Ctx):
        """Megatron layout with GQA: replicate KV heads up to q-heads so the
        scores' head dim is a single model-shardable axis (K x G cannot be
        sharded across a dim split).  Returns (k, v, score_head_axis)."""
        if (
            ctx.extra.get("expand_kv")
            and self.kv_heads != self.n_heads
            and ctx.mesh is not None
        ):
            reps = self.n_heads // self.kv_heads
            k = jnp.repeat(k, reps, axis=2)
            v = jnp.repeat(v, reps, axis=2)
            k = ctx.constrain(k, ("batch", "seq_act", "heads", None))
            v = ctx.constrain(v, ("batch", "seq_act", "heads", None))
            return k, v, "heads"
        return k, v, "kv_heads"

    def _score_constrain(self, ctx: Ctx, kv_axis: str):
        if ctx.mesh is None:
            return None

        def constrain(t):  # (B, K, G, S, X)
            return ctx.constrain(t, ("batch", kv_axis, None, "seq_act", None))

        return constrain

    def _pallas_ok(self) -> bool:
        # No seq-length gate: ragged seq is fine — the kernel wrapper pads
        # to block multiples (the old `seq` parameter was dead since that
        # padding landed).
        if self.head_dim % 128 != 0 and self.head_dim not in (64, 256):
            return False
        return self.n_heads % self.kv_heads == 0

    def _build_cache(self, k, v, positions, ctx, policy):
        """Prefill: pack computed K/V into a cache pytree for decode.

        Linear caches are padded to ctx.extra["cache_max_len"] (default: no
        growth room — the decode_32k dry-run cell semantics, where the one
        new token occupies the final slot).
        """
        B, S = k.shape[0], k.shape[1]
        if self.mask in ("sliding", "local") and self.window is not None and self.window < S:
            W = self.window
            k_w, v_w = k[:, -W:], v[:, -W:]
            pos_w = positions[0, -W:]
            slots = pos_w % W
            kc = jnp.zeros((B, W, self.kv_heads, self.head_dim), k.dtype).at[:, slots].set(k_w)
            vc = jnp.zeros((B, W, self.kv_heads, self.head_dim), v.dtype).at[:, slots].set(v_w)
            pos = jnp.full((W,), -1, jnp.int32).at[slots].set(pos_w)
            return {"k": kc, "v": vc, "pos": pos, "index": jnp.asarray(S, jnp.int32)}
        max_len = int(ctx.extra.get("cache_max_len", S))
        if max_len > S:
            pad = ((0, 0), (0, max_len - S), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return {"k": k, "v": v, "index": jnp.asarray(S, jnp.int32)}

    # -- paged prefill (write K/V straight into pool pages) ------------------------

    def _prefill_paged(self, params, q, x, positions, ctx, policy, cache,
                       block_tables, prefix_len: int):
        """Prefill a (possibly prefix-shared) request directly into a page
        pool: the `prefix_len` leading slots are already resident (shared
        physical pages mapped by this request's block table), only the
        non-shared suffix is computed here, and its K/V scatter at the
        same (page, offset) addressing the decode path uses — admission
        never materializes a dense max_len cache.

        `prefix_len` is static (the serving layer compiles one step per
        (prefix, suffix) shape, exactly as it already compiles per prompt
        length).  With no shared prefix the attention goes through
        `_attend_dense` — the identical impl dispatch the dense prefill
        runs, so direct-to-pool output is bit-identical to the transient
        path it replaces.  With a prefix, suffix queries attend over the
        pool-resident K/V gathered through the table (XLA path: the masks
        come from absolute positions, so sliding windows and softcap
        behave exactly as in the dense math).

        Serving layout only: one request at a time (B = 1).
        """
        if block_tables is None:
            raise ValueError("paged prefill needs block_tables (the model "
                             "hoists cache['block_tables'] to every layer)")
        B, S = q.shape[0], q.shape[1]
        if B != 1:
            raise ValueError("paged prefill packs one request at a time")
        k_new = self._proj(params, x, "k", self.kv_heads, policy)
        v_new = self._proj(params, x, "v", self.kv_heads, policy)
        if self.use_rope:
            sin, cos = rope_angles(positions, self.head_dim, self.rope_theta)
            q = apply_rope(q, sin, cos)
            k_new = apply_rope(k_new, sin, cos)

        pk, pv = cache["pk"], cache["pv"]
        ps = pk.shape[1]
        ring = "pos" in cache

        if ring:
            # ring pools never share a prefix (slot contents depend on the
            # wrap), so the whole prompt is here: keep the last W tokens,
            # scatter at slot = pos % W — `_build_cache`'s ring packing,
            # addressed through the block table.
            W = cache["pos"].shape[-1]
            keep = min(W, S)
            k_w, v_w = k_new[0, -keep:], v_new[0, -keep:]
            pos_w = positions[0, -keep:]
            slots = pos_w % W
            page = block_tables[0, slots // ps]
            off = slots % ps
            pk = pk.at[page, off].set(k_w)
            pv = pv.at[page, off].set(v_w)
            pos = jnp.full((W,), -1, jnp.int32).at[slots].set(pos_w)
            new_cache = {"pk": pk, "pv": pv, "pos": pos,
                         "index": cache["index"] + S}
            out = self._attend_dense(q, k_new, v_new, positions, ctx, policy)
            return out, new_cache

        quant = "ksc" in cache
        ksc = vsc = None
        slots = prefix_len + jnp.arange(S, dtype=jnp.int32)
        page = block_tables[0, slots // ps]
        off = slots % ps
        if quant:
            from repro.kernels.flash_attention.ops import (
                dequantize_kv,
                kv_scale_from_absmax,
                quantize_kv_write,
            )

            ksc, vsc = cache["ksc"], cache["vsc"]
            # fresh-page scale = absmax over the tokens this prefill writes
            # into the page (scatter-max from the 0.0 free sentinel).  A
            # page the shared prefix straddles keeps the donor's recorded
            # scale — its contributions are masked out, so already-written
            # slots are never requantized (the fixed-scale invariant that
            # keeps sharing and rollback bit-deterministic).
            k_tok = kv_scale_from_absmax(
                jnp.max(jnp.abs(k_new[0].astype(jnp.float32)), axis=-1),
                pk.dtype)
            v_tok = kv_scale_from_absmax(
                jnp.max(jnp.abs(v_new[0].astype(jnp.float32)), axis=-1),
                pv.dtype)
            if prefix_len % ps:
                keep = (slots // ps == prefix_len // ps)[:, None]
                k_tok = jnp.where(keep, 0.0, k_tok)
                v_tok = jnp.where(keep, 0.0, v_tok)
            ksc = ksc.at[page].max(k_tok)
            vsc = vsc.at[page].max(v_tok)
            k_w = quantize_kv_write(k_new[0], ksc[page], pk.dtype)
            v_w = quantize_kv_write(v_new[0], vsc[page], pv.dtype)
        else:
            k_w, v_w = k_new[0], v_new[0]
        pk = pk.at[page, off].set(k_w)
        pv = pv.at[page, off].set(v_w)
        new_cache = {"pk": pk, "pv": pv, "index": cache["index"] + S}
        if quant:
            new_cache["ksc"], new_cache["vsc"] = ksc, vsc

        if prefix_len == 0:
            if quant:
                # attend over the *dequantized* values, so prefill logits
                # match what every later pool read (re-score, decode over
                # the prefix) will see — the shared-vs-unshared parity
                # invariant under quantization
                k_att = dequantize_kv(k_w, ksc[page])[None]
                v_att = dequantize_kv(v_w, vsc[page])[None]
                out = self._attend_dense(q, k_att, v_att, positions, ctx,
                                         policy)
            else:
                out = self._attend_dense(q, k_new, v_new, positions, ctx,
                                         policy)
            return out, new_cache

        total = prefix_len + S  # static

        impl = ctx.impl("attention", "xla")
        if impl == "pallas" and self._pallas_ok() and ctx.mesh is None:
            # suffix-q over the pool-resident prefix through the widened-q
            # decode kernel (the q_offset variant): index = prefix_len puts
            # suffix token s's causal boundary at slot prefix_len + s, and
            # the pages stream block-by-block through the table — no
            # logical-view gather, O(live blocks) HBM traffic.  Same online
            # fp32 softmax per q row as the prefill kernel the unshared
            # path runs, so woven-pallas sharing keeps bit-parity.
            from repro.kernels.flash_attention.ops import flash_decode

            blk = ctx.extra.get("flash_block_kv_dec")  # woven extras win
            out = flash_decode(
                q, pk, pv, jnp.full((B,), prefix_len, jnp.int32),
                window=(self.window if self.mask in ("sliding", "local")
                        else None),
                softcap=self.softcap,
                block_kv=int(blk) if blk is not None else None,
                pruned=bool(ctx.extra.get("flash_pruned", True)),
                tables=block_tables, kv_len=total,
                k_scale=ksc, v_scale=vsc,
            )
            return out, new_cache

        # suffix queries over the full logical prefix: gather the live
        # slots (shared prefix pages + the suffix just written) through
        # the table and mask from absolute positions.  The gather
        # materializes one layer's (prompt, K, D) logical view at a time —
        # O(live prompt tokens), never O(max_len), and only the suffix was
        # *computed*.
        from repro.kernels.flash_attention.ops import paged_gather_kv

        k_log, v_log = paged_gather_kv(pk, pv, block_tables, total,
                                       k_scale=ksc, v_scale=vsc)
        k_log, v_log, _ = self._maybe_expand_kv(k_log, v_log, ctx)
        kv_pos = jnp.broadcast_to(
            jnp.arange(total, dtype=jnp.int32)[None], (B, total))
        block = int(ctx.extra.get("xla_attn_block", 1024))
        if total > 2 * block:  # long prefixes: bounded-memory blocked path
            out = xla_attention_blocked(
                q, k_log, v_log, positions, kv_pos, mask_kind=self.mask,
                window=self.window, softcap=self.softcap, block=block,
            )
        else:
            mask = _mask_dense(positions, kv_pos, self.mask,
                               self.window)[:, None, None]
            out = xla_attention(q, k_log, v_log, mask, softcap=self.softcap,
                                accum_dtype=policy.accum_dtype)
        return out, new_cache

    # -- decode (a block of S >= 1 new tokens against a cache) --------------------

    def _decode(self, params, q, x, positions, ctx, policy, cache, kv_pos=None,
                block_tables=None, skip_write=False):
        """S >= 1 new tokens against a linear, ring, or *paged* cache.

        The cache is updated in place (`.at[...].set`, so jit donates the
        buffers) and the attention dispatches through the same impl-weaving
        path as `_dense`: `impl == "pallas"` streams only the live cache
        blocks through the `flash_decode` kernel; the XLA path is kept as
        the reference (and the meshed fallback).  `cache["index"]` may be a
        scalar (single stream) or per-request (B,) — the stacked-serving
        layout — and ring `pos` follows with shape (W,) or (B, W).

        Paged caches (`{"pk", "pv"}` pools + the model-hoisted
        `block_tables`) write the new token at its physical (page, offset)
        and dispatch the same way: the kernel resolves blocks through the
        table, the XLA reference gathers the logical view — both
        bit-identical to the dense layout because the streamed values and
        mask are unchanged.

        S > 1 (the speculative verify step) writes the whole draft block at
        slots index..index+S-1 and attends it in one widened-q kernel call:
        token s's causal boundary is slot index + s, so the later draft
        slots are masked exactly as if they were not yet written — linear
        and paged caches stay bit-identical to S sequential decodes.  Ring
        caches are the exception: writing token s *evicts* position
        index+s-W, which earlier draft tokens can still see, so the ring
        branch unrolls the S tokens sequentially (same per-token math and
        eviction order as plain decode — bit-exact by construction, still
        one compiled step).

        Contract: the first new token's `positions` must equal
        `cache["index"]` (the autoregressive invariant — tokens are written
        from that slot).  The kernel derives its causal boundary from the
        index alone, so a caller re-scoring an earlier position against a
        fuller cache must use the XLA impl, which masks from
        `positions`/`kv_pos`.
        """
        assert cache is not None, "decode mode requires a cache"
        k_new = self._proj(params, x, "k", self.kv_heads, policy)
        v_new = self._proj(params, x, "v", self.kv_heads, policy)
        if self.use_rope:
            sin, cos = rope_angles(positions, self.head_dim, self.rope_theta)
            q = apply_rope(q, sin, cos)
            k_new = apply_rope(k_new, sin, cos)

        if "pk" in cache:
            return self._decode_paged(q, k_new, v_new, positions, ctx, policy,
                                      cache, kv_pos, block_tables,
                                      skip_write=skip_write)
        if skip_write:
            raise ValueError("skip_cache_write (the re-score step) is a "
                             "paged-cache contract — dense caches decode "
                             "normally")

        S = q.shape[1]
        if "pos" in cache and S > 1:
            # ring eviction: unroll the draft block token-by-token (see
            # docstring) — one compiled step, exact sequential semantics
            outs = []
            for s in range(S):
                o, cache = self._decode_written(
                    q[:, s:s + 1], k_new[:, s:s + 1], v_new[:, s:s + 1],
                    positions[:, s:s + 1], ctx, policy, cache, None)
                outs.append(o)
            return jnp.concatenate(outs, axis=1), cache
        return self._decode_written(q, k_new, v_new, positions, ctx, policy,
                                    cache, kv_pos)

    def _decode_written(self, q, k_new, v_new, positions, ctx, policy, cache,
                        kv_pos):
        """Write S projected tokens into a dense (linear/ring) cache and
        attend them — the post-projection body of `_decode`."""
        B, S = q.shape[0], q.shape[1]
        idx = cache["index"]
        per_req = getattr(idx, "ndim", 0) == 1  # stacked multi-request caches
        ring = "pos" in cache
        bidx = jnp.arange(B)
        if ring:
            assert S == 1, "ring caches decode one token at a time (unrolled)"
            W = cache["k"].shape[1]
            slot = idx % W
            if per_req:
                k_all = cache["k"].at[bidx, slot].set(k_new[:, 0])
                v_all = cache["v"].at[bidx, slot].set(v_new[:, 0])
                pos = cache["pos"].at[bidx, slot].set(idx)  # (B, W)
                kv_pos = pos
            else:
                k_all = cache["k"].at[:, slot].set(k_new[:, 0])
                v_all = cache["v"].at[:, slot].set(v_new[:, 0])
                pos = cache["pos"].at[slot].set(idx)
                kv_pos = jnp.broadcast_to(pos, (B, W))
            new_cache = {"k": k_all, "v": v_all, "pos": pos, "index": idx + 1}
            kernel_window = None  # the ring layout *is* the window
        else:
            T = cache["k"].shape[1]
            if per_req:
                # slots index..index+S-1 per request; OOB slots (cache full)
                # drop in the scatter, matching the single-token behaviour
                slots = jnp.reshape(idx, (-1, 1)) + jnp.arange(S)
                k_all = cache["k"].at[bidx[:, None], slots].set(k_new)
                v_all = cache["v"].at[bidx[:, None], slots].set(v_new)
            else:
                k_all = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k_new, idx, axis=1)
                v_all = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v_new, idx, axis=1)
            if kv_pos is None:
                # fallback for single-layer callers; the model hoists this
                # into the cache pytree so all layers share one kv_pos
                arange = jnp.arange(T, dtype=jnp.int32)
                last = jnp.reshape(idx, (-1, 1)) + (S - 1)
                kv_pos = jnp.where(arange[None] <= last, arange[None], -1)
                kv_pos = jnp.broadcast_to(kv_pos, (B, T))
            new_cache = {"k": k_all, "v": v_all, "index": idx + S}
            kernel_window = (
                self.window if self.mask in ("sliding", "local") else None
            )

        impl = ctx.impl("attention", "xla")
        if impl == "pallas" and self._pallas_ok() and ctx.mesh is None:
            from repro.kernels.flash_attention.ops import flash_decode

            blk = ctx.extra.get("flash_block_kv_dec")  # woven extras win
            out = flash_decode(
                q, k_all, v_all, idx,
                window=kernel_window, softcap=self.softcap,
                block_kv=int(blk) if blk is not None else None,
                pruned=bool(ctx.extra.get("flash_pruned", True)),
            )
            return out, new_cache

        k_all = ctx.constrain(k_all, ("batch", "kv_seq", "kv_heads", None))
        v_all = ctx.constrain(v_all, ("batch", "kv_seq", "kv_heads", None))
        k_c, v_c, kv_axis = self._maybe_expand_kv(k_all, v_all, ctx)
        mask = _mask_dense(positions, kv_pos, self.mask, self.window)[:, None, None]

        def constrain(t):  # (B, K, G, 1, T)
            return ctx.constrain(t, ("batch", kv_axis, None, None, "kv_seq"))

        out = xla_attention(q, k_c, v_c, mask, softcap=self.softcap,
                            accum_dtype=policy.accum_dtype,
                            constrain=constrain if ctx.mesh is not None else None)
        return out, new_cache

    def _decode_paged(self, q, k_new, v_new, positions, ctx, policy, cache,
                      kv_pos, block_tables, skip_write=False):
        """Paged-pool decode: the cache slots live in shared page pools
        (`pk`/`pv`: (P, page_size, K, D)) and the request's logical slot s
        maps to physical (tables[b, s // ps], s % ps).  Serving layout
        only: `index` is per-request (B,).

        `skip_write=True` is the *re-score* contract (a full-prompt prefix
        hit): the slot at `index` already holds this token's K/V on a
        shared page, so the step computes logits without mutating the pool
        — writing would perturb pages other requests still map.

        S > 1 (speculative verify) writes the draft block at logical slots
        index..index+S-1 through the table and attends it with the
        widened-q kernel — linear pools only (ring pools evict on write;
        the server falls back to plain decode for ring-pool archs)."""
        if block_tables is None:
            raise ValueError("paged caches need block_tables (the model "
                             "hoists cache['block_tables'] to every layer)")
        idx = cache["index"]
        if getattr(idx, "ndim", 0) != 1:
            raise ValueError("paged caches are per-request: index must be "
                             f"(B,), got shape {getattr(idx, 'shape', ())}")
        B, S = q.shape[0], q.shape[1]
        bidx = jnp.arange(B)
        pk, pv = cache["pk"], cache["pv"]
        ps = pk.shape[1]
        ring = "pos" in cache
        quant = "ksc" in cache
        ksc = vsc = None
        if quant:
            ksc, vsc = cache["ksc"], cache["vsc"]

        if ring:
            if S > 1:
                raise ValueError("ring pools decode one token at a time "
                                 "(eviction breaks the widened-q mask); the "
                                 "server gates speculative to linear pools")
            W = cache["pos"].shape[-1]
            slot = idx % W
            kv_len = W
            kernel_window = None  # the ring layout *is* the window
        else:
            # true logical length: the hoisted kv_pos row width (the table
            # may round up to whole pages); fallback covers bare callers
            kv_len = (kv_pos.shape[1] if kv_pos is not None
                      else block_tables.shape[1] * ps)
            slot = idx
            kernel_window = (
                self.window if self.mask in ("sliding", "local") else None
            )
        if skip_write:
            if ring:
                # ring pools never share a prefix (match_prefix refuses),
                # so a re-score admission cannot reach them
                raise ValueError("re-score is a linear prefix-shared "
                                 "contract — ring pools never share")
            # re-score: the token's K/V already sit at `slot` (a shared
            # prefix page) — the cache passes through untouched.
            k_all, v_all = pk, pv
            new_cache = {"pk": pk, "pv": pv, "index": idx}
            if quant:
                new_cache["ksc"], new_cache["vsc"] = ksc, vsc
        else:
            if ring:
                page = block_tables[bidx, slot // ps]
                off = slot % ps
            else:
                slots = slot[:, None] + jnp.arange(S)  # (B, S) logical slots
                page = block_tables[bidx[:, None], slots // ps]
                off = slots % ps
                # past-the-end writes must vanish exactly like the dense
                # layout's OOB scatter: the table *gather* clamps to the
                # last live page, so redirect to an OOB page id and let the
                # scatter drop it instead of corrupting a live slot
                page = jnp.where(slots < kv_len, page, pk.shape[0])
            if ring:
                k_all = pk.at[page, off].set(k_new[:, 0])
                v_all = pv.at[page, off].set(v_new[:, 0])
            else:
                if quant:
                    from repro.kernels.flash_attention.ops import (
                        kv_scale_from_absmax,
                        quantize_kv_write,
                    )

                    # linear slots fill sequentially, so a page's first
                    # write lands at offset 0: record its scale from that
                    # token (scatter-set with the same OOB redirect) and
                    # quantize every token at the post-scatter gathered
                    # row.  Later writes into the page reuse the recorded
                    # scale (clipped) — never requantized, so rollback and
                    # sharing stay bit-deterministic.
                    k_tok = kv_scale_from_absmax(
                        jnp.max(jnp.abs(k_new.astype(jnp.float32)),
                                axis=-1), pk.dtype)  # (B, S, K)
                    v_tok = kv_scale_from_absmax(
                        jnp.max(jnp.abs(v_new.astype(jnp.float32)),
                                axis=-1), pv.dtype)
                    fresh = (off == 0) & (slots < kv_len)
                    spage = jnp.where(fresh, page, pk.shape[0])
                    ksc = ksc.at[spage].set(k_tok)
                    vsc = vsc.at[spage].set(v_tok)
                    k_w = quantize_kv_write(k_new, ksc[page], pk.dtype)
                    v_w = quantize_kv_write(v_new, vsc[page], pv.dtype)
                else:
                    k_w, v_w = k_new, v_new
                k_all = pk.at[page, off].set(k_w)
                v_all = pv.at[page, off].set(v_w)
            new_cache = {"pk": k_all, "pv": v_all, "index": idx + S}
            if quant:
                new_cache["ksc"], new_cache["vsc"] = ksc, vsc
            if ring:
                pos = cache["pos"].at[bidx, slot].set(idx)
                new_cache["pos"] = pos
                kv_pos = pos
        if not ring and kv_pos is None:
            arange = jnp.arange(kv_len, dtype=jnp.int32)
            kv_pos = jnp.where(arange[None] <= idx[:, None] + (S - 1),
                               arange[None], -1)

        impl = ctx.impl("attention", "xla")
        if impl == "pallas" and self._pallas_ok() and ctx.mesh is None:
            from repro.kernels.flash_attention.ops import flash_decode

            blk = ctx.extra.get("flash_block_kv_dec")  # woven extras win
            out = flash_decode(
                q, k_all, v_all, idx,
                window=kernel_window, softcap=self.softcap,
                block_kv=int(blk) if blk is not None else None,
                pruned=bool(ctx.extra.get("flash_pruned", True)),
                tables=block_tables, kv_len=kv_len,
                k_scale=ksc, v_scale=vsc,
            )
            return out, new_cache

        # XLA reference: gather the logical view through the table, then the
        # exact dense decode math (bit-identical — same values, same mask).
        from repro.kernels.flash_attention.ops import paged_gather_kv

        k_log, v_log = paged_gather_kv(k_all, v_all, block_tables, kv_len,
                                       k_scale=ksc, v_scale=vsc)
        k_c, v_c, kv_axis = self._maybe_expand_kv(k_log, v_log, ctx)
        # mask from the caller's positions (== index on the hot path): the
        # XLA reference keeps the dense path's re-scoring escape hatch
        mask = _mask_dense(positions, kv_pos, self.mask,
                           self.window)[:, None, None]
        out = xla_attention(q, k_c, v_c, mask, softcap=self.softcap,
                            accum_dtype=policy.accum_dtype)
        return out, new_cache

    # -- cross attention (whisper decoder) ----------------------------------------

    def _cross(self, params, q, ctx, policy, cache, kv_src, mode="dense"):
        """Cross-attention over the (static-length) encoder states.

        Decode steps (one q token against the cached encoder K/V) dispatch
        through `flash_decode`: the encoder length is fixed, so the stream
        schedule is simply the whole prefix — `index = T - 1` marks every
        slot live and the kernel's causal clamp degenerates to the full
        mask, with no per-step index bookkeeping.  The XLA path stays as
        the reference (and covers prefill / dense / meshed calls).
        """
        if cache is not None and "ck" in cache:
            k, v = cache["ck"], cache["cv"]
            new_cache = cache
        else:
            assert kv_src is not None, "cross-attention needs kv_src or cached K/V"
            k = self._proj(params, kv_src, "k", self.kv_heads, policy)
            v = self._proj(params, kv_src, "v", self.kv_heads, policy)
            new_cache = {"ck": k, "cv": v}
        B, S = q.shape[0], q.shape[1]
        T = k.shape[1]
        impl = ctx.impl("attention", "xla")
        if (mode == "decode" and S == 1 and impl == "pallas"
                and self._pallas_ok() and ctx.mesh is None):
            from repro.kernels.flash_attention.ops import flash_decode

            blk = ctx.extra.get("flash_block_kv_dec")
            out = flash_decode(
                q, k, v, jnp.full((B,), T - 1, jnp.int32),
                softcap=self.softcap,
                block_kv=int(blk) if blk is not None else None,
                pruned=bool(ctx.extra.get("flash_pruned", True)),
            )
            return out, new_cache
        mask = jnp.ones((B, 1, 1, S, T), bool)
        out = xla_attention(q, k, v, mask, softcap=self.softcap,
                            accum_dtype=policy.accum_dtype)
        return out, new_cache
