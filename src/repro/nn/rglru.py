"""RecurrentGemma / Griffin recurrent block: temporal conv + RG-LRU.

RG-LRU (Real-Gated Linear Recurrent Unit, arXiv:2402.19427):
    r_t = sigmoid(BlockDiag_a(x_t))          (recurrence gate)
    i_t = sigmoid(BlockDiag_x(x_t))          (input gate)
    log a_t = -c * softplus(Lambda) * r_t    (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses a parallel form (associative scan or the Pallas blocked
kernel, woven by Ctx); decode is the O(1) single-step update — this is what
makes the `long_500k` cell run for this architecture.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.blocks import Linear
from repro.nn.module import Ctx, Module, ParamSpec, cast

RGLRU_C = 8.0


class BlockDiagonalLinear(Module):
    kind = "linear"

    def __init__(self, name: str, dim: int, num_blocks: int):
        self.name = name
        self.dim, self.num_blocks = dim, num_blocks
        assert dim % num_blocks == 0
        self.block = dim // num_blocks

    def spec(self):
        nb, bs = self.num_blocks, self.block
        return {
            "w": ParamSpec((nb, bs, bs), (None, None, None), init="scaled", scale=bs),
            "b": ParamSpec((nb, bs), (None, None), init="zeros"),
        }

    def __call__(self, params, x, *, ctx: Ctx):
        with ctx.scope(self.name):
            policy = ctx.policy()
            shape = x.shape
            # fp32 math: these are recurrence gates (small block-diag matmuls);
            # batched bf16->f32 dots are also unsupported by the CPU backend.
            xb = x.astype(jnp.float32).reshape(*shape[:-1], self.num_blocks, self.block)
            w = params["w"].astype(jnp.float32)
            y = jnp.einsum("...ni,nij->...nj", xb, w)
            y = y + params["b"].astype(jnp.float32)
            return cast(y, policy.compute_dtype).reshape(shape)


class RGLRU(Module):
    kind = "rglru"

    def __init__(self, name: str, dim: int, num_heads: int):
        self.name = name
        self.dim, self.num_heads = dim, num_heads
        self.gate_a = BlockDiagonalLinear("gate_a", dim, num_heads)
        self.gate_x = BlockDiagonalLinear("gate_x", dim, num_heads)

    def spec(self):
        return {
            "lam": ParamSpec((self.dim,), ("embed",), init="normal", scale=0.5,
                             dtype=jnp.float32),
            "gate_a": self.gate_a,
            "gate_x": self.gate_x,
        }

    def _coeffs(self, params, x, ctx):
        """Per-step a_t (decay) and b_t (gated input), fp32."""
        r = jax.nn.sigmoid(self.gate_a(params["gate_a"], x, ctx=ctx).astype(jnp.float32))
        i = jax.nn.sigmoid(self.gate_x(params["gate_x"], x, ctx=ctx).astype(jnp.float32))
        log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * r
        a = jnp.exp(log_a)
        mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        b = mult * (i * x.astype(jnp.float32))
        return a, b

    def __call__(self, params, x, *, ctx: Ctx, state: jax.Array | None = None,
                 mode: str = "dense"):
        """x: (B,S,D). Returns (y, final_state). state: (B,D) fp32."""
        with ctx.scope(self.name):
            policy = ctx.policy()
            B, S, D = x.shape
            a, b = self._coeffs(params, x, ctx)
            if state is None:
                state = jnp.zeros((B, D), jnp.float32)

            if mode == "decode":  # S == 1: one fused step
                h = a[:, 0] * state + b[:, 0]
                return cast(h[:, None], policy.compute_dtype), h

            impl = ctx.impl("rglru", "assoc")
            if impl == "pallas":
                from repro.kernels.rglru.ops import rglru_pallas

                # woven (DSE-tuned) blocks via TunedKernelAspect extras
                h_seq, h_last = rglru_pallas(
                    a, b, state,
                    block_d=int(ctx.extra.get("rglru_block_d", 512)),
                    chunk=int(ctx.extra.get("rglru_chunk", 256)),
                )
            elif impl == "scan":
                from repro.kernels.rglru.ref import rglru_scan

                h_seq, h_last = rglru_scan(a, b, state)
            else:
                from repro.kernels.rglru.ref import rglru_assoc

                h_seq, h_last = rglru_assoc(a, b, state)
            return cast(h_seq, policy.compute_dtype), h_last


class Conv1D(Module):
    """Causal depthwise temporal conv (width 4), with decode state."""

    kind = "conv"

    def __init__(self, name: str, dim: int, width: int = 4):
        self.name = name
        self.dim, self.width = dim, width

    def spec(self):
        return {
            "w": ParamSpec((self.width, self.dim), (None, "embed"), init="scaled",
                           scale=self.width),
            "b": ParamSpec((self.dim,), ("embed",), init="zeros"),
        }

    def __call__(self, params, x, *, ctx: Ctx, state: jax.Array | None = None,
                 mode: str = "dense"):
        """x: (B,S,D); state: (B,width-1,D). Returns (y, new_state)."""
        with ctx.scope(self.name):
            policy = ctx.policy()
            B, S, D = x.shape
            w = cast(params["w"], policy.compute_dtype)
            xc = cast(x, policy.compute_dtype)
            W = self.width
            if state is None:
                state = jnp.zeros((B, W - 1, D), xc.dtype)
            full = jnp.concatenate([cast(state, xc.dtype), xc], axis=1)  # (B, S+W-1, D)
            y = sum(full[:, i : i + S] * w[i] for i in range(W))
            y = y + cast(params["b"], policy.compute_dtype)
            new_state = full[:, -(W - 1):]
            return y, new_state


class RecurrentBlock(Module):
    """Griffin temporal-mixing block: (linear->conv->RG-LRU) * gelu(linear) -> linear."""

    kind = "recurrent"

    def __init__(self, name: str, d_model: int, lru_width: int, num_heads: int,
                 conv_width: int = 4):
        self.name = name
        self.d_model, self.lru_width = d_model, lru_width
        self.num_heads = num_heads
        self.proj_x = Linear("proj_x", d_model, lru_width, axes=("embed", "heads"),
                             out_axes=("batch", "seq_act", "heads"))
        self.proj_y = Linear("proj_y", d_model, lru_width, axes=("embed", "heads"),
                             out_axes=("batch", "seq_act", "heads"))
        self.conv = Conv1D("conv", lru_width, conv_width)
        self.rglru = RGLRU("rglru", lru_width, num_heads)
        self.proj_out = Linear("proj_out", lru_width, d_model, axes=("heads", "embed"),
                               out_axes=("batch", "res_seq", "embed"))

    def spec(self):
        return {
            "proj_x": self.proj_x,
            "proj_y": self.proj_y,
            "conv": self.conv,
            "rglru": self.rglru,
            "proj_out": self.proj_out,
        }

    def init_state(self, batch: int):
        return {
            "conv": jnp.zeros((batch, self.conv.width - 1, self.lru_width), jnp.bfloat16),
            "lru": jnp.zeros((batch, self.lru_width), jnp.float32),
        }

    @staticmethod
    def state_spec(batch: int, lru_width: int, conv_width: int = 4):
        sds = jax.ShapeDtypeStruct
        return {
            "conv": sds((batch, conv_width - 1, lru_width), jnp.bfloat16),
            "lru": sds((batch, lru_width), jnp.float32),
        }

    def __call__(self, params, x, *, ctx: Ctx, state: dict | None = None,
                 mode: str = "dense"):
        with ctx.scope(self.name):
            y = jax.nn.gelu(self.proj_y(params["proj_y"], x, ctx=ctx), approximate=True)
            h = self.proj_x(params["proj_x"], x, ctx=ctx)
            conv_state = state["conv"] if state is not None else None
            lru_state = state["lru"] if state is not None else None
            h, new_conv = self.conv(params["conv"], h, ctx=ctx, state=conv_state, mode=mode)
            h, new_lru = self.rglru(params["rglru"], h, ctx=ctx, state=lru_state, mode=mode)
            out = self.proj_out(params["proj_out"], h * y, ctx=ctx)
            new_state = {"conv": new_conv, "lru": new_lru}
            return out, new_state
