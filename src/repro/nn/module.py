"""Minimal composable module system for the ANTAREX-JAX framework.

Design goals (see DESIGN.md §2): the *functional* model definition is a tree
of `Module` objects with explicit parameter specs carrying *logical axis
names*.  All extra-functional concerns — dtype policies, kernel
implementation selection, sharding rules, remat, monitoring taps — live in a
`Ctx` object that the ANTAREX weaver builds from aspects.  The model code
consults the Ctx; it is never edited.

Parameters are plain nested dicts of jax arrays (a pytree), so they compose
with jit/grad/scan without any framework magic.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import zlib
from typing import Any, Callable, Iterator, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.dtypes import DTypePolicy, PolicyResolver

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

Initializer = str  # "normal" | "zeros" | "ones" | "scaled" | "embedding"


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor.

    ``axes`` holds one *logical* axis name (or None) per dimension; the
    distributed layer maps logical axes to mesh axes (distributed/sharding).
    ``dtype`` of None means "the woven dtype policy decides" (the common
    case); norms etc. may pin fp32.
    """

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: Initializer = "normal"
    scale: float | None = None  # stddev for "normal", fan-in override for "scaled"
    dtype: Any | None = None  # None -> policy param_dtype

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"ParamSpec shape {self.shape} and axes {self.axes} rank mismatch"
            )

    def instantiate(self, key: jax.Array, policy: DTypePolicy) -> jax.Array:
        dtype = self.dtype if self.dtype is not None else policy.param_dtype
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "normal":
            std = self.scale if self.scale is not None else 0.02
            return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dtype)
        if self.init == "scaled":  # 1/sqrt(fan_in) truncated-normal-ish
            fan_in = self.scale if self.scale is not None else self.shape[0]
            std = 1.0 / np.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dtype)
        if self.init == "embedding":
            std = self.scale if self.scale is not None else 1.0
            return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dtype)
        raise ValueError(f"unknown initializer {self.init!r}")

    def shape_dtype(self, policy: DTypePolicy) -> jax.ShapeDtypeStruct:
        dtype = self.dtype if self.dtype is not None else policy.param_dtype
        return jax.ShapeDtypeStruct(self.shape, dtype)


# ---------------------------------------------------------------------------
# Weave-time context
# ---------------------------------------------------------------------------


class Ctx:
    """Carries every woven extra-functional decision through `apply`.

    The weaver (repro/core) builds one of these; model code only *reads* it.
    All fields are trace-time constants except `taps`, which accumulates
    monitor values (jax arrays) during tracing.
    """

    def __init__(
        self,
        *,
        policies: PolicyResolver | None = None,
        impls: Sequence[tuple[str, str, str]] = (),  # (pattern, op_kind, impl)
        mesh: jax.sharding.Mesh | None = None,
        rules: Mapping[str, Any] | None = None,  # logical axis -> mesh axes
        taps_enabled: Sequence[str] = (),  # glob patterns of tap names to record
        deterministic: bool = True,
        rng: jax.Array | None = None,
        extra: Mapping[str, Any] | None = None,
    ):
        self.policies = policies or PolicyResolver.default()
        self.impls = list(impls)
        self.mesh = mesh
        self.rules = dict(rules or {})
        self.taps_enabled = list(taps_enabled)
        self.deterministic = deterministic
        self.rng = rng
        self.extra = dict(extra or {})
        self.taps: dict[str, jax.Array] = {}
        self._path: list[str] = []

    # -- path scoping --------------------------------------------------------

    def scope(self, name: str) -> "_Scope":
        return _Scope(self, name)

    @property
    def path(self) -> str:
        return "/".join(self._path)

    # -- policy / impl resolution --------------------------------------------

    def policy(self) -> DTypePolicy:
        return self.policies.resolve(self.path)

    def impl(self, op_kind: str, default: str) -> str:
        """Resolve the woven implementation for an op kind at current path."""
        chosen = default
        for pattern, kind, impl in self.impls:
            if kind == op_kind and fnmatch.fnmatch(self.path, pattern):
                chosen = impl
        return chosen

    # -- monitoring taps -------------------------------------------------------

    def tap(self, name: str, value: jax.Array) -> None:
        full = f"{self.path}/{name}" if self.path else name
        for pattern in self.taps_enabled:
            if fnmatch.fnmatch(full, pattern):
                self.taps[full] = jnp.asarray(value, jnp.float32)
                return

    # -- sharding constraints --------------------------------------------------

    def constrain(self, x: jax.Array, logical_axes: tuple[str | None, ...]) -> jax.Array:
        if self.mesh is None or not self.rules:
            return x
        from repro.distributed.sharding import logical_to_pspec

        if len(logical_axes) != x.ndim:
            return x
        # activations use "embed_act" (params' "embed" may be FSDP-sharded
        # over the data axis — never wanted on activations)
        axes = tuple("embed_act" if a == "embed" else a for a in logical_axes)
        spec = logical_to_pspec(axes, self.rules, self.mesh, x.shape)
        if spec is None:
            return x
        sharding = jax.sharding.NamedSharding(self.mesh, spec)
        return jax.lax.with_sharding_constraint(x, sharding)


class _Scope:
    def __init__(self, ctx: Ctx, name: str):
        self.ctx, self.name = ctx, name

    def __enter__(self):
        self.ctx._path.append(self.name)
        return self.ctx

    def __exit__(self, *exc):
        self.ctx._path.pop()
        return False


# ---------------------------------------------------------------------------
# Module base
# ---------------------------------------------------------------------------


class Module:
    """A named tree node with parameter specs and a pure apply.

    Subclasses define ``kind`` (the joinpoint kind the ANTAREX selectors match
    on), implement ``spec()`` returning ``{name: ParamSpec | Module}``, and a
    ``__call__(params, ..., ctx=ctx)``.
    """

    kind: str = "module"
    name: str = "module"

    def spec(self) -> dict[str, "ParamSpec | Module"]:
        raise NotImplementedError

    # Attributes exposed to ANTAREX selectors (LARA joinpoint attributes).
    def attrs(self) -> dict[str, Any]:
        out = {}
        for k, v in vars(self).items():
            if isinstance(v, (int, float, str, bool, tuple)) and not k.startswith("_"):
                out[k] = v
        return out

    # -- tree walking ----------------------------------------------------------

    def walk(self, prefix: str = "") -> Iterator[tuple[str, "Module"]]:
        """Yield (path, module) for this module and all descendants."""
        path = f"{prefix}/{self.name}" if prefix else self.name
        yield path, self
        for child_name, child in self.spec().items():
            if isinstance(child, Module):
                yield from child.walk(path)

    def param_specs(self, prefix: str = "") -> dict[str, Any]:
        """Nested dict mirroring the params pytree, of ParamSpec leaves."""
        out: dict[str, Any] = {}
        for child_name, child in self.spec().items():
            if isinstance(child, Module):
                out[child_name] = child.param_specs()
            else:
                out[child_name] = child
        return out


# ---------------------------------------------------------------------------
# Param tree utilities
# ---------------------------------------------------------------------------


def _key_for(path: str, key: jax.Array) -> jax.Array:
    return jax.random.fold_in(key, zlib.crc32(path.encode()) & 0x7FFFFFFF)


def _walk_spec(value, path: str, leaf_fn) -> Any:
    """Generic recursion over spec trees (Module | dict | ParamSpec leaves)."""
    if isinstance(value, Module):
        sub_path = f"{path}/{value.name}" if path else value.name
        return {
            name: _walk_spec(child, sub_path, leaf_fn)
            for name, child in value.spec().items()
        }
    if isinstance(value, Mapping):
        return {
            name: _walk_spec(child, f"{path}/{name}" if path else name, leaf_fn)
            for name, child in value.items()
        }
    return leaf_fn(value, path)


def flatten_specs(module: Module) -> dict[str, ParamSpec]:
    """Flat {path: ParamSpec} (paths relative to, and including, module.name)."""
    flat: dict[str, ParamSpec] = {}

    def leaf(spec: ParamSpec, path: str):
        flat[path] = spec
        return spec

    _walk_spec(module, "", leaf)
    return flat


def init_params(
    module: Module, key: jax.Array, policies: PolicyResolver | None = None
) -> dict[str, Any]:
    """Materialize the parameter pytree (nested dicts keyed by module names)."""
    policies = policies or PolicyResolver.default()

    def leaf(spec: ParamSpec, path: str):
        return spec.instantiate(_key_for(path, key), policies.resolve(path))

    return _walk_spec(module, "", leaf)


def abstract_params(
    module: Module, policies: PolicyResolver | None = None
) -> dict[str, Any]:
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    policies = policies or PolicyResolver.default()

    def leaf(spec: ParamSpec, path: str):
        return spec.shape_dtype(policies.resolve(path))

    return _walk_spec(module, "", leaf)


def param_axes(module: Module) -> dict[str, Any]:
    """Pytree of logical-axes tuples matching the params pytree structure."""
    return _walk_spec(module, "", lambda spec, path: spec.axes)


def param_count(module: Module) -> int:
    return int(sum(np.prod(s.shape) for s in flatten_specs(module).values()))


def cast(x: jax.Array, dtype) -> jax.Array:
    return x if x.dtype == dtype else x.astype(dtype)
