"""RWKV6 "Finch" blocks (arXiv:2404.05892): attention-free LM with
data-dependent decay.

TimeMix: token-shift with data-dependent low-rank interpolation (ddlerp) for
the r/k/v/w/g streams, per-channel decay w_t = exp(-exp(ww_t)) from a
low-rank MLP, and the per-head WKV linear-attention recurrence

    y_t = (S_{t-1} + (u * k_t) v_t^T)^T r_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

ChannelMix: token-shift + squared-ReLU MLP with a sigmoid receptance gate.

The WKV recurrence implementation is woven (ANTAREX kernel aspect):
"scan" (oracle), "chunked" (parallel XLA form, the roofline path) or
"pallas" (TPU kernel, kernels/rwkv6).  Decode carries (x_prev, S) state and
is O(1) per token — `long_500k` runs for this arch.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.blocks import GroupNorm, Linear
from repro.nn.module import Ctx, Module, ParamSpec, cast

DDLERP_RANK = 32
DECAY_RANK = 64
STREAMS = ("w", "k", "v", "r", "g")


def _token_shift(x: jax.Array, x_prev: jax.Array | None) -> jax.Array:
    """Shift sequence right by one; slot 0 gets x_prev (decode carry) or 0."""
    B, S, D = x.shape
    if S == 1:
        prev = jnp.zeros((B, 1, D), x.dtype) if x_prev is None else x_prev[:, None].astype(x.dtype)
        return prev
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x_prev is not None:
        shifted = shifted.at[:, 0].set(x_prev.astype(x.dtype))
    return shifted


class TimeMix(Module):
    kind = "rwkv_time_mix"

    def __init__(self, name: str, d_model: int, head_dim: int = 64):
        self.name = name
        self.d_model, self.head_dim = d_model, head_dim
        assert d_model % head_dim == 0
        self.num_heads = d_model // head_dim
        d = d_model
        self.wr = Linear("wr", d, d, axes=("embed", "heads"), out_axes=("batch", "seq_act", "heads"))
        self.wk = Linear("wk", d, d, axes=("embed", "heads"), out_axes=("batch", "seq_act", "heads"))
        self.wv = Linear("wv", d, d, axes=("embed", "heads"), out_axes=("batch", "seq_act", "heads"))
        self.wg = Linear("wg", d, d, axes=("embed", "heads"), out_axes=("batch", "seq_act", "heads"))
        self.wo = Linear("wo", d, d, axes=("heads", "embed"), out_axes=("batch", "res_seq", "embed"))
        self.norm = GroupNorm("norm", self.num_heads, d)

    def spec(self):
        d = self.d_model
        return {
            "maa_x": ParamSpec((d,), ("embed",), init="normal", scale=0.1),
            "maa": ParamSpec((5, d), (None, "embed"), init="normal", scale=0.1),
            "maa_w1": ParamSpec((d, 5 * DDLERP_RANK), ("embed", None), init="normal",
                                scale=0.01),
            "maa_w2": ParamSpec((5, DDLERP_RANK, d), (None, None, "embed"), init="normal",
                                scale=0.01),
            "decay": ParamSpec((d,), ("embed",), init="normal", scale=0.5,
                               dtype=jnp.float32),
            "decay_w1": ParamSpec((d, DECAY_RANK), ("embed", None), init="normal",
                                  scale=0.01),
            "decay_w2": ParamSpec((DECAY_RANK, d), (None, "embed"), init="normal",
                                  scale=0.01),
            "u": ParamSpec((self.num_heads, self.head_dim), ("heads", None),
                           init="normal", scale=0.5, dtype=jnp.float32),
            "wr": self.wr, "wk": self.wk, "wv": self.wv, "wg": self.wg, "wo": self.wo,
            "norm": self.norm,
        }

    def __call__(self, params, x, *, ctx: Ctx, state: dict | None = None,
                 mode: str = "dense"):
        """state: {"x_prev": (B,D), "wkv": (B,H,hd,hd) fp32}."""
        with ctx.scope(self.name):
            policy = ctx.policy()
            B, S, D = x.shape
            H, hd = self.num_heads, self.head_dim
            x_prev = state["x_prev"] if state is not None else None
            xx = _token_shift(x, x_prev) - x

            # ddlerp: data-dependent interpolation amounts for the 5 streams
            xxx = x + xx * cast(params["maa_x"], x.dtype)
            t = jnp.tanh(jnp.einsum("bsd,dr->bsr", cast(xxx, policy.compute_dtype),
                                    cast(params["maa_w1"], policy.compute_dtype)))
            t = t.reshape(B, S, 5, DDLERP_RANK)
            mix = jnp.einsum("bsnr,nrd->nbsd", t, cast(params["maa_w2"],
                                                       policy.compute_dtype))
            streams = {}
            for i, s in enumerate(STREAMS):
                m = cast(params["maa"][i], x.dtype) + cast(mix[i], x.dtype)
                streams[s] = x + xx * m

            r = self.wr(params["wr"], streams["r"], ctx=ctx).reshape(B, S, H, hd)
            k = self.wk(params["wk"], streams["k"], ctx=ctx).reshape(B, S, H, hd)
            v = self.wv(params["wv"], streams["v"], ctx=ctx).reshape(B, S, H, hd)
            g = jax.nn.silu(self.wg(params["wg"], streams["g"], ctx=ctx))

            ww = params["decay"] + jnp.einsum(
                "bsr,rd->bsd",
                jnp.tanh(jnp.einsum("bsd,dr->bsr",
                                    cast(streams["w"], policy.compute_dtype),
                                    cast(params["decay_w1"], policy.compute_dtype))),
                cast(params["decay_w2"], policy.compute_dtype),
            ).astype(jnp.float32)
            w = jnp.exp(-jnp.exp(jnp.clip(ww, -60.0, 20.0)))  # (B,S,D) in (0,1)
            w = w.reshape(B, S, H, hd)

            s0 = state["wkv"] if state is not None else jnp.zeros((B, H, hd, hd), jnp.float32)
            u = params["u"]

            impl = ctx.impl("wkv", "chunked")
            if impl == "proj_only":
                # roofline component mode: recurrence core costed analytically
                # (tiny k/v/w mix keeps their projections alive through DCE)
                y = r + 1e-30 * (k + v + w.astype(r.dtype))
                s_last = s0
            elif impl == "pallas" and S > 1:
                from repro.kernels.rwkv6.ops import wkv_pallas

                y, s_last = wkv_pallas(r, k, v, w, u, s0,
                                       chunk=int(ctx.extra.get("wkv_chunk", 32)))
            elif impl == "scan" or S == 1:
                from repro.kernels.rwkv6.ref import wkv_scan

                y, s_last = wkv_scan(r, k, v, w, u, s0)
            else:
                from repro.kernels.rwkv6.ref import wkv_chunked

                y, s_last = wkv_chunked(r, k, v, w, u, s0,
                                        chunk=int(ctx.extra.get("wkv_chunk", 32)))

            y = self.norm(params["norm"], y.reshape(B, S, D), ctx=ctx)
            out = self.wo(params["wo"], y * g, ctx=ctx)
            new_state = {"x_prev": x[:, -1].astype(jnp.float32), "wkv": s_last}
            return out, new_state


class ChannelMix(Module):
    kind = "rwkv_channel_mix"

    def __init__(self, name: str, d_model: int, d_ff: int):
        self.name = name
        self.d_model, self.d_ff = d_model, d_ff
        self.wk = Linear("wk", d_model, d_ff, axes=("embed", "mlp"),
                         out_axes=("batch", "seq_act", "mlp"))
        self.wv = Linear("wv", d_ff, d_model, axes=("mlp", "embed"),
                         out_axes=("batch", "res_seq", "embed"))
        self.wr = Linear("wr", d_model, d_model, axes=("embed", None))

    def spec(self):
        d = self.d_model
        return {
            "maa_k": ParamSpec((d,), ("embed",), init="normal", scale=0.1),
            "maa_r": ParamSpec((d,), ("embed",), init="normal", scale=0.1),
            "wk": self.wk, "wv": self.wv, "wr": self.wr,
        }

    def __call__(self, params, x, *, ctx: Ctx, state: dict | None = None,
                 mode: str = "dense"):
        """state: {"x_prev": (B,D)}."""
        with ctx.scope(self.name):
            x_prev = state["x_prev"] if state is not None else None
            xx = _token_shift(x, x_prev) - x
            xk = x + xx * cast(params["maa_k"], x.dtype)
            xr = x + xx * cast(params["maa_r"], x.dtype)
            k = self.wk(params["wk"], xk, ctx=ctx)
            k = jnp.square(jax.nn.relu(k))
            kv = self.wv(params["wv"], k, ctx=ctx)
            out = jax.nn.sigmoid(self.wr(params["wr"], xr, ctx=ctx)) * kv
            new_state = {"x_prev": x[:, -1].astype(jnp.float32)}
            return out, new_state


def rwkv_state_spec(batch: int, d_model: int, head_dim: int = 64):
    """ShapeDtypeStructs for one layer's decode state (time + channel)."""
    sds = jax.ShapeDtypeStruct
    H = d_model // head_dim
    return {
        "time": {
            "x_prev": sds((batch, d_model), jnp.float32),
            "wkv": sds((batch, H, head_dim, head_dim), jnp.float32),
        },
        "channel": {"x_prev": sds((batch, d_model), jnp.float32)},
    }
