"""Core neural blocks: linear/embedding/norms/MLP variants/RoPE.

Every block reads its dtype policy from the woven Ctx (ANTAREX precision
aspects), applies logical-axis sharding constraints on activations, and can
emit monitoring taps.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.module import Ctx, Module, ParamSpec, cast


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------


class Linear(Module):
    """y = x @ w (+ b); w: (d_in, d_out) with logical axes."""

    kind = "linear"

    def __init__(
        self,
        name: str,
        d_in: int,
        d_out: int,
        *,
        axes: tuple[str | None, str | None],
        bias: bool = False,
        out_axes: tuple[str | None, ...] | None = None,
        init_scale: float | None = None,
    ):
        self.name = name
        self.d_in, self.d_out = d_in, d_out
        self.axes = axes
        self.bias = bias
        self.out_axes = out_axes
        self.init_scale = init_scale

    def spec(self):
        s: dict[str, Any] = {
            "w": ParamSpec(
                (self.d_in, self.d_out),
                self.axes,
                init="scaled",
                scale=self.init_scale or self.d_in,
            )
        }
        if self.bias:
            s["b"] = ParamSpec((self.d_out,), (self.axes[1],), init="zeros")
        return s

    def __call__(self, params, x, *, ctx: Ctx):
        with ctx.scope(self.name):
            policy = ctx.policy()
            w = params["w"]
            if policy.quantized:
                w, scale = _quantize_int8(w)
                y = _int8_matmul(cast(x, policy.compute_dtype), w, scale, policy)
            else:
                w = cast(w, policy.compute_dtype)
                y = jnp.dot(
                    cast(x, policy.compute_dtype),
                    w,
                    preferred_element_type=policy.accum_dtype,
                )
            if self.bias:
                y = y + cast(params["b"], policy.accum_dtype)
            y = cast(y, policy.compute_dtype)
            if self.out_axes is not None:
                y = ctx.constrain(y, self.out_axes)
            ctx.tap("out_absmax", jnp.max(jnp.abs(y)))
            return y


def _quantize_int8(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-output-channel int8 quantization (paper's 'fixed')."""
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _int8_matmul(x, wq, scale, policy):
    y = jnp.dot(
        x.astype(policy.compute_dtype),
        wq.astype(policy.compute_dtype),
        preferred_element_type=policy.accum_dtype,
    )
    return y * scale.astype(policy.accum_dtype)


# ---------------------------------------------------------------------------
# Embedding (tied head supported by models calling `attend`)
# ---------------------------------------------------------------------------


class Embedding(Module):
    kind = "embedding"

    def __init__(self, name: str, vocab: int, dim: int, *, scale_by_dim: bool = False):
        self.name = name
        self.vocab, self.dim = vocab, dim
        self.scale_by_dim = scale_by_dim  # gemma multiplies by sqrt(dim)

    def spec(self):
        return {
            "table": ParamSpec(
                (self.vocab, self.dim), ("vocab", "embed"), init="embedding", scale=0.02
            )
        }

    def __call__(self, params, tokens, *, ctx: Ctx):
        with ctx.scope(self.name):
            policy = ctx.policy()
            table = cast(params["table"], policy.compute_dtype)
            x = jnp.take(table, tokens, axis=0)
            if self.scale_by_dim:
                x = x * jnp.asarray(np.sqrt(self.dim), policy.compute_dtype)
            return ctx.constrain(x, ("batch", "res_seq", "embed"))

    def attend(self, params, x, *, ctx: Ctx):
        """Logits = x @ table.T (tied output head)."""
        with ctx.scope(self.name):
            policy = ctx.policy()
            table = cast(params["table"], policy.compute_dtype)
            logits = jnp.dot(
                cast(x, policy.compute_dtype),
                table.T,
                preferred_element_type=policy.accum_dtype,
            )
            return ctx.constrain(logits, ("batch", "res_seq", "vocab"))


# ---------------------------------------------------------------------------
# Norms (fp32 params + fp32 math — standard for stability)
# ---------------------------------------------------------------------------


class RMSNorm(Module):
    kind = "norm"

    def __init__(self, name: str, dim: int, *, eps: float = 1e-6, plus_one: bool = False):
        self.name = name
        self.dim, self.eps = dim, eps
        self.plus_one = plus_one  # gemma parameterizes weight as (1 + w)

    def spec(self):
        init = "zeros" if self.plus_one else "ones"
        return {"w": ParamSpec((self.dim,), ("embed",), init=init, dtype=jnp.float32)}

    def __call__(self, params, x, *, ctx: Ctx):
        with ctx.scope(self.name):
            policy = ctx.policy()
            w = params["w"] + 1.0 if self.plus_one else params["w"]
            if ctx.impl("norm", "xla") == "pallas":
                # fused Pallas path (forward-only — woven for serving, where
                # nothing differentiates through the norm); block_rows is the
                # DSE-tuned knob TunedKernelAspect threads through
                from repro.kernels.rmsnorm.ops import rmsnorm

                y = rmsnorm(x, w, eps=self.eps,
                            block_rows=int(ctx.extra.get("rms_block_rows", 256)))
                return cast(y, policy.compute_dtype)
            xf = x.astype(jnp.float32)
            var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
            y = xf * jax.lax.rsqrt(var + self.eps) * w
            ctx.tap("rms", jnp.sqrt(jnp.mean(var)))
            return cast(y, policy.compute_dtype)


class LayerNorm(Module):
    kind = "norm"

    def __init__(self, name: str, dim: int, *, eps: float = 1e-5):
        self.name = name
        self.dim, self.eps = dim, eps

    def spec(self):
        return {
            "w": ParamSpec((self.dim,), ("embed",), init="ones", dtype=jnp.float32),
            "b": ParamSpec((self.dim,), ("embed",), init="zeros", dtype=jnp.float32),
        }

    def __call__(self, params, x, *, ctx: Ctx):
        with ctx.scope(self.name):
            policy = ctx.policy()
            xf = x.astype(jnp.float32)
            mean = jnp.mean(xf, axis=-1, keepdims=True)
            var = jnp.var(xf, axis=-1, keepdims=True)
            y = (xf - mean) * jax.lax.rsqrt(var + self.eps) * params["w"] + params["b"]
            return cast(y, policy.compute_dtype)


class GroupNorm(Module):
    """Per-head group norm (RWKV6 time-mixing output norm)."""

    kind = "norm"

    def __init__(self, name: str, num_groups: int, dim: int, *, eps: float = 1e-5):
        self.name = name
        self.num_groups, self.dim, self.eps = num_groups, dim, eps

    def spec(self):
        return {
            "w": ParamSpec((self.dim,), ("embed",), init="ones", dtype=jnp.float32),
            "b": ParamSpec((self.dim,), ("embed",), init="zeros", dtype=jnp.float32),
        }

    def __call__(self, params, x, *, ctx: Ctx):
        with ctx.scope(self.name):
            policy = ctx.policy()
            shape = x.shape
            xf = x.astype(jnp.float32).reshape(*shape[:-1], self.num_groups, -1)
            mean = jnp.mean(xf, axis=-1, keepdims=True)
            var = jnp.var(xf, axis=-1, keepdims=True)
            y = ((xf - mean) * jax.lax.rsqrt(var + self.eps)).reshape(shape)
            y = y * params["w"] + params["b"]
            return cast(y, policy.compute_dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu2":  # nemotron squared-ReLU
        r = jax.nn.relu(x)
        return r * r
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


class MLP(Module):
    """Gated (llama/gemma) or plain (whisper/nemotron) feed-forward."""

    kind = "mlp"

    def __init__(
        self,
        name: str,
        d_model: int,
        d_ff: int,
        *,
        activation: str = "silu",
        gated: bool = True,
        bias: bool = False,
    ):
        self.name = name
        self.d_model, self.d_ff = d_model, d_ff
        self.activation, self.gated, self.bias = activation, gated, bias
        self.wi = Linear(
            "wi", d_model, d_ff, axes=("embed", "mlp"), bias=bias,
            out_axes=("batch", "seq_act", "mlp"),
        )
        self.wg = (
            Linear("wg", d_model, d_ff, axes=("embed", "mlp"), bias=bias,
                   out_axes=("batch", "seq_act", "mlp"))
            if gated
            else None
        )
        self.wo = Linear(
            "wo", d_ff, d_model, axes=("mlp", "embed"), bias=bias,
            out_axes=("batch", "res_seq", "embed"),
        )

    def spec(self):
        s: dict[str, Any] = {"wi": self.wi, "wo": self.wo}
        if self.wg is not None:
            s["wg"] = self.wg
        return s

    def __call__(self, params, x, *, ctx: Ctx):
        with ctx.scope(self.name):
            h = self.wi(params["wi"], x, ctx=ctx)
            if self.wg is not None:
                g = self.wg(params["wg"], x, ctx=ctx)
                h = _act(self.activation, g) * h
            else:
                h = _act(self.activation, h)
            return self.wo(params["wo"], h, ctx=ctx)


# ---------------------------------------------------------------------------
# Rotary position embedding (functional)
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: int32[...]; returns (sin, cos) of shape positions.shape + (head_dim//2,)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., seq, heads, head_dim); sin/cos: (..., seq, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :]  # broadcast over heads
    c = cos[..., None, :]
    out = jnp.concatenate(
        [x1.astype(jnp.float32) * c - x2.astype(jnp.float32) * s,
         x2.astype(jnp.float32) * c + x1.astype(jnp.float32) * s],
        axis=-1,
    )
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings for arbitrary positions."""
    half = dim // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (np.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
