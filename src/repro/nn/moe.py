"""Mixture-of-Experts feed-forward (mixtral / grok style: 8 experts, top-2).

Dispatch is the classic Mesh-TensorFlow capacity-based einsum formulation:
tokens are grouped (one group per batch row), each token's top-k experts get
a one-hot (expert, capacity-slot) assignment, and dispatch/combine are dense
einsums — the formulation GSPMD partitions well on TPU.  Tokens overflowing
an expert's capacity are dropped (standard; capacity_factor knob controls
the trade-off and is exposed to the ANTAREX autotuner).

With 8 experts against a 16-way model axis, expert parallelism does not
divide; the woven default layout replicates experts and applies tensor
parallelism *inside* each expert (mlp -> model axis).  See DESIGN.md §5.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.module import Ctx, Module, ParamSpec, cast


class MoEMLP(Module):
    kind = "moe"

    def __init__(
        self,
        name: str,
        d_model: int,
        d_ff: int,
        *,
        num_experts: int,
        top_k: int = 2,
        capacity_factor: float = 1.25,
        activation: str = "silu",
    ):
        self.name = name
        self.d_model, self.d_ff = d_model, d_ff
        self.num_experts, self.top_k = num_experts, top_k
        self.capacity_factor = capacity_factor
        self.activation = activation

    def spec(self):
        E, dm, dff = self.num_experts, self.d_model, self.d_ff
        return {
            "router": ParamSpec((dm, E), ("embed", None), init="scaled", scale=dm),
            "wi": ParamSpec((E, dm, dff), ("experts", "embed", "mlp"), init="scaled", scale=dm),
            "wg": ParamSpec((E, dm, dff), ("experts", "embed", "mlp"), init="scaled", scale=dm),
            "wo": ParamSpec((E, dff, dm), ("experts", "mlp", "embed"), init="scaled", scale=dff),
        }

    def __call__(self, params, x, *, ctx: Ctx):
        with ctx.scope(self.name):
            policy = ctx.policy()
            B, S, dm = x.shape
            E, K = self.num_experts, self.top_k
            cf = float(ctx.extra.get("moe_capacity_factor", self.capacity_factor))
            # Bounded dispatch groups: the one-hot dispatch/combine einsums
            # cost O(tokens x E x C x d) with C ∝ group size — grouping by
            # the full sequence (32k prefill!) made dispatch dominate expert
            # compute 20:1.  Fixed-size sequence groups bound the overhead
            # (knob: moe_group_size; §Perf mixtral iteration).
            grp = int(ctx.extra.get("moe_group_size", 2048))
            grp = max(1, min(grp, S))
            while S % grp:
                grp -= 1
            n_groups = S // grp
            C = max(int(np.ceil(grp * K * cf / E)), 1)

            xc = cast(x, policy.compute_dtype)
            if n_groups > 1:
                xc = xc.reshape(B * n_groups, grp, dm)
            Bg, Sg = xc.shape[0], grp
            # --- routing (fp32 for stable softmax/top-k) ---
            logits = jnp.einsum(
                "bsd,de->bse", xc, cast(params["router"], policy.compute_dtype),
                preferred_element_type=jnp.float32,
            )
            gates = jax.nn.softmax(logits, axis=-1)  # (Bg,Sg,E)
            topg, tope = jax.lax.top_k(gates, K)  # (Bg,Sg,K)
            topg = topg / jnp.sum(topg, axis=-1, keepdims=True)

            # --- capacity assignment: rank of each (token,k) within its expert ---
            onehot = jax.nn.one_hot(tope, E, dtype=jnp.float32)  # (Bg,Sg,K,E)
            flat = onehot.reshape(Bg, Sg * K, E)
            ranks = (jnp.cumsum(flat, axis=1) - flat).reshape(Bg, Sg, K, E)
            rank = jnp.sum(ranks * onehot, axis=-1)  # (B,S,K)
            keep = rank < C
            ctx.tap("moe_drop_frac", 1.0 - jnp.mean(keep.astype(jnp.float32)))

            gate_kept = jnp.where(keep, topg, 0.0)
            slot_oh = jax.nn.one_hot(rank.astype(jnp.int32), C, dtype=jnp.float32)
            # combine[b,s,e,c] = sum_k gate * onehot_e * onehot_c
            combine = jnp.einsum("bske,bskc->bsec", onehot * gate_kept[..., None], slot_oh)
            dispatch = (combine > 0).astype(policy.compute_dtype)  # (B,S,E,C)
            combine = combine.astype(policy.compute_dtype)

            # --- dispatch -> expert compute -> combine ---
            expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch, xc)
            wi = cast(params["wi"], policy.compute_dtype)
            wg = cast(params["wg"], policy.compute_dtype)
            wo = cast(params["wo"], policy.compute_dtype)
            h = jnp.einsum("ebcd,edf->ebcf", expert_in, wi,
                           preferred_element_type=policy.accum_dtype)
            g = jnp.einsum("ebcd,edf->ebcf", expert_in, wg,
                           preferred_element_type=policy.accum_dtype)
            if self.activation == "silu":
                h = jax.nn.silu(cast(g, policy.compute_dtype)) * cast(h, policy.compute_dtype)
            else:
                h = jax.nn.gelu(cast(g, policy.compute_dtype), approximate=True) * cast(
                    h, policy.compute_dtype
                )
            h = ctx.constrain(h, ("experts", "batch", None, "mlp"))
            out_e = jnp.einsum("ebcf,efd->ebcd", h, wo,
                               preferred_element_type=policy.accum_dtype)
            out = jnp.einsum("ebcd,bsec->bsd", cast(out_e, policy.compute_dtype),
                             combine.astype(policy.compute_dtype))
            if n_groups > 1:
                out = out.reshape(B, S, dm)
            out = ctx.constrain(out, ("batch", "res_seq", "embed"))
            return cast(out, policy.compute_dtype)

    def active_params_per_token(self) -> int:
        """Parameters touched per token (router + top_k experts) for MODEL_FLOPS."""
        per_expert = self.d_model * self.d_ff * 3
        return self.d_model * self.num_experts + self.top_k * per_expert
