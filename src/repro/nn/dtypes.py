"""Dtype policies — the substrate of the ANTAREX precision-tuning aspects.

A `DTypePolicy` is the TPU analogue of the paper's double/float/half/fixed
choice: storage (param) dtype, compute dtype (MXU input) and accumulation
dtype.  The `PolicyResolver` holds an ordered list of (glob-pattern, policy)
entries; the *last* matching pattern wins, so aspects append overrides —
exactly the paper's "change the type of the declarations inside this
function" with path patterns standing in for AST selection.

"fixed point" from the paper maps to int8 storage with fp32 scales
(`quantized=True`), dequantized on load — the TPU-native reduced-precision
representation.
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Any

import jax.numpy as jnp

_DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
    "int8": jnp.int8,
}


def parse_dtype(d: Any):
    if isinstance(d, str):
        return _DTYPES[d]
    return d


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    accum_dtype: Any = jnp.float32
    quantized: bool = False  # int8 weights + per-channel fp32 scales
    # KV-cache pool storage format ("int8" / "float8_e4m3fn" / ...): the
    # paper's fixed-point declaration-retyping applied to the *cache* kind —
    # pk/pv stored narrow with per-page fp32 scales, dequantized on load
    cache_dtype: str | None = None

    @staticmethod
    def make(name: str) -> "DTypePolicy":
        """Named policies mirroring the paper's precision levels.

        double -> f32 everywhere;  float -> bf16 compute / f32 params;
        half   -> bf16 params+compute;  fixed -> int8 weights (emulated);
        cache_<dtype> -> quantized KV-cache pool at <dtype>.
        """
        if name in ("double", "f32", "float32"):
            return DTypePolicy(jnp.float32, jnp.float32, jnp.float32)
        if name in ("float", "mixed", "bf16_mixed"):
            return DTypePolicy(jnp.float32, jnp.bfloat16, jnp.float32)
        if name in ("half", "bf16", "bfloat16"):
            return DTypePolicy(jnp.bfloat16, jnp.bfloat16, jnp.float32)
        if name in ("fixed", "int8"):
            return DTypePolicy(jnp.bfloat16, jnp.bfloat16, jnp.float32, quantized=True)
        if name.startswith("cache_"):
            return DTypePolicy(jnp.bfloat16, jnp.bfloat16, jnp.float32,
                               cache_dtype=name[len("cache_"):])
        raise ValueError(f"unknown policy name {name!r}")


class PolicyResolver:
    """Ordered (pattern, policy) table; last match wins."""

    def __init__(self, entries: list[tuple[str, DTypePolicy]] | None = None):
        self.entries: list[tuple[str, DTypePolicy]] = list(entries or [])

    @staticmethod
    def default(base: str = "half") -> "PolicyResolver":
        return PolicyResolver([("*", DTypePolicy.make(base))])

    def override(self, pattern: str, policy: DTypePolicy | str) -> "PolicyResolver":
        if isinstance(policy, str):
            policy = DTypePolicy.make(policy)
        self.entries.append((pattern, policy))
        return self

    def resolve(self, path: str) -> DTypePolicy:
        found = DTypePolicy()
        for pattern, policy in self.entries:
            if fnmatch.fnmatch(path, pattern):
                found = policy
        return found

    def copy(self) -> "PolicyResolver":
        return PolicyResolver(list(self.entries))

    def __repr__(self):
        return f"PolicyResolver({self.entries!r})"
