"""Sharded AdamW with fp32 master weights, global-norm clipping, and
optional int8 error-feedback gradient compression (distributed-optimization
trick for the DCN-crossing pod axis; see optim/compression.py).

Optimizer state leaves inherit the parameter shardings (GSPMD propagates
them through the update), so FSDP layouts shard m/v/master identically to
the params — the ZeRO posture required to fit the ≥70B trains on v5e HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.compression import ef_compress_tree


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compression: bool = False  # int8 EF quantize-dequant on grads
    state_dtype: str = "float32"  # m/v dtype: "float32" | "bfloat16" (memory knob)


def init_state(params, cfg: AdamWConfig) -> dict:
    sdt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    f32 = lambda p: jnp.zeros(p.shape, sdt)
    state = {
        # copy=True: fp32 leaves (norms) would otherwise alias the live
        # params — fatal when both trees are donated to the train step
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
        ),
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.compression:
        state["ef"] = jax.tree.map(f32, params)
    return state


def abstract_state(params_sds, cfg: AdamWConfig) -> dict:
    sdt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    sds_f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, sdt)
    state = {
        "master": jax.tree.map(sds_f32, params_sds),
        "m": jax.tree.map(sds, params_sds),
        "v": jax.tree.map(sds, params_sds),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.compression:
        state["ef"] = jax.tree.map(sds, params_sds)
    return state


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(params, grads, state, cfg: AdamWConfig, lr: jax.Array):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    new_ef = None
    if cfg.compression:
        grads, new_ef = ef_compress_tree(grads, state["ef"])

    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(master, g, m, v):
        m = (cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g).astype(m.dtype)
        v = (cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)).astype(v.dtype)
        mh = m.astype(jnp.float32) / c1
        vh = v.astype(jnp.float32) / c2
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master
        master = master - lr * step
        return master, m, v

    flat_master, treedef = jax.tree.flatten(state["master"])
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    outs = [upd(*args) for args in zip(flat_master, flat_g, flat_m, flat_v)]
    new_master = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])

    new_params = jax.tree.map(
        lambda master, p: master.astype(p.dtype), new_master, params
    )
    new_state = {"master": new_master, "m": new_m, "v": new_v, "count": count}
    if cfg.compression:
        new_state["ef"] = new_ef
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
