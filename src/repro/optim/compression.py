"""int8 gradient compression with error feedback.

On a real pod fleet the int8 representation (plus one fp32 scale per
tensor-row) is what crosses the DCN pod axis — a ~3.9x wire reduction on
the slowest collective (see EXPERIMENTS.md §Perf).  Numerically the
transform is quantize -> dequantize with the residual carried to the next
step (error feedback), which is exactly what we implement and test for
convergence; the wire-level gain is accounted in the roofline analysis
(Pallas/XLA cannot express an int8 all-reduce portably from jit today).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-row (last dim) symmetric int8; scalars/small tensors pass through."""
    absmax = jnp.max(jnp.abs(g), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jax.Array, ef: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Error-feedback compression of one fp32 tensor."""
    if g.ndim == 0 or g.size < 128:
        return g, ef
    corrected = g + ef
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale)
    return deq, corrected - deq


def ef_compress_tree(grads, ef_tree):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_tree)
    outs = [ef_compress(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in outs]),
        jax.tree.unflatten(treedef, [o[1] for o in outs]),
    )


def compressed_bytes(tree) -> int:
    """Wire bytes if the tree crossed a link int8-compressed."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        if leaf.ndim == 0 or leaf.size < 128:
            total += leaf.size * 4
        else:
            rows = leaf.size // leaf.shape[-1]
            total += leaf.size + rows * 4  # int8 payload + fp32 scales
    return total
