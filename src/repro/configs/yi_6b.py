"""yi-6b — llama-arch dense GQA LM [arXiv:2403.04652; hf 01-ai/Yi-6B]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab=64000,
    activation="silu",
    gated_mlp=True,
    norm_type="rmsnorm",
    tie_embeddings=False,
    rope_theta=5_000_000.0,
    notes="GQA kv=4; full attention -> long_500k skipped.",
)
