"""Model/shape configuration schema covering all assigned architecture
families, plus the four assigned input-shape cells.

Every architecture file in this package instantiates `ModelConfig` with the
exact published numbers (sources in each file) and provides `reduced()`
smoke configs for CPU tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any

# ---------------------------------------------------------------------------
# Shapes (assigned; identical across LM-family archs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | encdec | vlm | hybrid | ssm
    num_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # MLP / block details
    activation: str = "silu"  # silu | gelu | relu2
    gated_mlp: bool = True
    qkv_bias: bool = False
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    norm_plus_one: bool = False  # gemma (1 + w) RMSNorm
    embed_scale: bool = False  # gemma sqrt(d_model) embedding scale
    tie_embeddings: bool = True
    use_rope: bool = True
    rope_theta: float = 10000.0

    # attention flavour
    attn_window: int | None = None  # sliding-window size (mixtral / local attn)
    attn_softcap: float | None = None  # grok logit soft-cap

    # MoE
    num_experts: int = 0
    top_k: int = 2

    # hybrid (recurrentgemma / griffin)
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    local_window: int = 2048

    # ssm (rwkv6)
    rwkv_head_dim: int = 64

    # enc-dec (whisper)
    enc_layers: int = 0

    # vlm
    num_image_tokens: int = 0

    # distribution defaults (weavable; see distributed/sharding.py)
    layer_groups: tuple[int, ...] = ()  # () -> one group with all layers

    notes: str = ""

    # -- derived -----------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (windowed / recurrent decode)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path (no encoder-only)

    def supported_shapes(self) -> list[str]:
        out = ["train_4k", "prefill_32k", "decode_32k"]
        if self.sub_quadratic:
            out.append("long_500k")
        return out

    def param_count(self) -> int:
        """Analytic total parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.num_layers
        hd = self.resolved_head_dim
        H, K = self.n_heads, self.kv_heads
        attn = d * H * hd + 2 * d * K * hd + H * hd * d
        mlp = d * f * (3 if self.gated_mlp else 2)
        if self.family == "moe":
            mlp = self.num_experts * mlp + d * self.num_experts
        per_layer = attn + mlp + 2 * d
        if self.family == "ssm":
            dr = self.rwkv_head_dim
            time_mix = 5 * d * d + d * d + (5 * d + 5 * 32 * d + d * 32 * 5) + (
                d * 64 + 64 * d + d
            )
            chan = d * f + f * d + d * d
            per_layer = time_mix + chan + 4 * d
        if self.family == "hybrid":
            lw = self.lru_width or d
            nb = max(self.n_heads, 1)
            rec = 2 * d * lw + lw * d + 4 * lw + 2 * (nb * (lw // nb) ** 2) + lw
            att = attn
            pat = self.block_pattern or ("rec", "rec", "attn")
            n_rec = sum(1 for i in range(L) if pat[i % len(pat)] == "rec")
            n_att = L - n_rec
            per_layer = 0  # handled below
            body = n_rec * (rec + mlp + 2 * d) + n_att * (att + mlp + 2 * d)
            return body + V * d * (1 if self.tie_embeddings else 2)
        body = L * per_layer
        if self.family == "encdec":
            body += self.enc_layers * (attn + mlp + 2 * d) + L * (attn + d)  # + cross
        embed = V * d * (1 if self.tie_embeddings else 2)
        return body + embed

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        attn = d * self.n_heads * hd + 2 * d * self.kv_heads * hd + self.n_heads * hd * d
        mlp_active = self.top_k * d * f * 3 + d * self.num_experts
        body = L * (attn + mlp_active + 2 * d)
        return body + self.vocab * d * (1 if self.tie_embeddings else 2)

    def groups(self) -> tuple[int, ...]:
        if self.layer_groups:
            assert sum(self.layer_groups) == self.num_layers
            return self.layer_groups
        return (self.num_layers,)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
