"""whisper-small — enc-dec audio backbone [arXiv:2212.04356].

The conv frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S, d_model).  Sinusoidal positions replace
whisper's learned/fixed tables so the assigned 4k/32k cells are
well-defined (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    enc_layers=12,
    d_model=768,
    n_heads=12,
    kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    activation="gelu",
    gated_mlp=False,
    norm_type="layernorm",
    tie_embeddings=True,
    use_rope=False,
    notes="Enc-dec: encoder and decoder both run at the cell's seq_len. "
    "Full attention -> long_500k skipped.",
)
