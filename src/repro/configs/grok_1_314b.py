"""grok-1-314b — MoE (8 experts, top-2) with attention logit soft-cap
[hf xai-org/grok-1; unverified]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    n_heads=48,
    kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    activation="gelu",
    gated_mlp=True,
    num_experts=8,
    top_k=2,
    attn_softcap=30.0,
    norm_type="rmsnorm",
    tie_embeddings=True,
    rope_theta=10000.0,
    layer_groups=(32, 32),
    notes="Full attention -> long_500k skipped. Soft-capped logits (30).",
)
