"""mixtral-8x22b — MoE (8 experts, top-2) with sliding-window attention
[arXiv:2401.04088; hf mistralai/Mixtral-8x22B]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    n_heads=48,
    kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    activation="silu",
    gated_mlp=True,
    num_experts=8,
    top_k=2,
    attn_window=4096,
    norm_type="rmsnorm",
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    notes="SWA window 4096 -> ring KV cache, long_500k RUNS. "
    "8 experts vs 16-way model axis: experts replicated, TP inside experts.",
)
