"""internvl2-1b — VLM: InternViT frontend (STUB: patch embeddings provided
by input_specs) + Qwen2-0.5B-class LM backbone [arXiv:2404.16821; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    n_heads=14,
    kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151655,
    activation="silu",
    gated_mlp=True,
    qkv_bias=True,
    norm_type="rmsnorm",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    num_image_tokens=256,
    notes="Patch embeddings stubbed (256 image tokens prepended). "
    "Full attention -> long_500k skipped.",
)
