"""recurrentgemma-2b — Griffin hybrid: RG-LRU recurrent blocks + local
attention in a (rec, rec, attn) pattern [arXiv:2402.19427; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    n_heads=10,
    kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    activation="gelu",
    gated_mlp=True,
    norm_type="rmsnorm",
    norm_plus_one=True,
    embed_scale=True,
    tie_embeddings=True,
    block_pattern=("rec", "rec", "attn"),
    lru_width=2560,
    local_window=2048,
    notes="Recurrent state + windowed attention -> long_500k RUNS "
    "(O(window) decode). MQA on the attention blocks.",
)
