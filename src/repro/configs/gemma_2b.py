"""gemma-2b — dense MQA LM with GeGLU, head_dim 256 [arXiv:2403.08295; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    n_heads=8,
    kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    activation="gelu",
    gated_mlp=True,
    norm_type="rmsnorm",
    norm_plus_one=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
    notes="MQA (kv=1): KV replicated across TP; decode KV cache sequence-sharded. "
    "Full attention -> long_500k skipped.",
)
