"""nemotron-4-340b — dense GQA LM with squared-ReLU MLP [arXiv:2402.16819].

Deviations noted in DESIGN.md: full-dim RoPE (paper uses partial rotary);
LayerNorm per the paper; non-gated squared-ReLU MLP (d_ff 73728).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    n_heads=96,
    kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab=256000,
    activation="relu2",
    gated_mlp=False,
    norm_type="layernorm",
    tie_embeddings=False,
    rope_theta=10000.0,
    layer_groups=(48, 48),
    notes="Largest cell: FSDP x TP, grad accumulation, full remat. "
    "Full attention -> long_500k skipped.",
)
