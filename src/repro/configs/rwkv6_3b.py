"""rwkv6-3b — "Finch": attention-free RNN-LM with data-dependent decay
[arXiv:2404.05892; hf RWKV/rwkv-6-world-3b]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    n_heads=40,  # d_model / rwkv_head_dim
    kv_heads=40,
    d_ff=8960,
    vocab=65536,
    norm_type="layernorm",
    tie_embeddings=False,
    use_rope=False,
    rwkv_head_dim=64,
    notes="Attention-free: attention-sharding aspects inapplicable "
    "(DESIGN.md §5); O(1)-state decode -> long_500k RUNS.",
)
