"""qwen2-72b — dense GQA LM with QKV bias [arXiv:2407.10671; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    activation="silu",
    gated_mlp=True,
    qkv_bias=True,
    norm_type="rmsnorm",
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    notes="GQA kv=8, QKV bias. Train cell needs FSDP+TP+accum. "
    "Full attention -> long_500k skipped.",
)
