"""Joinpoint model — the JAX analogue of Clava's C/C++ AST joinpoints.

A `Program` (core/program.py) exposes a tree of joinpoints: one per module
in the model tree plus synthetic program-level points (the step functions).
Selectors (LARA `select`) query them; aspects (LARA `apply`) act on them
through the Weaver, which records analysis/transformation metrics exactly in
the spirit of the paper's Tables 1–2 (selects, attributes, actions,
inserts).
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Any, Callable, Iterable

from repro.nn.module import Module


@dataclasses.dataclass
class JoinPoint:
    path: str  # e.g. "yi_6b/blocks0/block/attn"
    kind: str  # module kind: attention | mlp | moe | norm | ... | step | model
    module: Module | None = None
    _attrs: dict[str, Any] = dataclasses.field(default_factory=dict)
    _access_counter: list[int] | None = None  # shared counter from the weaver

    def attr(self, name: str, default: Any = None) -> Any:
        """Attribute access (counted — the paper's 'Attributes' metric)."""
        if self._access_counter is not None:
            self._access_counter[0] += 1
        return self._attrs.get(name, default)

    def attrs(self) -> dict[str, Any]:
        if self._access_counter is not None:
            self._access_counter[0] += len(self._attrs)
        return dict(self._attrs)

    @property
    def name(self) -> str:
        return self.path.rsplit("/", 1)[-1]

    def matches(self, pattern: str) -> bool:
        return fnmatch.fnmatch(self.path, pattern) or fnmatch.fnmatch(
            self.name, pattern
        )

    def __repr__(self):
        return f"JoinPoint({self.path!r}, kind={self.kind!r})"


def build_joinpoints(model: Module, step_kinds: Iterable[str] = ("train_step", "serve_step")) -> list[JoinPoint]:
    jps: list[JoinPoint] = []
    for path, mod in model.walk():
        jps.append(JoinPoint(path=path, kind=mod.kind, module=mod, _attrs=mod.attrs()))
    root = model.name
    for sk in step_kinds:
        jps.append(JoinPoint(path=f"{root}/{sk}", kind="step", _attrs={"step": sk}))
    return jps


class Selector:
    """LARA-style `select`: filter joinpoints by kind / path pattern / predicate.

    Chainable:  sel.kind("attention").where(lambda jp: jp.attr("kv_heads") < 4)
    Every evaluation is counted by the weaver ("Selects" in Table 2).
    """

    def __init__(self, joinpoints: list[JoinPoint], on_select: Callable[[int], None] | None = None):
        self._jps = joinpoints
        self._on_select = on_select or (lambda n: None)

    def _derive(self, jps: list[JoinPoint]) -> "Selector":
        self._on_select(1)
        return Selector(jps, self._on_select)

    def all(self) -> list[JoinPoint]:
        return list(self._jps)

    def kind(self, kind: str) -> "Selector":
        return self._derive([j for j in self._jps if j.kind == kind])

    def path(self, pattern: str) -> "Selector":
        return self._derive([j for j in self._jps if j.matches(pattern)])

    def where(self, pred: Callable[[JoinPoint], bool]) -> "Selector":
        return self._derive([j for j in self._jps if pred(j)])

    def __iter__(self):
        return iter(self._jps)

    def __len__(self):
        return len(self._jps)
