"""The Weaver — Clava's role in the ANTAREX tool flow, for JAX programs.

Aspects call `select(...)` to query joinpoints and action methods
(`def_policy`, `set_impl`, `set_rule`, `set_extra`, `add_tap`, `add_knob`,
`add_variant`, `wrap_step`) to transform the weave state.  The weaver
records the paper's static/dynamic weaving metrics (Tables 1–2): selects
issued, joinpoint attributes analysed, actions taken, and inserts
(actions that add code to the woven program rather than only analysing).

The output is a `WovenProgram`: the untouched functional Program plus the
final WeaveState, named variants (for libVC multi-versioning), the knob
space (for mARGOt) and the metrics report.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.core.joinpoint import JoinPoint, Selector, build_joinpoints
from repro.core.knob import Knob, KnobSpace
from repro.core.program import Program, WeaveState
from repro.nn.dtypes import DTypePolicy


@dataclasses.dataclass
class AspectMetrics:
    name: str
    selects: int = 0
    attributes: int = 0
    actions: int = 0
    inserts: int = 0


@dataclasses.dataclass
class WeaveReport:
    per_aspect: list[AspectMetrics] = dataclasses.field(default_factory=list)

    def totals(self) -> AspectMetrics:
        t = AspectMetrics("TOTAL")
        for m in self.per_aspect:
            t.selects += m.selects
            t.attributes += m.attributes
            t.actions += m.actions
            t.inserts += m.inserts
        return t

    def table(self) -> str:
        rows = [f"{'Aspect':28s} {'Selects':>8s} {'Attrs':>8s} {'Actions':>8s} {'Inserts':>8s}"]
        for m in self.per_aspect + [self.totals()]:
            rows.append(
                f"{m.name:28s} {m.selects:8d} {m.attributes:8d} {m.actions:8d} {m.inserts:8d}"
            )
        return "\n".join(rows)


@dataclasses.dataclass
class WovenProgram:
    program: Program
    state: WeaveState
    variants: dict[str, WeaveState]
    knobs: KnobSpace
    report: WeaveReport

    def variant_state(self, name: str | None) -> WeaveState:
        if name is None or name == "__default__":
            return self.state
        return self.variants[name]


class Weaver:
    def __init__(self, program: Program):
        self.program = program
        self.state = WeaveState()
        self.variants: dict[str, WeaveState] = {}
        self.knobs = KnobSpace()
        self.report = WeaveReport()
        self._joinpoints = build_joinpoints(program.model)
        self._attr_counter = [0]
        for jp in self._joinpoints:
            jp._access_counter = self._attr_counter
        self._current: AspectMetrics | None = None

    # -- select ------------------------------------------------------------------

    def select(self, pattern: str | None = None, *, kind: str | None = None) -> Selector:
        if self._current is not None:
            self._current.selects += 1
        sel = Selector(self._joinpoints, self._count_select)
        if kind is not None:
            sel = sel.kind(kind)
        if pattern is not None:
            sel = sel.path(pattern)
        return sel

    def _count_select(self, n: int) -> None:
        if self._current is not None:
            self._current.selects += n

    # -- actions -----------------------------------------------------------------

    def _action(self, inserts: int = 0) -> None:
        if self._current is not None:
            self._current.actions += 1
            self._current.inserts += inserts

    def def_policy(self, target: "JoinPoint | str", policy: DTypePolicy | str) -> None:
        pattern = target.path + "*" if isinstance(target, JoinPoint) else target
        self.state.policies.override(pattern, policy)
        self._action()

    def set_impl(self, target: "JoinPoint | str", op_kind: str, impl: str) -> None:
        pattern = target.path + "*" if isinstance(target, JoinPoint) else target
        self.state.impls.append((pattern, op_kind, impl))
        self._action(inserts=1)

    def set_rule(self, logical_axis: str, mesh_axes: Any) -> None:
        self.state.rules[logical_axis] = mesh_axes
        self._action()

    def set_extra(self, key: str, value: Any) -> None:
        self.state.extra[key] = value
        self._action()

    def add_tap(self, pattern: str) -> None:
        self.state.taps.append(pattern)
        self._action(inserts=1)

    def add_knob(self, knob: Knob) -> None:
        self.knobs.add(knob)
        self._action(inserts=1)

    def wrap_step(self, wrapper: Callable) -> None:
        """Host-level instrumentation around the step (timers, sensors...)."""
        self.state.step_wrappers.append(wrapper)
        self._action(inserts=1)

    def set_priority(self, priority: int) -> None:
        self.state.priority = priority
        self._action()

    def add_variant(self, name: str, mutate: Callable[[WeaveState], None]) -> None:
        """Clone the current weave state, apply `mutate` — the function-clone
        + type-change idiom (CreateFloatVersion) at weave-state granularity."""
        st = self.state.copy()
        mutate(st)
        self.variants[name] = st
        self._action(inserts=1)

    # -- aspect application --------------------------------------------------------

    def apply(self, aspect: "Aspect") -> None:
        metrics = AspectMetrics(aspect.name)
        self._current = metrics
        before = self._attr_counter[0]
        aspect.apply(self)
        metrics.attributes = self._attr_counter[0] - before
        self.report.per_aspect.append(metrics)
        self._current = None

    def weave(self, aspects: list["Aspect"]) -> WovenProgram:
        for a in aspects:
            self.apply(a)
        return WovenProgram(
            program=self.program,
            state=self.state,
            variants=self.variants,
            knobs=self.knobs,
            report=self.report,
        )


class Aspect:
    """Base class for ANTAREX aspects (LARA aspectdef analogue)."""

    name = "aspect"

    def apply(self, weaver: Weaver) -> None:
        raise NotImplementedError


def weave(program: Program, aspects: list[Aspect]) -> WovenProgram:
    return Weaver(program).weave(aspects)
