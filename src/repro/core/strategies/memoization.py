"""Memoization aspects (paper §2.4, Figs. 8–9).

The paper wraps pure C/C++ functions with a lookup table.  The JAX-native
equivalents (DESIGN.md §2) are host-level: serving request caches, compiled
executable caches, and DSE-result caches.  The aspect exposes the same
surface as the paper's Memoize_Method: table size, replacement policy,
approximation bits (float-key quantization), persistence files, full
offline mode, and a runtime stop/run toggle — all implemented by
repro.memo.table.MemoTable.
"""

from __future__ import annotations

from typing import Any

from repro.core.weaver import Aspect, Weaver
from repro.memo.table import MemoTable


class MemoizeStep(Aspect):
    """Wrap the (pure) serve step with a MemoTable keyed on request content."""

    name = "Memoize_Method"

    def __init__(
        self,
        *,
        tsize: int = 65536,
        replace: bool = True,
        approx_bits: int = 0,
        file_to_load: str | None = None,
        file_to_save: str | None = None,
        full_offline: bool = False,
    ):
        self.table = MemoTable(
            size=tsize,
            replace=replace,
            approx_bits=approx_bits,
            load_path=file_to_load,
            save_path=file_to_save,
            full_offline=full_offline,
        )

    def apply(self, weaver: Weaver) -> None:
        steps = weaver.select(kind="step").where(lambda j: j.attr("step") == "serve_step")
        if not len(steps.all()):
            steps = weaver.select(kind="step")
        from repro.monitor.sensors import memo_wrapper

        weaver.set_extra("memo_table", self.table)
        weaver.wrap_step(memo_wrapper(self.table))


def find_memoizable(weaver: Weaver) -> list[str]:
    """The paper's 'automatically detect memoizable functions': any pure
    joinpoint without per-call randomness or mutable state is eligible."""
    out = []
    for jp in weaver.select():
        if jp.kind in ("embedding", "norm", "mlp"):  # deterministic, side-effect-free
            out.append(jp.path)
    return out
