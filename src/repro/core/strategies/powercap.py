"""Power-capping aspect (paper §2.7): attach a task priority and register
the step with the PowerCapper runtime, which allocates the node power budget
across tasks by priority (application-aware, unlike plain RAPL)."""

from __future__ import annotations

from repro.core.weaver import Aspect, Weaver


class PowerPriority(Aspect):
    name = "PowerPriority"

    def __init__(self, priority: int, capper=None):
        self.priority = priority
        self.capper = capper

    def apply(self, weaver: Weaver) -> None:
        weaver.set_priority(self.priority)
        if self.capper is not None:
            from repro.monitor.sensors import powercap_wrapper

            weaver.wrap_step(powercap_wrapper(self.capper, self.priority))
