"""Code-versioning aspects (paper §2.3, Figs. 5–7).

`Multiversion` weaves a runtime switch between the default weave and named
variants, keyed by an autotuner knob — the paper's generated C `switch`
(Fig. 6) becomes a libVC-JAX dispatcher over AOT-compiled executables, with
per-version timing (the paper's Timer.time on both calls) provided by the
monitoring wrapper.

`SpecializeCall` is SimpleLibVC (Fig. 7): compile a specialized version with
runtime-discovered constants baked in as trace-time constants (+ compile
options), and replace the call.
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence

from repro.core.knob import Knob
from repro.core.weaver import Aspect, Weaver


class Multiversion(Aspect):
    name = "Multiversion"

    def __init__(self, knob_name: str, variants: Sequence[str] | None = None,
                 *, time_versions: bool = True):
        self.knob_name = knob_name
        self.variants = variants
        self.time_versions = time_versions

    def apply(self, weaver: Weaver) -> None:
        # identify the step call joinpoint (the paper identifies the call by
        # name and type signature)
        steps = weaver.select(kind="step").all()
        if not steps:
            raise ValueError("program exposes no step joinpoints")
        names = list(self.variants if self.variants is not None else weaver.variants)
        values = tuple(["__default__"] + [n for n in names if n != "__default__"])
        weaver.add_knob(Knob(self.knob_name, values, "__default__"))
        if self.time_versions:
            from repro.monitor.sensors import timing_wrapper

            weaver.wrap_step(timing_wrapper(label_from_knob=self.knob_name))


class SpecializeCall(Aspect):
    """Bake runtime constants into a dedicated variant (libVC specialize)."""

    name = "SimpleLibVC"

    def __init__(self, version_name: str, constants: Mapping[str, Any],
                 compile_options: Mapping[str, Any] | None = None):
        self.version_name = version_name
        self.constants = dict(constants)
        self.compile_options = dict(compile_options or {})

    def apply(self, weaver: Weaver) -> None:
        consts, opts = self.constants, self.compile_options

        def mutate(state):
            for k, v in consts.items():
                state.extra[k] = v
            state.extra.setdefault("compile_options", {}).update(opts)

        weaver.add_variant(self.version_name, mutate)
