"""Kernel-substitution aspects: weave Pallas implementations (or block-size
choices) onto compute joinpoints — the TPU analogue of the paper's compiler
-flag / code-variant selection (§2.3).

`TunedKernelAspect` closes the DSE->autotuner loop at weave time: it builds
the program's flash-attention signature, consults the persistent kernel-tuner
cache (repro.autotune.kernel_tuner), and weaves the tuned block sizes in as
extras — so a woven program automatically runs with DSE-selected blocks, and
exposes them as knobs for mARGOt refinement."""

from __future__ import annotations

from repro.core.knob import Knob
from repro.core.weaver import Aspect, Weaver


class KernelAspect(Aspect):
    name = "KernelSubstitution"

    def __init__(self, pattern: str, op_kind: str, impl: str, *,
                 expose_knob: bool = False, impls: tuple[str, ...] = ()):
        self.pattern, self.op_kind, self.impl = pattern, op_kind, impl
        self.expose_knob = expose_knob
        self.impls = impls or (impl,)

    def apply(self, weaver: Weaver) -> None:
        matched = weaver.select(self.pattern).all()
        for jp in matched:
            jp.attr("kind")
        weaver.set_impl(self.pattern, self.op_kind, self.impl)
        if self.expose_knob:
            weaver.add_knob(Knob(f"{self.op_kind}_impl", self.impls, self.impl))


class BlockSizeAspect(Aspect):
    name = "KernelBlockSizes"

    def __init__(self, **sizes: int):
        self.sizes = sizes  # e.g. flash_block_q=512, flash_block_kv=1024, wkv_chunk=32

    def apply(self, weaver: Weaver) -> None:
        for key, val in self.sizes.items():
            weaver.set_extra(key, val)


class TunedKernelAspect(Aspect):
    """Weave DSE-tuned kernel block sizes from the tuner cache.

    For every tunable kernel the program actually contains — flash attention
    (`attention` joinpoints, including the decode and paged-decode serving
    signatures), the WKV recurrence (`rwkv_time_mix`) and the RG-LRU
    (`rglru`) — builds the problem signature, looks it up in the
    persistent cache and, on a hit, sets the corresponding extras
    (`flash_block_q[_bwd]` / `flash_block_kv[_bwd]`, `flash_block_kv_dec`,
    `flash_page_size`, `wkv_chunk`, `rglru_block_d` / `rglru_chunk`) and
    exposes the tuned values as knobs for the dynamic autotuner.  On a miss it leaves the defaults untouched —
    tuning itself is explicit (benchmarks / launch tooling), never a weave
    side effect — unless `tune_on_miss=True`.
    """

    name = "TunedKernelBlocks"

    def __init__(self, batch: int, seq_len: int, *, dtype: str = "bfloat16",
                 cache_len: int | None = None,
                 tuner=None, tune_on_miss: bool = False,
                 expose_knobs: bool = True):
        self.batch, self.seq_len, self.dtype = batch, seq_len, dtype
        self.cache_len = cache_len  # decode-signature cache length
        self.tuner = tuner
        self.tune_on_miss = tune_on_miss
        self.expose_knobs = expose_knobs

    def signature(self, cfg):
        from repro.autotune.kernel_tuner import flash_signature

        return flash_signature(
            (self.batch, self.seq_len, cfg.n_heads, cfg.resolved_head_dim),
            cfg.kv_heads, self.dtype,
            causal=True, window=cfg.attn_window,
        )

    def decode_signature(self, cfg):
        """Serving decode: one token against a cache of `cache_len` slots
        (ring caches clamp to the window — the cache *is* the window)."""
        from repro.autotune.kernel_tuner import flash_decode_signature

        cache_len = self.cache_len or self.seq_len
        window = cfg.attn_window
        if window is not None and window < cache_len:
            cache_len, window = window, None  # ring layout
        return flash_decode_signature(
            self.batch, cache_len, cfg.n_heads, cfg.kv_heads,
            cfg.resolved_head_dim, self.dtype, window=window,
        )

    def paged_signature(self, cfg):
        """Paged serving decode: the same problem as `decode_signature`
        but against the shared page pool, adding the `page_size` pool-
        geometry knob (jointly tuned with `block_kv_dec`)."""
        from repro.autotune.kernel_tuner import paged_decode_signature

        cache_len = self.cache_len or self.seq_len
        window = cfg.attn_window
        if window is not None and window < cache_len:
            cache_len, window = window, None  # ring layout
        return paged_decode_signature(
            self.batch, cache_len, cfg.n_heads, cfg.kv_heads,
            cfg.resolved_head_dim, self.dtype, window=window,
        )

    def quantized_signature(self, cfg):
        """Quantized-pool serving: the accuracy-constrained dtype×geometry
        DSE.  The signature keys the fp *reference* dtype; `cache_dtype`
        itself is a knob the space explores (with fp names as the
        accuracy-fallback arm)."""
        from repro.autotune.kernel_tuner import quantized_cache_signature

        cache_len = self.cache_len or self.seq_len
        window = cfg.attn_window
        if window is not None and window < cache_len:
            cache_len, window = window, None  # ring layout
        return quantized_cache_signature(
            self.batch, cache_len, cfg.n_heads, cfg.kv_heads,
            cfg.resolved_head_dim, self.dtype, window=window,
        )

    def speculative_signature(self, cfg):
        """Speculative verify step: same problem geometry as the decode
        signatures, but the knob is the draft span itself (`draft_len`
        scales the widened q tile; acceptance-refined at runtime)."""
        from repro.autotune.kernel_tuner import speculative_signature

        cache_len = self.cache_len or self.seq_len
        window = cfg.attn_window
        if window is not None and window < cache_len:
            cache_len, window = window, None  # ring layout
        return speculative_signature(
            self.batch, cache_len, cfg.n_heads, cfg.kv_heads,
            cfg.resolved_head_dim, self.dtype, window=window,
        )

    def rmsnorm_signature(self, cfg):
        from repro.autotune.kernel_tuner import rmsnorm_signature

        return rmsnorm_signature(self.batch * self.seq_len, cfg.d_model,
                                 self.dtype)

    def rwkv_signature(self, cfg):
        from repro.autotune.kernel_tuner import rwkv6_signature

        return rwkv6_signature(self.batch, self.seq_len, cfg.d_model,
                               cfg.rwkv_head_dim, self.dtype)

    def rglru_signature(self, cfg):
        from repro.autotune.kernel_tuner import rglru_signature

        return rglru_signature(self.batch, self.seq_len,
                               cfg.lru_width or cfg.d_model, self.dtype)

    def _knobs_for(self, tuner, sig):
        knobs = tuner.lookup(sig)
        if knobs is None and self.tune_on_miss:
            knobs = tuner.tune(sig)
        return knobs

    def _weave(self, weaver, kernel: str, knobs, extras: dict[str, str]):
        """Set extras and expose knobs for one kernel's tuned values.

        `extras` maps knob name in the tuner space -> extra key consumed by
        the nn layer (e.g. "chunk" -> "wkv_chunk").
        """
        from repro.autotune.kernel_tuner import KERNEL_SPACES

        space = KERNEL_SPACES[kernel]
        for name, extra_key in extras.items():
            if name not in knobs:  # e.g. pre-bwd cache entries
                continue
            val = knobs[name]
            # categorical knobs (cache_dtype) weave as strings; geometry
            # knobs stay ints
            val = val if isinstance(val, str) else int(val)
            weaver.set_extra(extra_key, val)
            if self.expose_knobs:
                if isinstance(val, str):
                    values = tuple(space[name]) if val in space[name] \
                        else tuple(space[name]) + (val,)
                else:
                    values = tuple(sorted(set(space[name]) | {val}))
                weaver.add_knob(Knob(extra_key, values, val))

    def apply(self, weaver: Weaver) -> None:
        from repro.autotune.kernel_tuner import default_tuner

        tuner = self.tuner or default_tuner()
        cfg = weaver.program.cfg

        attn_jps = weaver.select(kind="attention").all()
        if attn_jps:
            for jp in attn_jps:
                jp.attr("kind")
            knobs = self._knobs_for(tuner, self.signature(cfg))
            if knobs:
                self._weave(weaver, "flash_attention", knobs, {
                    "block_q": "flash_block_q",
                    "block_kv": "flash_block_kv",
                    "block_q_bwd": "flash_block_q_bwd",
                    "block_kv_bwd": "flash_block_kv_bwd",
                })
            dec_knobs = self._knobs_for(tuner, self.decode_signature(cfg))
            if dec_knobs:
                self._weave(weaver, "flash_decode", dec_knobs,
                            {"block_kv_dec": "flash_block_kv_dec"})
            paged_knobs = self._knobs_for(tuner, self.paged_signature(cfg))
            if paged_knobs:
                # a paged entry wins over the plain decode entry: a server
                # running the pool should stream the jointly-tuned blocks
                self._weave(weaver, "paged_decode", paged_knobs, {
                    "page_size": "flash_page_size",
                    "block_kv_dec": "flash_block_kv_dec",
                })
            q_knobs = self._knobs_for(tuner, self.quantized_signature(cfg))
            if q_knobs:
                # the accuracy-constrained dtype×geometry entry wins over
                # the fp paged entry: the pool stores what the DSE picked
                # (fp dtype values resolve to "keep the fp pool")
                self._weave(weaver, "quantized_cache", q_knobs, {
                    "cache_dtype": "flash_cache_dtype",
                    "page_size": "flash_page_size",
                    "block_kv_dec": "flash_block_kv_dec",
                })
            spec_knobs = self._knobs_for(tuner,
                                         self.speculative_signature(cfg))
            if spec_knobs:
                # a tuned draft span turns speculative serving on: the
                # server reads "speculative_draft_len" from the woven
                # extras and drafts/verifies in k+1-token rounds
                self._weave(weaver, "speculative", spec_knobs, {
                    "draft_len": "speculative_draft_len",
                })

        norm_jps = weaver.select(kind="norm").all()
        if norm_jps and cfg.norm_type == "rmsnorm":
            for jp in norm_jps:
                jp.attr("kind")
            knobs = self._knobs_for(tuner, self.rmsnorm_signature(cfg))
            if knobs:
                self._weave(weaver, "rmsnorm", knobs,
                            {"block_rows": "rms_block_rows"})

        wkv_jps = weaver.select(kind="rwkv_time_mix").all()
        if wkv_jps:
            for jp in wkv_jps:
                jp.attr("kind")
            knobs = self._knobs_for(tuner, self.rwkv_signature(cfg))
            if knobs:
                self._weave(weaver, "rwkv6", knobs, {"chunk": "wkv_chunk"})

        rglru_jps = weaver.select(kind="rglru").all()
        if rglru_jps:
            for jp in rglru_jps:
                jp.attr("kind")
            knobs = self._knobs_for(tuner, self.rglru_signature(cfg))
            if knobs:
                self._weave(weaver, "rglru", knobs, {
                    "block_d": "rglru_block_d",
                    "chunk": "rglru_chunk",
                })
