"""Kernel-substitution aspects: weave Pallas implementations (or block-size
choices) onto compute joinpoints — the TPU analogue of the paper's compiler
-flag / code-variant selection (§2.3)."""

from __future__ import annotations

from repro.core.knob import Knob
from repro.core.weaver import Aspect, Weaver


class KernelAspect(Aspect):
    name = "KernelSubstitution"

    def __init__(self, pattern: str, op_kind: str, impl: str, *,
                 expose_knob: bool = False, impls: tuple[str, ...] = ()):
        self.pattern, self.op_kind, self.impl = pattern, op_kind, impl
        self.expose_knob = expose_knob
        self.impls = impls or (impl,)

    def apply(self, weaver: Weaver) -> None:
        matched = weaver.select(self.pattern).all()
        for jp in matched:
            jp.attr("kind")
        weaver.set_impl(self.pattern, self.op_kind, self.impl)
        if self.expose_knob:
            weaver.add_knob(Knob(f"{self.op_kind}_impl", self.impls, self.impl))


class BlockSizeAspect(Aspect):
    name = "KernelBlockSizes"

    def __init__(self, **sizes: int):
        self.sizes = sizes  # e.g. flash_block_q=512, flash_block_kv=1024, wkv_chunk=32

    def apply(self, weaver: Weaver) -> None:
        for key, val in self.sizes.items():
            weaver.set_extra(key, val)
