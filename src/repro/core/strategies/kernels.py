"""Kernel-substitution aspects: weave Pallas implementations (or block-size
choices) onto compute joinpoints — the TPU analogue of the paper's compiler
-flag / code-variant selection (§2.3).

`TunedKernelAspect` closes the DSE->autotuner loop at weave time: it builds
the program's flash-attention signature, consults the persistent kernel-tuner
cache (repro.autotune.kernel_tuner), and weaves the tuned block sizes in as
extras — so a woven program automatically runs with DSE-selected blocks, and
exposes them as knobs for mARGOt refinement."""

from __future__ import annotations

from repro.core.knob import Knob
from repro.core.weaver import Aspect, Weaver


class KernelAspect(Aspect):
    name = "KernelSubstitution"

    def __init__(self, pattern: str, op_kind: str, impl: str, *,
                 expose_knob: bool = False, impls: tuple[str, ...] = ()):
        self.pattern, self.op_kind, self.impl = pattern, op_kind, impl
        self.expose_knob = expose_knob
        self.impls = impls or (impl,)

    def apply(self, weaver: Weaver) -> None:
        matched = weaver.select(self.pattern).all()
        for jp in matched:
            jp.attr("kind")
        weaver.set_impl(self.pattern, self.op_kind, self.impl)
        if self.expose_knob:
            weaver.add_knob(Knob(f"{self.op_kind}_impl", self.impls, self.impl))


class BlockSizeAspect(Aspect):
    name = "KernelBlockSizes"

    def __init__(self, **sizes: int):
        self.sizes = sizes  # e.g. flash_block_q=512, flash_block_kv=1024, wkv_chunk=32

    def apply(self, weaver: Weaver) -> None:
        for key, val in self.sizes.items():
            weaver.set_extra(key, val)


class TunedKernelAspect(Aspect):
    """Weave DSE-tuned flash-attention block sizes from the tuner cache.

    Looks up the (batch, seq, heads, kv_heads, head_dim, dtype, mask)
    signature in the persistent cache; on a hit, sets the `flash_block_*`
    extras and exposes block knobs (tuned value as default) for the dynamic
    autotuner.  On a miss it leaves the defaults untouched — tuning itself
    is explicit (benchmarks / launch tooling), never a weave side effect —
    unless `tune_on_miss=True`.
    """

    name = "TunedKernelBlocks"

    def __init__(self, batch: int, seq_len: int, *, dtype: str = "bfloat16",
                 tuner=None, tune_on_miss: bool = False,
                 expose_knobs: bool = True):
        self.batch, self.seq_len, self.dtype = batch, seq_len, dtype
        self.tuner = tuner
        self.tune_on_miss = tune_on_miss
        self.expose_knobs = expose_knobs

    def signature(self, cfg):
        from repro.autotune.kernel_tuner import flash_signature

        return flash_signature(
            (self.batch, self.seq_len, cfg.n_heads, cfg.resolved_head_dim),
            cfg.kv_heads, self.dtype,
            causal=True, window=cfg.attn_window,
        )

    def apply(self, weaver: Weaver) -> None:
        from repro.autotune.kernel_tuner import default_tuner

        attn_jps = weaver.select(kind="attention").all()
        if not attn_jps:  # nothing to tune (ssm/recurrent-only programs)
            return
        for jp in attn_jps:
            jp.attr("kind")
        tuner = self.tuner or default_tuner()
        sig = self.signature(weaver.program.cfg)
        knobs = tuner.lookup(sig)
        if knobs is None and self.tune_on_miss:
            knobs = tuner.tune(sig)
        if not knobs:
            return
        bq, bkv = int(knobs["block_q"]), int(knobs["block_kv"])
        weaver.set_extra("flash_block_q", bq)
        weaver.set_extra("flash_block_kv", bkv)
        if self.expose_knobs:
            from repro.autotune.kernel_tuner import KERNEL_SPACES

            space = KERNEL_SPACES["flash_attention"]
            for name, default in (("block_q", bq), ("block_kv", bkv)):
                values = tuple(sorted(set(space[name]) | {default}))
                weaver.add_knob(Knob(f"flash_{name}", values, default))
