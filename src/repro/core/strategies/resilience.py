"""Resilience aspect: fault injection + recovery policies for serving.

The ANTAREX position (PAPER.md; also the AOP building-block argument of
arXiv:2203.13431) is that *extra-functional* properties — performance,
precision, power, and here **resilience** — are woven at named join points
rather than entangled with application logic.  `Server.serve_continuous`
exposes the serving join points

    admit          a request enters the pool (admission control + prefill)
    paged_prefill  the direct-to-pool prefill / re-score dispatch
    decode_step    a plain one-token batched decode step
    verify_step    a widened-q speculative verify step
    draft_step     one draft-model proposal step
    cow            copy-on-write splits before a step's pool writes
    rollback       speculative-misprediction page rollback
    retire         a request's pages return to the pool

and consults the woven `FaultInjector` at each of them.  The injector is
deterministic and seedable: a scheduled `FaultSpec` fires on the N-th
visit of its join point (or at a seeded per-visit rate), raising
(`raise` / `pool_exhausted`), poisoning logits (`nan_logits`), or forcing
a request past its SLO (`deadline`).  The server's recovery machinery —
per-request quarantine, structured rejection, speculation degradation,
bounded retry, deadline retirement — is what the injected faults exercise;
with no injector woven, serving is bit-identical to the fault-free path.

`ResilienceAspect` is the LARA-style aspect that binds an injector and the
recovery *policy* (per-request deadline, step watchdog deadline, retry
budget/backoff, speculation patience, pool auditing) into the weave state
(`fault_injector` / `serve_resilience` extras) without the serving loop
ever knowing where the schedule came from.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Sequence

import numpy as np

from repro.core.weaver import Aspect, Weaver

JOIN_POINTS = ("admit", "paged_prefill", "decode_step", "verify_step",
               "draft_step", "cow", "rollback", "retire")
# fleet-level join points (runtime/fleet.ServingFleet): one routing
# decision, one replica dispatch, one drain check — the injector drives the
# kill-a-replica / SIGTERM-drain sweeps the same way it drives the serving
# sweep.  Kept separate from JOIN_POINTS so the within-replica fault sweep
# (benchmarks/robustness, tests) keeps its exact 8-point matrix.
FLEET_JOIN_POINTS = ("route", "replica_loss", "drain")
ALL_JOIN_POINTS = JOIN_POINTS + FLEET_JOIN_POINTS
FAULT_KINDS = ("raise", "nan_logits", "pool_exhausted", "deadline")

# default recovery policy the server falls back to when no ResilienceAspect
# was woven and the ServerConfig leaves the knobs unset
DEFAULT_POLICY: dict[str, Any] = {
    "deadline_s": None,        # per-request SLO (None: no deadline)
    "step_deadline_s": None,   # Watchdog deadline per target step
    "retries": 2,              # bounded retry around transient step faults
    "backoff_s": 0.0,          # base backoff between retries (doubles)
    "spec_patience": None,     # all-reject verify rounds before degrading
    #                            speculation (None: never — a mispredicting
    #                            foreign draft is legal and still makes one
    #                            token of progress per round, so degradation
    #                            is an opt-in latency policy, not a default)
    "pool_audit": False,       # PoolAuditor at retire/rollback barriers
}


class FaultError(RuntimeError):
    """Base class for faults the serving loop isolates per-request."""


class InjectedFault(FaultError):
    """A `raise`-kind injected fault (carries the resolved FaultSpec)."""

    def __init__(self, msg: str, *, spec: "FaultSpec | None" = None):
        super().__init__(msg)
        self.spec = spec


class NonFiniteLogits(FaultError):
    """NaN/Inf logits detected at admission — the victim is rejected."""


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault: fire `kind` on the `at`-th visit (0-based,
    counting retries) of join point `point`.  `rid` pins the victim
    request; None resolves to the request at the join point (admission)
    or the first request of the current batch.  `repeat` fires the spec
    on `repeat` consecutive visits starting at `at`."""

    point: str
    kind: str
    at: int = 0
    rid: Any = None
    repeat: int = 1

    def __post_init__(self):
        if self.point not in ALL_JOIN_POINTS:
            raise ValueError(f"unknown join point {self.point!r}; "
                             f"one of {ALL_JOIN_POINTS}")
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")


class FaultInjector:
    """Deterministic, seedable fault schedule over the serving join points.

    Two modes, composable:
      * scheduled — explicit `FaultSpec`s (or (point, kind[, at[, rid]])
        tuples) fire on exact visit counts;
      * seeded-random — with `rate` > 0, every visit draws from a
        `np.random.default_rng(seed)` stream and fires a random kind from
        `kinds` with probability `rate` (deterministic given the visit
        sequence).

    `fire(point, ...)` is the weave hook the server calls at each join
    point: it raises for `raise` / `pool_exhausted` kinds (the caller's
    recovery path catches them) and *returns* the resolved spec for
    `nan_logits` / `deadline` (the caller applies the poison / SLO
    overrun).  Every fired fault is recorded in `events`.
    """

    def __init__(self, faults: Iterable[FaultSpec | tuple | dict] = (), *,
                 seed: int | None = None, rate: float = 0.0,
                 kinds: Sequence[str] = FAULT_KINDS):
        self._seed = seed
        self.rate = float(rate)
        self.kinds = tuple(kinds)
        for k in self.kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        self._schedule: list[FaultSpec] = [self._coerce(f) for f in faults]
        self._remaining: list[int] = [s.repeat for s in self._schedule]
        self._rng = np.random.default_rng(seed)
        self.visits: dict[str, int] = {p: 0 for p in ALL_JOIN_POINTS}
        self.events: list[dict[str, Any]] = []

    @staticmethod
    def _coerce(f) -> FaultSpec:
        if isinstance(f, FaultSpec):
            return f
        if isinstance(f, dict):
            return FaultSpec(**f)
        return FaultSpec(*f)

    @classmethod
    def single(cls, point: str, kind: str, *, at: int = 0,
               rid: Any = None) -> "FaultInjector":
        """One fault, once — the bench/test sweep's unit schedule."""
        return cls([FaultSpec(point, kind, at=at, rid=rid)])

    @property
    def armed(self) -> bool:
        """True while any fault can still fire (the server bypasses the
        memo table for armed serves — injected results must never be
        memoized, and memo hits would skip the join points entirely)."""
        return self.rate > 0.0 or any(r > 0 for r in self._remaining)

    def reset(self) -> None:
        """Restore the full schedule and reseed the random stream — the
        same injector replays the same fault sequence."""
        self._remaining = [s.repeat for s in self._schedule]
        self._rng = np.random.default_rng(self._seed)
        self.visits = {p: 0 for p in ALL_JOIN_POINTS}
        self.events = []

    def _match(self, point: str, visit: int) -> FaultSpec | None:
        for i, spec in enumerate(self._schedule):
            if (spec.point == point and self._remaining[i] > 0
                    and spec.at <= visit < spec.at + spec.repeat):
                self._remaining[i] -= 1
                return spec
        return None

    def fire(self, point: str, *, rid: Any = None,
             rids: Sequence[Any] | None = None) -> FaultSpec | None:
        """Visit a join point.  Returns None (no fault), raises
        InjectedFault / PoolExhausted (`raise` / `pool_exhausted` kinds),
        or returns the resolved FaultSpec (`nan_logits` / `deadline`) for
        the caller to apply.  Visits count retries, so a retried step that
        consumed its one-shot fault passes clean on the next visit."""
        from repro.runtime.pages import PoolExhausted

        if point not in ALL_JOIN_POINTS:
            raise ValueError(f"unknown join point {point!r}")
        visit = self.visits[point]
        self.visits[point] = visit + 1
        spec = self._match(point, visit)
        if spec is None and self.rate > 0.0:
            if float(self._rng.random()) < self.rate:
                spec = FaultSpec(point, self.kinds[
                    int(self._rng.integers(len(self.kinds)))], at=visit)
        if spec is None:
            return None
        victim = spec.rid
        if victim is None:
            victim = rid if rid is not None else (
                rids[0] if rids else None)
        fired = FaultSpec(point=point, kind=spec.kind, at=visit, rid=victim)
        self.events.append({"point": point, "kind": spec.kind,
                            "visit": visit, "rid": victim})
        if spec.kind == "raise":
            raise InjectedFault(
                f"injected fault at {point} (visit {visit})", spec=fired)
        if spec.kind == "pool_exhausted":
            raise PoolExhausted(
                f"injected pool exhaustion at {point} (visit {visit})")
        return fired

    def stats(self) -> dict[str, Any]:
        by_point: dict[str, int] = {}
        by_kind: dict[str, int] = {}
        for ev in self.events:
            by_point[ev["point"]] = by_point.get(ev["point"], 0) + 1
            by_kind[ev["kind"]] = by_kind.get(ev["kind"], 0) + 1
        return {"fired": len(self.events), "by_point": by_point,
                "by_kind": by_kind, "visits": dict(self.visits),
                "armed": self.armed}


class ResilienceAspect(Aspect):
    """Weave fault tolerance into continuous serving.

    Binds a `FaultInjector` (optional — production serves weave only the
    policy) and the recovery policy knobs into the weave state:

      * `fault_injector`   consulted by the serving join points;
      * `serve_resilience` {deadline_s, step_deadline_s, retries,
                            backoff_s, spec_patience, pool_audit} — the
                            degradation/deadline policy the server applies
                            (explicit ServerConfig fields still win).

    The analysis pass selects the attention joinpoints (the page pool
    hosts their K/V — resilience guards exactly the state those blocks
    own), mirroring how the cache-dtype and kernel aspects account their
    weaving metrics.
    """

    name = "Resilience"

    def __init__(self, injector: FaultInjector | None = None, *,
                 deadline_s: float | None = None,
                 step_deadline_s: float | None = None,
                 retries: int = 2, backoff_s: float = 0.0,
                 spec_patience: int | None = 3, pool_audit: bool = False):
        self.injector = injector
        self.policy = {
            "deadline_s": deadline_s,
            "step_deadline_s": step_deadline_s,
            "retries": int(retries),
            "backoff_s": float(backoff_s),
            "spec_patience": None if spec_patience is None else int(spec_patience),
            "pool_audit": bool(pool_audit),
        }

    def apply(self, weaver: Weaver) -> None:
        for jp in weaver.select("*", kind="attention"):
            jp.attr("kind")
        if self.injector is not None:
            weaver.set_extra("fault_injector", self.injector)
        weaver.set_extra("serve_resilience", dict(self.policy))


# default fleet recovery policy (runtime/fleet.ServingFleet falls back to
# this when no FleetResilienceAspect was woven and the constructor leaves
# the knobs unset)
DEFAULT_FLEET_POLICY: dict[str, Any] = {
    "retries": 2,              # re-dispatches per request after replica loss
    "backoff_s": 0.0,          # base backoff before a re-dispatch (doubles)
    "deadline_s": None,        # per-request fleet SLO (None: no deadline)
    "affinity": True,          # prefix-affinity routing (else least-loaded)
    "wave_size": 4,            # requests routed to one replica per round
    "dead_after_rounds": 1.5,  # missed-beat rounds before a replica is dead
    "straggler_factor": 2.0,   # HeartbeatMonitor straggler threshold
    "straggler_patience": 3,   # consecutive slow rounds before flagging
}


class FleetResilienceAspect(Aspect):
    """Weave the fleet-level serving policy (runtime/fleet.ServingFleet).

    The same AOP argument one level up: replica placement, prefix-affinity
    routing, replica-loss re-dispatch and graceful drain are extra-
    functional concerns of the *fleet*, woven as extras rather than
    hard-coded into the router:

      * `fleet_injector`    consulted at the fleet join points
                            (`route`, `replica_loss`, `drain`);
      * `fleet_resilience`  {retries, backoff_s, deadline_s, affinity,
                            wave_size, dead_after_rounds, straggler_factor,
                            straggler_patience} — explicit ServingFleet
                            constructor arguments still win.

    The analysis pass selects the attention join points exactly like
    `ResilienceAspect`: the fleet's unit of placement is a replica whose
    page pool hosts attention K/V — the state replica loss puts at risk.
    """

    name = "FleetResilience"

    def __init__(self, injector: FaultInjector | None = None, *,
                 retries: int = 2, backoff_s: float = 0.0,
                 deadline_s: float | None = None, affinity: bool = True,
                 wave_size: int = 4, dead_after_rounds: float = 1.5,
                 straggler_factor: float = 2.0, straggler_patience: int = 3):
        self.injector = injector
        self.policy = {
            "retries": int(retries),
            "backoff_s": float(backoff_s),
            "deadline_s": deadline_s,
            "affinity": bool(affinity),
            "wave_size": int(wave_size),
            "dead_after_rounds": float(dead_after_rounds),
            "straggler_factor": float(straggler_factor),
            "straggler_patience": int(straggler_patience),
        }

    def apply(self, weaver: Weaver) -> None:
        for jp in weaver.select("*", kind="attention"):
            jp.attr("kind")
        if self.injector is not None:
            weaver.set_extra("fleet_injector", self.injector)
        weaver.set_extra("fleet_resilience", dict(self.policy))
