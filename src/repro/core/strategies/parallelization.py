"""Parallelization aspects (paper §4.1, Fig. 12 — the OpenMP/MPI analogue).

On TPU pods the parallelization degrees of freedom are mesh-axis mappings
(DP/FSDP/TP/SP), remat policy, gradient-accumulation factor, and collective
compression.  `AutoShard` plays the role of the paper's auto-parallelization
library: static analysis of the model (head counts, expert counts, param
sizes vs HBM) chooses a layout; `validate_rules` is the "disable nested
pragmas" pass (an axis must not shard two conflicting dimensions).
"""

from __future__ import annotations

from typing import Any, Mapping

from repro.core.knob import Knob
from repro.core.weaver import Aspect, Weaver


class ShardingAspect(Aspect):
    name = "Sharding"

    def __init__(self, rules: Mapping[str, Any]):
        self.rules = dict(rules)

    def apply(self, weaver: Weaver) -> None:
        for axis, mapping in self.rules.items():
            weaver.set_rule(axis, mapping)


class RematAspect(Aspect):
    name = "Remat"

    def __init__(self, policy: str = "full", *, expose_knob: bool = False):
        self.policy = policy
        self.expose_knob = expose_knob

    def apply(self, weaver: Weaver) -> None:
        weaver.set_extra("remat", self.policy)
        if self.expose_knob:
            weaver.add_knob(Knob("remat", ("full", "dots", "none"), self.policy))


class AccumAspect(Aspect):
    name = "GradAccumulation"

    def __init__(self, steps: int = 1, *, expose_knob: bool = False,
                 choices: tuple[int, ...] = (1, 2, 4, 8)):
        self.steps = steps
        self.expose_knob = expose_knob
        self.choices = choices

    def apply(self, weaver: Weaver) -> None:
        weaver.set_extra("accum_steps", self.steps)
        if self.expose_knob:
            vals = self.choices if self.steps in self.choices else (self.steps, *self.choices)
            weaver.add_knob(Knob("accum_steps", vals, self.steps))


class CompressionAspect(Aspect):
    """int8 error-feedback compression on the DCN-crossing gradient psum."""

    name = "GradCompression"

    def __init__(self, enabled: bool = True, axes: tuple[str, ...] = ("pod",)):
        self.enabled = enabled
        self.axes = axes

    def apply(self, weaver: Weaver) -> None:
        weaver.set_extra("grad_compression", self.enabled)
        weaver.set_extra("grad_compression_axes", self.axes)


class AutoShard(Aspect):
    """Static analysis -> layout (the auto-parallelization library).

    Chooses one of three production layouts from the model's structure:

      megatron_tp : heads % tp == 0 — TP on vocab/heads/mlp (KV heads are
                    expanded to q-heads inside attention so scores shard),
                    DP batch on (pod, data), FSDP on embed when params+opt
                    exceed HBM.                      [yi, qwen2, nemotron,
                    mixtral, grok — experts replicated, TP inside experts]
      fsdp_sp     : dense but heads do not divide tp — activations are
                    sequence-sharded over model (DP x SP), vocab TP for the
                    embedding/logits, params FSDP over data.
                    [gemma, whisper, internvl]
      dp_fsdp     : recurrent families (ssm/hybrid) — batch over every mesh
                    axis (pure DP; recurrences have no token parallelism to
                    exploit), params FSDP over (data, model).
                    [rwkv6, recurrentgemma]
    """

    name = "AutoShard"

    def __init__(self, mesh_axes: Mapping[str, int], *, hbm_bytes: int = 16 << 30,
                 train: bool = True, layout: str | None = None):
        self.mesh_axes = dict(mesh_axes)  # e.g. {"pod": 2, "data": 16, "model": 16}
        self.hbm_bytes = hbm_bytes
        self.train = train
        self.layout = layout  # force a layout (hillclimb override)

    def apply(self, weaver: Weaver) -> None:
        tp = self.mesh_axes.get("model", 1)
        data_axes = tuple(a for a in ("pod", "data") if a in self.mesh_axes)
        cfg = weaver.program.cfg

        attn_jps = weaver.select(kind="attention").all()
        heads = min((jp.attr("n_heads", 10**9) for jp in attn_jps), default=0)
        kv_heads = min((jp.attr("kv_heads", 10**9) for jp in attn_jps), default=0)

        layout = self.layout
        if layout is None:
            if cfg.family in ("ssm", "hybrid"):
                layout = "dp_fsdp"
            elif heads and heads % tp == 0:
                layout = "megatron_tp"
            else:
                layout = "fsdp_sp"

        n_params = _estimate_params(weaver)
        bytes_per_param = 14 if self.train else 2  # bf16 + adamw fp32 states

        rules: dict[str, Any] = {"layers": None, "experts": None}
        if layout == "megatron_tp":
            rules.update(
                batch=data_axes,
                vocab="model", mlp="model",
                heads="model",
                # params' fused K*head_dim dim shards even when the head
                # count does not divide tp (activation constraints are
                # shape-guarded, so this only affects storage layout)
                kv_heads="model",
                kv_seq=None,
                seq_act=None,
                # res_seq="model" enables Korthikanti sequence-parallel
                # residuals (a §Perf hillclimb variant via rules override);
                # the baseline keeps the textbook replicated-residual
                # megatron schedule (2 fwd + 3 bwd all-reduces per layer).
                res_seq=None,
                expand_kv=kv_heads and kv_heads % tp != 0,
            )
            replicated = n_params * bytes_per_param / max(tp, 1)
            # FSDP spans every data-parallel axis (pod included): a 340B
            # train only fits 16 GB HBM when state shards 512-way
            rules["embed"] = data_axes if replicated > 0.5 * self.hbm_bytes else None
        elif layout == "fsdp_sp":
            rules.update(
                batch=data_axes,
                vocab="model", mlp=None, heads=None, kv_heads=None,
                kv_seq="model", seq_act="model", res_seq="model",
                # block params are NOT tensor-parallel in this layout: FSDP
                # over (data, model) when the replicated footprint is large
                embed=("data", "model") if n_params * bytes_per_param
                > 0.3 * self.hbm_bytes else None,
                expand_kv=False,
            )
        else:  # dp_fsdp
            # axis order matters: shape-guarded fallback drops TRAILING axes,
            # so put "pod" last — a 256-batch on the 2x16x16 mesh then lands
            # on (data, model) = 256-way DP with pod-replicated grads.
            dp_batch = tuple(a for a in ("data", "model", "pod")
                             if a in self.mesh_axes)
            rules.update(
                batch=dp_batch,
                vocab=None, mlp=None, heads=None, kv_heads=None,
                kv_seq=None, seq_act=None, res_seq=None,
                embed=("data", "model") if n_params * bytes_per_param
                > 0.5 * self.hbm_bytes else None,
                expand_kv=False,
            )
        weaver.set_extra("layout", layout)
        for axis, mapping in rules.items():
            if axis == "expand_kv":
                weaver.set_extra("expand_kv", bool(mapping))
                continue
            weaver.set_rule(axis, mapping)
        validate_rules(rules)


def _estimate_params(weaver: Weaver) -> int:
    from repro.nn.module import param_count

    return param_count(weaver.program.model)


def validate_rules(rules: Mapping[str, Any]) -> None:
    """The 'no nested pragmas' check: within one tensor the same mesh axis
    must not appear on two logical axes that co-occur.  Conservative check:
    embed/mlp/heads must not collide with batch axes."""
    batch_axes = set()
    v = rules.get("batch")
    for a in (v if isinstance(v, (tuple, list)) else [v]):
        if a:
            batch_axes.add(a)
    for key in ("vocab", "mlp", "heads", "kv_heads"):
        axis = rules.get(key)
        axes = axis if isinstance(axis, (tuple, list)) else [axis]
        for a in axes:
            if a in batch_axes:
                raise ValueError(
                    f"nested parallelism: mesh axis {a!r} used for both batch "
                    f"and {key} (the paper's nested-pragma hazard)"
                )
