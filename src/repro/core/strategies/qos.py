"""QoS aspect: weave the serving operating-point control plane.

The same AOP argument `ResilienceAspect` makes for fault tolerance applies
to QoS (the ANTAREX position — PAPER.md §3–4): which batch size, prefill
chunk, draft length and DVFS point a serve runs at is an *extra-functional*
property, woven as weave-state extras rather than hard-coded into the
event loop:

  * `serve_qos`      the policy dict `runtime/qos.QoSGovernor` is built
                     from (knob grids, SLOs, objective, power cap) — a
                     fresh governor per serve, the common case;
  * `qos_governor`   a pre-built QoSGovernor instance, when state (the
                     energy ledger, the capper's task table, Margot's
                     error coefficients) must persist across serves —
                     e.g. a fleet replica serving a request stream.

Explicit `serve_stream(qos=...)` / SLO arguments still win, and `qos=False`
forces the plane off regardless of what was woven.  Composes with
`ResilienceAspect` (fault isolation wraps every wave the governor paces)
and with the fleet aspects.
"""

from __future__ import annotations

from typing import Any

from repro.core.weaver import Aspect, Weaver


class QoSAspect(Aspect):
    name = "QoS"

    def __init__(self, policy: dict[str, Any] | None = None, *,
                 governor=None, **knobs: Any):
        self.policy = {**(policy or {}), **knobs}
        self.governor = governor

    def apply(self, weaver: Weaver) -> None:
        # the analysis pass selects the attention join points, like the
        # resilience/cache-dtype aspects: the operating point paces the
        # waves that read/write exactly the state those blocks own
        for jp in weaver.select("*", kind="attention"):
            jp.attr("kind")
        if self.governor is not None:
            weaver.set_extra("qos_governor", self.governor)
        weaver.set_extra("serve_qos", dict(self.policy))
