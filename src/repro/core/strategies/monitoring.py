"""Monitoring aspects (paper §2.6, Fig. 11 — SimpleExamon).

Weaves ExaMon collectors into the program: in-graph taps (activation
statistics on selected joinpoints) and host-level step sensors (time,
throughput, modeled power) published to the ExaMon broker under the given
topic.  The Collector API can then be queried asynchronously — e.g. by
mARGOt or the PowerCapper.
"""

from __future__ import annotations

from repro.core.weaver import Aspect, Weaver


class ExamonMonitor(Aspect):
    name = "SimpleExamon"

    def __init__(self, topic: str, *, tap_patterns: tuple[str, ...] = (),
                 broker=None, sensors: tuple[str, ...] = ("time", "throughput", "power")):
        self.topic = topic
        self.tap_patterns = tap_patterns
        self.broker = broker
        self.sensors = sensors

    def apply(self, weaver: Weaver) -> None:
        from repro.monitor.examon import ExamonBroker, get_default_broker
        from repro.monitor.sensors import sensor_wrapper

        broker = self.broker or get_default_broker()
        for pattern in self.tap_patterns:
            for jp in weaver.select(pattern):
                jp.attr("kind")
                weaver.add_tap(f"{jp.path}/*")
        weaver.set_extra("examon_topic", self.topic)
        weaver.wrap_step(sensor_wrapper(broker, self.topic, self.sensors))
