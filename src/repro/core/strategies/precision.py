"""Precision-tuning aspects (paper §2.2).

`ChangePrecision` is Fig. 2: change the numeric type of everything inside a
selected region.  `CreateLowPrecVersion` is Fig. 4 (clone + change types of
the clone — here: a named weave-state variant).  `MixedPrecisionVersions`
is Fig. 3 (HalfPrecisionOpenCL): enumerate per-region precision-mix
combinations, filtered, capped at max_versions, each becoming a selectable
variant for runtime evaluation.
"""

from __future__ import annotations

import itertools
from typing import Callable, Sequence

from repro.core.knob import Knob
from repro.core.weaver import Aspect, Weaver
from repro.nn.dtypes import DTypePolicy


class ChangePrecision(Aspect):
    name = "ChangePrecision"

    def __init__(self, pattern: str, policy: str | DTypePolicy, *, kind: str | None = None):
        self.pattern = pattern
        self.policy = policy
        self.jp_kind = kind

    def apply(self, weaver: Weaver) -> None:
        policy = (DTypePolicy.make(self.policy)
                  if isinstance(self.policy, str) else self.policy)
        if policy.cache_dtype is not None or self.jp_kind == "cache":
            # the "cache" kind retypes KV-cache *storage*, not compute:
            # the attention joinpoints are selected for analysis (the pool
            # hosts their K/V; their compute policy stays untouched) and
            # the dtype is woven as the "flash_cache_dtype" extra the
            # serving runtime and the tuned kernels resolve
            for jp in weaver.select(self.pattern, kind="attention"):
                jp.attr("kind")
            weaver.set_extra("flash_cache_dtype", policy.cache_dtype)
            return
        sel = weaver.select(self.pattern, kind=self.jp_kind)
        for jp in sel:
            # analysis: skip norm joinpoints — they pin fp32 params (the
            # paper's "library functions related to the type" caveat).
            if jp.attr("kind", jp.kind) == "norm":
                continue
            weaver.def_policy(jp, self.policy)


class CreateLowPrecVersion(Aspect):
    """Clone the program's weave under `suffix` with a lower-precision policy."""

    name = "CreateFloatVersion"

    def __init__(self, pattern: str = "*", policy: str = "half", suffix: str = "_f"):
        self.pattern, self.policy, self.suffix = pattern, policy, suffix

    def apply(self, weaver: Weaver) -> None:
        n = len(weaver.select(self.pattern).all())
        if n == 0:
            raise ValueError(f"no joinpoints match {self.pattern!r}")
        pattern, policy = self.pattern, self.policy

        def mutate(state):
            state.policies.override(pattern, policy)

        weaver.add_variant(self.suffix.strip("_") or "lowprec", mutate)


class MixedPrecisionVersions(Aspect):
    """Generate up to max_versions precision-mix variants over N regions."""

    name = "HalfPrecisionVersions"

    def __init__(
        self,
        patterns: Sequence[str],
        policies: Sequence[str] = ("float", "half"),
        *,
        max_versions: int | None = None,
        combination_filter: Callable[[tuple[str, ...]], bool] | None = None,
        knob_name: str = "precision_mix",
    ):
        self.patterns = list(patterns)
        self.policies = list(policies)
        self.max_versions = max_versions
        self.combination_filter = combination_filter
        self.knob_name = knob_name

    def apply(self, weaver: Weaver) -> None:
        for p in self.patterns:  # analysis pass (counted as selects/attrs)
            for jp in weaver.select(p):
                jp.attr("kind")
        names = []
        count = 0
        for combo in itertools.product(self.policies, repeat=len(self.patterns)):
            if self.combination_filter and not self.combination_filter(combo):
                continue
            if self.max_versions is not None and count >= self.max_versions:
                break
            vname = "mix_" + "_".join(c[0] for c in combo)  # e.g. mix_f_h_h

            def mutate(state, combo=combo):
                for pattern, policy in zip(self.patterns, combo):
                    pol = (DTypePolicy.make(policy)
                           if isinstance(policy, str) else policy)
                    if pol.cache_dtype is not None:
                        # cache policies retype pool storage, not compute
                        state.extra["flash_cache_dtype"] = pol.cache_dtype
                    else:
                        state.policies.override(pattern, policy)

            weaver.add_variant(vname, mutate)
            names.append(vname)
            count += 1
        weaver.add_knob(
            Knob(self.knob_name, tuple(["__default__"] + names), "__default__")
        )
        self.generated = names
