"""Software knobs — the k_1..k_n of the paper's parametric-function view
(o = f(i, k_1, ..., k_n)), exposed by aspects and tuned by mARGOt.

Knobs are either *static* (change the compiled program: precision policy,
kernel impl, remat, sharding layout — dispatched through libVC variants) or
*dynamic* (plain runtime values: capacity factor used at trace time still
counts as static; request batch size etc. are dynamic).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterable, Sequence


@dataclasses.dataclass(frozen=True)
class Knob:
    name: str
    values: tuple[Any, ...]
    default: Any = None
    static: bool = True  # requires recompilation (libVC variant switch)

    def __post_init__(self):
        if self.default is None:
            object.__setattr__(self, "default", self.values[0])
        if self.default not in self.values:
            raise ValueError(f"default {self.default!r} not in values for {self.name}")


class KnobSpace:
    def __init__(self, knobs: Iterable[Knob] = ()):
        self._knobs: dict[str, Knob] = {}
        for k in knobs:
            self.add(k)

    def add(self, knob: Knob) -> None:
        self._knobs[knob.name] = knob

    def __contains__(self, name: str) -> bool:
        return name in self._knobs

    def __getitem__(self, name: str) -> Knob:
        return self._knobs[name]

    def __iter__(self):
        return iter(self._knobs.values())

    def __len__(self):
        return len(self._knobs)

    def names(self) -> list[str]:
        return list(self._knobs)

    def defaults(self) -> dict[str, Any]:
        return {k.name: k.default for k in self}

    def grid(self, subset: Sequence[str] | None = None) -> list[dict[str, Any]]:
        """Full factorial over (a subset of) knobs; other knobs at default."""
        names = list(subset) if subset is not None else self.names()
        axes = [self._knobs[n].values for n in names]
        out = []
        for combo in itertools.product(*axes):
            point = self.defaults()
            point.update(dict(zip(names, combo)))
            out.append(point)
        return out

    def neighbors(self, point: dict[str, Any]) -> list[dict[str, Any]]:
        """One-knob-changed neighbourhood (hill-climbing moves)."""
        out = []
        for k in self:
            for v in k.values:
                if v != point.get(k.name, k.default):
                    p = dict(point)
                    p[k.name] = v
                    out.append(p)
        return out

    def validate(self, point: dict[str, Any]) -> None:
        for name, value in point.items():
            if name not in self._knobs:
                raise KeyError(f"unknown knob {name!r}")
            if value not in self._knobs[name].values:
                raise ValueError(f"value {value!r} invalid for knob {name!r}")
