"""Program: the functional application the ANTAREX aspects are woven onto.

The *domain expert* writes/choses the model (configs + models packages) and
is done.  Extra-functional concerns — precision, sharding, remat, kernels,
monitoring, autotuning, power — arrive exclusively through aspects, which
never touch the model code (DESIGN.md §2: separation of concerns).
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.configs.base import ModelConfig
from repro.nn.dtypes import PolicyResolver
from repro.nn.module import Ctx, Module


@dataclasses.dataclass
class WeaveState:
    """Everything a weave decides; consumed by runtime/steps.py via Ctx."""

    # bf16 storage + bf16 MXU compute + fp32 accumulation; the fp32 master
    # copy lives in the optimizer state (standard TPU LLM training posture).
    policies: PolicyResolver = dataclasses.field(
        default_factory=lambda: PolicyResolver.default("half")
    )
    impls: list[tuple[str, str, str]] = dataclasses.field(default_factory=list)
    rules: dict[str, Any] = dataclasses.field(default_factory=dict)
    extra: dict[str, Any] = dataclasses.field(default_factory=dict)
    taps: list[str] = dataclasses.field(default_factory=list)
    step_wrappers: list[Any] = dataclasses.field(default_factory=list)
    priority: int = 0  # PowerCapper task priority

    def copy(self) -> "WeaveState":
        return WeaveState(
            policies=self.policies.copy(),
            impls=list(self.impls),
            rules=dict(self.rules),
            extra=dict(self.extra),
            taps=list(self.taps),
            step_wrappers=list(self.step_wrappers),
            priority=self.priority,
        )

    def make_ctx(self, mesh=None, **kw) -> Ctx:
        return Ctx(
            policies=self.policies,
            impls=self.impls,
            mesh=mesh,
            rules=self.rules,
            taps_enabled=self.taps,
            extra=self.extra,
            **kw,
        )


@dataclasses.dataclass
class Program:
    model: Module
    cfg: ModelConfig
    kind: str = "train"  # train | serve

    @staticmethod
    def from_arch(arch: str, *, kind: str = "train", reduced: bool = False) -> "Program":
        from repro.models.registry import build_model, get_config, reduced_config

        cfg = reduced_config(arch) if reduced else get_config(arch)
        return Program(model=build_model(cfg), cfg=cfg, kind=kind)
