"""Architecture registry: configs, reduced smoke configs, model builders and
per-(arch x shape) input specs for the dry-run."""

from __future__ import annotations

import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

ARCHS = {
    "yi-6b": "repro.configs.yi_6b",
    "gemma-2b": "repro.configs.gemma_2b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "whisper-small": "repro.configs.whisper_small",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
}

# Speculative-decoding pairings: target arch -> the small arch that drafts
# for it.  A pairing is only meaningful when the two models share a token
# space (same tokenizer/vocab — true for the reduced smoke configs, which
# all use vocab=512); the draft proposes ids the target verifies in one
# widened-q decode step, so a vocab mismatch would feed the target
# out-of-range ids.  Targets absent from this table self-draft (the server
# uses its own weights — the degenerate pairing with 100% acceptance).
# Only attention-cache (paged-compatible) archs can draft: the draft runs
# its own page pool inside serve_continuous.
DRAFTS = {
    "qwen2-72b": "gemma-2b",
    "yi-6b": "gemma-2b",
    "nemotron-4-340b": "gemma-2b",
    "grok-1-314b": "yi-6b",
    "mixtral-8x22b": "yi-6b",
}


def draft_for(name: str) -> str | None:
    """The registry's draft pairing for `name` (None: self-draft)."""
    return DRAFTS.get(name)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[name]).CONFIG


def reduced_config(name: str) -> ModelConfig:
    """Family-preserving reduced config for CPU smoke tests."""
    cfg = get_config(name)
    kw: dict[str, Any] = dict(
        num_layers=2, d_model=64, n_heads=4, kv_heads=max(1, min(cfg.kv_heads, 2)),
        head_dim=16, d_ff=128, vocab=512, layer_groups=(),
    )
    if cfg.family == "moe":
        kw.update(num_experts=4, top_k=2)
    if cfg.family == "hybrid":
        kw.update(num_layers=3, lru_width=64, local_window=16, n_heads=4,
                  head_dim=16, kv_heads=1)
    if cfg.family == "ssm":
        kw.update(rwkv_head_dim=16, n_heads=4, kv_heads=4)
    if cfg.family == "encdec":
        kw.update(enc_layers=2)
    if cfg.family == "vlm":
        kw.update(num_image_tokens=8)
    if cfg.attn_window:
        kw.update(attn_window=16)
    return cfg.replace(name=cfg.name + "-reduced", **kw)


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM

        return EncDecLM(cfg)
    from repro.models.lm import TransformerLM

    return TransformerLM(cfg)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; never allocate)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str) -> dict[str, Any]:
    """Returns {"inputs": ..., "cache": ... (decode only)} SDS pytrees.

    train : tokens/labels (B, S)  [+frames/embeds for stub frontends]
    prefill: tokens (B, S)        [+frames/embeds]
    decode : tokens (B, 1), positions (B, 1), cache with seq_len entries
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    sds = jax.ShapeDtypeStruct
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)

    def text_inputs(seq, with_labels):
        d: dict[str, Any] = {"tokens": sds((B, seq), jnp.int32)}
        if with_labels:
            d["labels"] = sds((B, seq), jnp.int32)
        return d

    if cfg.family == "encdec":
        if shape.kind == "train":
            inp = text_inputs(S, True)
            inp["frames"] = sds((B, S, cfg.d_model), jnp.bfloat16)
            return {"inputs": inp, "cache": None}
        if shape.kind == "prefill":
            inp = text_inputs(S, False)
            inp["frames"] = sds((B, S, cfg.d_model), jnp.bfloat16)
            return {"inputs": inp, "cache": None}
        inp = {"tokens": sds((B, 1), jnp.int32), "positions": sds((B, 1), jnp.int32)}
        return {"inputs": inp, "cache": model.cache_specs(B, S, enc_len=S)}

    if cfg.family == "vlm":
        P = cfg.num_image_tokens
        if shape.kind == "train":
            inp = text_inputs(S - P, True)
            inp["embeds"] = sds((B, P, cfg.d_model), jnp.bfloat16)
            return {"inputs": inp, "cache": None}
        if shape.kind == "prefill":
            inp = text_inputs(S - P, False)
            inp["embeds"] = sds((B, P, cfg.d_model), jnp.bfloat16)
            return {"inputs": inp, "cache": None}
        inp = {"tokens": sds((B, 1), jnp.int32), "positions": sds((B, 1), jnp.int32)}
        return {"inputs": inp, "cache": model.cache_specs(B, S)}

    if shape.kind == "train":
        return {"inputs": text_inputs(S, True), "cache": None}
    if shape.kind == "prefill":
        return {"inputs": text_inputs(S, False), "cache": None}
    inp = {"tokens": sds((B, 1), jnp.int32), "positions": sds((B, 1), jnp.int32)}
    return {"inputs": inp, "cache": model.cache_specs(B, S)}


def cells(arch: str) -> list[str]:
    """Supported (arch x shape) cells; long_500k only for sub-quadratic."""
    return get_config(arch).supported_shapes()


def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch in ARCHS:
        for shape in cells(arch):
            out.append((arch, shape))
    return out


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            if shape not in cfg.supported_shapes():
                out.append((arch, shape, "full-attention arch: long_500k needs "
                            "sub-quadratic attention (DESIGN.md §5)"))
    return out
