"""Decoder LM family: dense / MoE / VLM / hybrid (Griffin) / SSM (RWKV6).

One composable model class (`TransformerLM`) assembles per-family blocks:

  dense / moe / vlm : [norm -> attention -> +res ; norm -> MLP|MoE -> +res] xL
                      (scan-over-layers in weavable groups)
  hybrid            : recurrentgemma 1:2 pattern (rec, rec, local-attn),
                      unrolled (heterogeneous blocks)
  ssm               : RWKV6 time-mix + channel-mix blocks (scan)

Modes: "dense" (train), "prefill" (returns last-token logits + KV cache),
"decode" (one token against the cache).  Caches are plain pytrees with a
leading per-layer dim produced/consumed by lax.scan.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.attention import Attention, cache_spec
from repro.nn.blocks import MLP, Embedding, LayerNorm, Linear, RMSNorm
from repro.nn.moe import MoEMLP
from repro.nn.module import Ctx, Module, cast
from repro.nn.rglru import RecurrentBlock
from repro.nn.rwkv import ChannelMix, TimeMix, rwkv_state_spec
from repro.nn.stack import ScannedStack


def _make_norm(name: str, cfg: ModelConfig):
    if cfg.norm_type == "layernorm":
        return LayerNorm(name, cfg.d_model)
    return RMSNorm(name, cfg.d_model, plus_one=cfg.norm_plus_one)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


class DecoderBlock(Module):
    kind = "block"

    def __init__(self, name: str, cfg: ModelConfig, *, mask: str = "causal",
                 window: int | None = None):
        self.name = name
        self.cfg = cfg
        self.norm1 = _make_norm("norm1", cfg)
        self.attn = Attention(
            "attn", cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.resolved_head_dim,
            bias=cfg.qkv_bias, use_rope=cfg.use_rope, rope_theta=cfg.rope_theta,
            mask=mask, window=window, softcap=cfg.attn_softcap,
        )
        self.norm2 = _make_norm("norm2", cfg)
        if cfg.family == "moe":
            self.ffn: Module = MoEMLP(
                "ffn", cfg.d_model, cfg.d_ff, num_experts=cfg.num_experts,
                top_k=cfg.top_k, activation=cfg.activation,
            )
        else:
            self.ffn = MLP(
                "ffn", cfg.d_model, cfg.d_ff, activation=cfg.activation,
                gated=cfg.gated_mlp,
            )

    def spec(self):
        return {"norm1": self.norm1, "attn": self.attn, "norm2": self.norm2,
                "ffn": self.ffn}

    def __call__(self, params, x, *, ctx: Ctx, mode="dense", cache=None,
                 positions=None, kv_pos=None, block_tables=None,
                 prefix_len=0, skip_cache_write=False):
        with ctx.scope(self.name):
            h = self.norm1(params["norm1"], x, ctx=ctx)
            # single gather point for the sequence-parallel residual (the
            # Megatron-SP "g" operator): one AG feeds qkv, not one each
            h = ctx.constrain(h, ("batch", "seq_act", "embed"))
            h, new_cache = self.attn(params["attn"], h, ctx=ctx, positions=positions,
                                     mode=mode, cache=cache, kv_pos=kv_pos,
                                     block_tables=block_tables,
                                     prefix_len=prefix_len,
                                     skip_cache_write=skip_cache_write)
            x = x + h
            h = self.norm2(params["norm2"], x, ctx=ctx)
            h = ctx.constrain(h, ("batch", "seq_act", "embed"))
            h = self.ffn(params["ffn"], h, ctx=ctx)
            x = x + h
            return x, new_cache


class RecBlock(Module):
    """Hybrid temporal-mixing block (RG-LRU) + MLP."""

    kind = "block"

    def __init__(self, name: str, cfg: ModelConfig):
        self.name = name
        self.cfg = cfg
        lru = cfg.lru_width or cfg.d_model
        self.norm1 = _make_norm("norm1", cfg)
        self.rec = RecurrentBlock("rec", cfg.d_model, lru, cfg.n_heads)
        self.norm2 = _make_norm("norm2", cfg)
        self.ffn = MLP("ffn", cfg.d_model, cfg.d_ff, activation=cfg.activation,
                       gated=cfg.gated_mlp)

    def spec(self):
        return {"norm1": self.norm1, "rec": self.rec, "norm2": self.norm2,
                "ffn": self.ffn}

    def __call__(self, params, x, *, ctx: Ctx, mode="dense", cache=None,
                 positions=None):
        with ctx.scope(self.name):
            h = self.norm1(params["norm1"], x, ctx=ctx)
            h, new_state = self.rec(params["rec"], h, ctx=ctx, state=cache, mode=mode)
            x = x + h
            h = self.norm2(params["norm2"], x, ctx=ctx)
            x = x + self.ffn(params["ffn"], h, ctx=ctx)
            if mode == "dense":
                new_state = None
            return x, new_state


class RWKVBlock(Module):
    kind = "block"

    def __init__(self, name: str, cfg: ModelConfig):
        self.name = name
        self.cfg = cfg
        self.ln1 = LayerNorm("ln1", cfg.d_model)
        self.time_mix = TimeMix("time_mix", cfg.d_model, cfg.rwkv_head_dim)
        self.ln2 = LayerNorm("ln2", cfg.d_model)
        self.channel_mix = ChannelMix("channel_mix", cfg.d_model, cfg.d_ff)

    def spec(self):
        return {"ln1": self.ln1, "time_mix": self.time_mix, "ln2": self.ln2,
                "channel_mix": self.channel_mix}

    def __call__(self, params, x, *, ctx: Ctx, mode="dense", cache=None,
                 positions=None):
        with ctx.scope(self.name):
            t_state = cache["time"] if cache is not None else None
            c_state = cache["channel"] if cache is not None else None
            h, t_new = self.time_mix(params["time_mix"],
                                     self.ln1(params["ln1"], x, ctx=ctx),
                                     ctx=ctx, state=t_state, mode=mode)
            x = x + h
            h, c_new = self.channel_mix(params["channel_mix"],
                                        self.ln2(params["ln2"], x, ctx=ctx),
                                        ctx=ctx, state=c_state, mode=mode)
            x = x + h
            new_cache = {"time": t_new, "channel": c_new}
            if mode == "dense":
                new_cache = None
            return x, new_cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class TransformerLM(Module):
    kind = "model"

    def __init__(self, cfg: ModelConfig):
        self.name = cfg.name.replace("-", "_")
        self.cfg = cfg
        self.embed = Embedding("embed", cfg.vocab, cfg.d_model,
                               scale_by_dim=cfg.embed_scale)
        self.final_norm = _make_norm("final_norm", cfg)
        self.head = (
            None
            if cfg.tie_embeddings
            else Linear("head", cfg.d_model, cfg.vocab, axes=("embed", "vocab"),
                        out_axes=("batch", "seq_act", "vocab"))
        )
        self.ln0 = LayerNorm("ln0", cfg.d_model) if cfg.family == "ssm" else None

        self.trunk: list[Module] = []
        if cfg.family == "hybrid":
            pat = cfg.block_pattern or ("rec", "rec", "attn")
            for i in range(cfg.num_layers):
                kind_i = pat[i % len(pat)]
                if kind_i == "attn":
                    self.trunk.append(
                        DecoderBlock(f"layer{i:02d}", cfg, mask="local",
                                     window=cfg.local_window)
                    )
                else:
                    self.trunk.append(RecBlock(f"layer{i:02d}", cfg))
        else:
            mask = "sliding" if cfg.attn_window else "causal"
            for gi, n in enumerate(cfg.groups()):
                if cfg.family == "ssm":
                    block: Module = RWKVBlock("block", cfg)
                else:
                    block = DecoderBlock("block", cfg, mask=mask,
                                         window=cfg.attn_window)
                self.trunk.append(ScannedStack(f"blocks{gi}", block, n))

    def spec(self):
        s: dict[str, Any] = {"embed": self.embed}
        if self.ln0 is not None:
            s["ln0"] = self.ln0
        for part in self.trunk:
            s[part.name] = part
        s["final_norm"] = self.final_norm
        if self.head is not None:
            s["head"] = self.head
        return s

    # -- forward -----------------------------------------------------------------

    def __call__(self, params, inputs: dict, *, ctx: Ctx, mode: str = "dense",
                 cache: dict | None = None, prefix_len: int = 0,
                 skip_cache_write: bool = False):
        cfg = self.cfg
        tokens = inputs["tokens"]
        B = tokens.shape[0]
        x = self.embed(params["embed"], tokens, ctx=ctx)
        if cfg.family == "vlm" and "embeds" in inputs:
            emb = cast(inputs["embeds"], x.dtype)
            x = jnp.concatenate([emb, x], axis=1)
        if self.ln0 is not None:
            x = self.ln0(params["ln0"], x, ctx=ctx)
        x = ctx.constrain(x, ("batch", "res_seq", "embed"))

        S = x.shape[1]
        positions = inputs.get("positions")
        if positions is None:
            if mode == "decode":
                raise ValueError("decode mode requires explicit positions")
            if prefix_len:
                raise ValueError("paged prefill with a shared prefix needs "
                                 "explicit (prefix-offset) positions")
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        new_caches: dict[str, Any] = {}
        remat_unrolled = (
            mode == "dense"
            and str(ctx.extra.get("remat", "full")) != "none"
            and cfg.family == "hybrid"
        )
        # Hoisted linear-cache decode positions: updated ONCE per step (an
        # O(B) scatter on the cached (B, T) kv_pos) and shared by every
        # attention layer — instead of each layer re-deriving an arange(T)
        # mask broadcast to (B, T).  Paged serving caches hoist their
        # block tables the same way: one (B, NB) page map shared by every
        # layer (the per-layer pools index the same physical page space) —
        # in decode mode AND in the paged-prefill mode, where each layer
        # scatters the prompt suffix K/V straight into its pool pages.
        kv_pos = None
        block_tables = None
        if mode == "decode" and cache is not None and "kv_pos" in cache:
            # S >= 1 new columns (S > 1: the speculative verify step writes
            # the whole draft block's positions in one O(B·S) scatter)
            kv_pos = cache["kv_pos"].at[
                jnp.arange(B)[:, None], positions].set(positions)
            new_caches["kv_pos"] = kv_pos
        if mode in ("decode", "prefill") and cache is not None \
                and "block_tables" in cache:
            block_tables = cache["block_tables"]
            new_caches["block_tables"] = block_tables
        if not ctx.extra.get("skip_trunk"):  # roofline outer-component mode
            for part in self.trunk:
                part_cache = None if cache is None else cache.get(part.name)
                attn_kw: dict[str, Any] = {}
                shared = {}
                if kv_pos is not None:
                    shared["kv_pos"] = kv_pos
                if block_tables is not None:
                    shared["block_tables"] = block_tables
                    if mode == "prefill":
                        shared["prefix_len"] = prefix_len
                if skip_cache_write:
                    # threaded unconditionally: a re-score step against a
                    # table-less (dense) cache must reach Attention's
                    # contract guard, not silently write the cache
                    shared["skip_cache_write"] = True
                if shared:
                    if isinstance(part, ScannedStack) and isinstance(
                            part.block, DecoderBlock):
                        attn_kw = {"block_kwargs": shared}
                    elif isinstance(part, DecoderBlock):
                        attn_kw = shared
                if remat_unrolled and not isinstance(part, ScannedStack):
                    # unrolled hybrid blocks need per-block remat too
                    def call(p, h, _part=part):
                        out, c = _part(p, h, ctx=ctx, mode=mode,
                                       cache=None, positions=positions)
                        return out
                    x = jax.checkpoint(
                        call, policy=jax.checkpoint_policies.nothing_saveable
                    )(params[part.name], x)
                    c = None
                else:
                    x, c = part(params[part.name], x, ctx=ctx, mode=mode,
                                cache=part_cache, positions=positions,
                                **attn_kw)
                new_caches[part.name] = c
        if mode == "prefill":
            kvp = self._prefill_kv_pos(new_caches, positions)
            if kvp is not None:
                new_caches["kv_pos"] = kvp

        if mode == "prefill":
            x = x[:, -1:]
        x = self.final_norm(params["final_norm"], x, ctx=ctx)
        if self.head is not None:
            logits = self.head(params["head"], x, ctx=ctx)
        else:
            logits = self.embed.attend(params["embed"], x, ctx=ctx)
        logits = ctx.constrain(logits, ("batch", "res_seq", "vocab"))
        if mode == "dense":
            return logits, None
        return logits, new_caches

    # -- roofline components ---------------------------------------------------

    def component_blocks(self, batch: int, cache_len: int):
        """Distinct trunk block types for compositional roofline costing:
        [(name, block_module, count, per_layer_cache_spec, kwargs)]."""
        cfg = self.cfg
        if cfg.family == "hybrid":
            rec = [p for p in self.trunk if isinstance(p, RecBlock)]
            att = [p for p in self.trunk if isinstance(p, DecoderBlock)]
            out = []
            if rec:
                out.append(("rec_block", rec[0], len(rec),
                            RecurrentBlock.state_spec(batch, cfg.lru_width or cfg.d_model),
                            {}))
            if att:
                W = min(cfg.local_window, cache_len)
                out.append(("attn_block", att[0], len(att),
                            cache_spec(batch, W, cfg.kv_heads, cfg.resolved_head_dim,
                                       ring=cfg.local_window < cache_len), {}))
            return out
        layer_spec = self._layer_cache_spec(batch, cache_len)
        return [
            (part.name, part.block, part.n_layers, layer_spec, {})
            for part in self.trunk
            if isinstance(part, ScannedStack)
        ]

    # -- caches -------------------------------------------------------------------

    @staticmethod
    def _prefill_kv_pos(new_caches, positions):
        """(B, T) slot->position map for the *linear* attention caches, built
        once at prefill and carried in the cache pytree (slot s holds
        position s for s < S, -1 beyond).  Ring caches carry their own `pos`
        and need no shared map; models without linear attention caches
        return None."""
        for c in new_caches.values():
            if isinstance(c, dict) and "k" in c and "pos" not in c \
                    and "ck" not in c:
                T = c["k"].shape[-3]  # (..., B, T, K, D)
                ar = jnp.arange(T, dtype=jnp.int32)[None]
                return jnp.where(ar <= positions[:, -1:], ar, -1)
        return None

    def _layer_cache_spec(self, batch: int, cache_len: int):
        cfg = self.cfg
        if cfg.family == "ssm":
            return rwkv_state_spec(batch, cfg.d_model, cfg.rwkv_head_dim)
        window = cfg.attn_window
        ring = window is not None and window < cache_len
        length = min(window, cache_len) if window else cache_len
        return cache_spec(batch, length, cfg.kv_heads, cfg.resolved_head_dim,
                          ring=ring)

    def cache_specs(self, batch: int, cache_len: int) -> dict:
        """ShapeDtypeStruct cache pytree (leading per-layer dim per group)."""
        cfg = self.cfg

        def stack(tree, n):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n, *s.shape), s.dtype), tree
            )

        out: dict[str, Any] = {}
        if cfg.family == "hybrid":
            for part in self.trunk:
                if isinstance(part, RecBlock):
                    out[part.name] = RecurrentBlock.state_spec(
                        batch, cfg.lru_width or cfg.d_model
                    )
                else:
                    W = min(cfg.local_window, cache_len)
                    ring = cfg.local_window < cache_len
                    out[part.name] = cache_spec(
                        batch, W, cfg.kv_heads, cfg.resolved_head_dim, ring=ring
                    )
                    if not ring:
                        out["kv_pos"] = jax.ShapeDtypeStruct(
                            (batch, W), jnp.int32)
            return out
        layer_spec = self._layer_cache_spec(batch, cache_len)
        for part, n in zip(self.trunk, cfg.groups()):
            out[part.name] = stack(layer_spec, n)
        if isinstance(layer_spec, dict) and "k" in layer_spec \
                and "pos" not in layer_spec:
            # linear attention caches share one hoisted (B, T) kv_pos
            out["kv_pos"] = jax.ShapeDtypeStruct(
                (batch, layer_spec["k"].shape[1]), jnp.int32)
        return out

    def stack_caches(self, caches: list[dict]) -> dict:
        """Stack per-request (batch=1) decode caches into one batched cache
        — the serving layout: array leaves concatenate on their batch axis
        (axis 1 under a scanned stack's layer dim, else 0), while the
        per-stream metadata gains a leading per-request dim: `index` becomes
        (..., B) and ring `pos` (..., B, W).  `Attention._decode` detects the
        per-request index and updates/prunes each request's slots
        independently (the flash_decode kernel reads the index vector as a
        scalar-prefetch operand)."""
        first = caches[0]

        def merge(vals, scanned: bool):
            out = {}
            for key in vals[0]:
                arrs = [v[key] for v in vals]
                if isinstance(arrs[0], dict):
                    out[key] = merge(arrs, scanned)
                elif key == "index":
                    out[key] = jnp.stack(arrs, axis=-1)
                elif key == "pos":
                    out[key] = jnp.stack(arrs, axis=1 if scanned else 0)
                else:
                    out[key] = jnp.concatenate(arrs, axis=1 if scanned else 0)
            return out

        stacked: dict[str, Any] = {}
        for part in self.trunk:
            vals = [c[part.name] for c in caches]
            if vals[0] is None:
                stacked[part.name] = None
                continue
            stacked[part.name] = merge(vals, isinstance(part, ScannedStack))
        if "kv_pos" in first:
            stacked["kv_pos"] = jnp.concatenate(
                [c["kv_pos"] for c in caches], axis=0)
        return stacked

    def init_cache(self, batch: int, cache_len: int, *, index: int = 0) -> dict:
        """Concrete zero cache (tests/examples); index = #valid tokens."""
        specs = self.cache_specs(batch, cache_len)

        def mk(s: jax.ShapeDtypeStruct):
            return jnp.zeros(s.shape, s.dtype)

        cache = jax.tree.map(mk, specs)

        def fix_meta(tree):
            if isinstance(tree, dict):
                if "index" in tree:
                    tree = dict(tree)
                    tree["index"] = jnp.full_like(tree["index"], index)
                    if "pos" in tree:
                        tree["pos"] = jnp.full_like(tree["pos"], -1)
                    return tree
                return {k: fix_meta(v) for k, v in tree.items()}
            return tree

        cache = fix_meta(cache)
        if "kv_pos" in cache:  # slot s -> position s for the filled prefix
            ar = jnp.arange(cache["kv_pos"].shape[1], dtype=jnp.int32)[None]
            cache["kv_pos"] = jnp.broadcast_to(
                jnp.where(ar < index, ar, -1), cache["kv_pos"].shape)
        return cache
