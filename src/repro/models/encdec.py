"""Whisper-style encoder-decoder backbone (audio frontend is a stub:
`input_specs` provides precomputed frame embeddings, per the assignment).

Encoder: bidirectional attention blocks over frame embeddings + sinusoidal
positions.  Decoder: causal self-attention + cross-attention to the encoder
states + MLP.  Both stacks are scanned.  Decode mode carries a per-layer
self cache and a per-layer cross K/V cache (computed once at prefill).

Deviation noted in DESIGN.md: positions are sinusoidal (not learned) so the
assigned 4k/32k sequence cells are well-defined beyond whisper's native
1500-frame / 448-token limits; the backbone dims are exact whisper-small.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn.attention import Attention, cache_spec
from repro.nn.blocks import MLP, Embedding, LayerNorm, sinusoidal_positions
from repro.nn.module import Ctx, Module, cast
from repro.nn.stack import ScannedStack


class EncoderBlock(Module):
    kind = "block"

    def __init__(self, name: str, cfg: ModelConfig):
        self.name = name
        self.norm1 = LayerNorm("norm1", cfg.d_model)
        self.attn = Attention("attn", cfg.d_model, cfg.n_heads, cfg.kv_heads,
                              cfg.resolved_head_dim, use_rope=False, mask="full")
        self.norm2 = LayerNorm("norm2", cfg.d_model)
        self.ffn = MLP("ffn", cfg.d_model, cfg.d_ff, activation="gelu", gated=False,
                       bias=True)

    def spec(self):
        return {"norm1": self.norm1, "attn": self.attn, "norm2": self.norm2,
                "ffn": self.ffn}

    def __call__(self, params, x, *, ctx: Ctx, mode="dense", cache=None,
                 positions=None):
        with ctx.scope(self.name):
            h = self.norm1(params["norm1"], x, ctx=ctx)
            h = ctx.constrain(h, ("batch", "seq_act", "embed"))
            h, _ = self.attn(params["attn"], h, ctx=ctx, positions=positions,
                             mode="dense")
            x = x + h
            h = self.norm2(params["norm2"], x, ctx=ctx)
            h = ctx.constrain(h, ("batch", "seq_act", "embed"))
            x = x + self.ffn(params["ffn"], h, ctx=ctx)
            return x, None


class DecoderXBlock(Module):
    kind = "block"

    def __init__(self, name: str, cfg: ModelConfig):
        self.name = name
        self.norm1 = LayerNorm("norm1", cfg.d_model)
        self.self_attn = Attention("self_attn", cfg.d_model, cfg.n_heads,
                                   cfg.kv_heads, cfg.resolved_head_dim,
                                   use_rope=False, mask="causal")
        self.norm_x = LayerNorm("norm_x", cfg.d_model)
        self.cross_attn = Attention("cross_attn", cfg.d_model, cfg.n_heads,
                                    cfg.kv_heads, cfg.resolved_head_dim,
                                    use_rope=False, mask="full", cross=True)
        self.norm2 = LayerNorm("norm2", cfg.d_model)
        self.ffn = MLP("ffn", cfg.d_model, cfg.d_ff, activation="gelu", gated=False,
                       bias=True)

    def spec(self):
        return {"norm1": self.norm1, "self_attn": self.self_attn,
                "norm_x": self.norm_x, "cross_attn": self.cross_attn,
                "norm2": self.norm2, "ffn": self.ffn}

    def __call__(self, params, x, *, ctx: Ctx, mode="dense", cache=None,
                 positions=None, kv_src=None):
        with ctx.scope(self.name):
            self_cache = cache.get("self") if cache is not None else None
            cross_cache = cache.get("cross") if cache is not None else None
            h = self.norm1(params["norm1"], x, ctx=ctx)
            h = ctx.constrain(h, ("batch", "seq_act", "embed"))
            h, self_new = self.self_attn(
                params["self_attn"], h,
                ctx=ctx, positions=positions, mode=mode, cache=self_cache,
            )
            x = x + h
            h = self.norm_x(params["norm_x"], x, ctx=ctx)
            h = ctx.constrain(h, ("batch", "seq_act", "embed"))
            h, cross_new = self.cross_attn(
                params["cross_attn"], h,
                ctx=ctx, cache=cross_cache, kv_src=kv_src, mode=mode,
            )
            x = x + h
            h = self.norm2(params["norm2"], x, ctx=ctx)
            h = ctx.constrain(h, ("batch", "seq_act", "embed"))
            x = x + self.ffn(params["ffn"], h, ctx=ctx)
            new_cache = None
            if mode != "dense":
                new_cache = {"self": self_new, "cross": cross_new}
            return x, new_cache


class EncDecLM(Module):
    kind = "model"

    def __init__(self, cfg: ModelConfig):
        self.name = cfg.name.replace("-", "_")
        self.cfg = cfg
        enc_layers = cfg.enc_layers or cfg.num_layers
        self.embed = Embedding("embed", cfg.vocab, cfg.d_model)
        self.encoder = ScannedStack("encoder", EncoderBlock("block", cfg), enc_layers)
        self.enc_norm = LayerNorm("enc_norm", cfg.d_model)
        self.decoder = ScannedStack("decoder", DecoderXBlock("block", cfg),
                                    cfg.num_layers)
        self.final_norm = LayerNorm("final_norm", cfg.d_model)

    def spec(self):
        return {
            "embed": self.embed,
            "encoder": self.encoder,
            "enc_norm": self.enc_norm,
            "decoder": self.decoder,
            "final_norm": self.final_norm,
        }

    def encode(self, params, frames, *, ctx: Ctx):
        """frames: (B, T, d_model) stub frame embeddings."""
        B, T, _ = frames.shape
        pos = sinusoidal_positions(jnp.arange(T), self.cfg.d_model)
        x = cast(frames, ctx.policy().compute_dtype) + cast(pos, ctx.policy().compute_dtype)
        x = ctx.constrain(x, ("batch", "res_seq", "embed"))
        x, _ = self.encoder(params["encoder"], x, ctx=ctx, mode="dense")
        return self.enc_norm(params["enc_norm"], x, ctx=ctx)

    def __call__(self, params, inputs: dict, *, ctx: Ctx, mode: str = "dense",
                 cache: dict | None = None):
        cfg = self.cfg
        tokens = inputs["tokens"]
        B, S = tokens.shape

        if ctx.extra.get("skip_trunk"):  # roofline outer-component mode
            enc = None
        elif cache is not None and "enc" in cache and mode == "decode":
            enc = cache["enc"]
        else:
            enc = self.encode(params, inputs["frames"], ctx=ctx)

        positions = inputs.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = self.embed(params["embed"], tokens, ctx=ctx)
        x = x + cast(sinusoidal_positions(positions, cfg.d_model), x.dtype)
        x = ctx.constrain(x, ("batch", "res_seq", "embed"))

        if ctx.extra.get("skip_trunk"):
            new_dec_cache = None
        else:
            dec_cache = cache.get("decoder") if cache is not None else None
            x, new_dec_cache = self.decoder(
                params["decoder"], x, ctx=ctx, mode=mode, cache=dec_cache,
                positions=positions, block_kwargs={"kv_src": enc},
            )
        if mode == "prefill":
            x = x[:, -1:]
        x = self.final_norm(params["final_norm"], x, ctx=ctx)
        logits = self.embed.attend(params["embed"], x, ctx=ctx)
        logits = ctx.constrain(logits, ("batch", "res_seq", "vocab"))
        if mode == "dense":
            return logits, None
        return logits, {"decoder": new_dec_cache, "enc": enc}

    def component_blocks(self, batch: int, cache_len: int):
        cfg = self.cfg
        K, hd = cfg.kv_heads, cfg.resolved_head_dim
        sds = jax.ShapeDtypeStruct
        dec_cache = {
            "self": cache_spec(batch, cache_len, K, hd),
            "cross": {
                "ck": sds((batch, cache_len, K, hd), jnp.bfloat16),
                "cv": sds((batch, cache_len, K, hd), jnp.bfloat16),
            },
        }
        kv_src = sds((batch, cache_len, cfg.d_model), jnp.bfloat16)
        return [
            ("enc_block", self.encoder.block, cfg.enc_layers or cfg.num_layers,
             None, {}),
            ("dec_block", self.decoder.block, cfg.num_layers, dec_cache,
             {"kv_src": kv_src}),
        ]

    def cache_specs(self, batch: int, cache_len: int, enc_len: int | None = None):
        cfg = self.cfg
        enc_len = enc_len or cache_len
        L = cfg.num_layers
        K, hd = cfg.kv_heads, cfg.resolved_head_dim

        def stk(tree):
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((L, *s.shape), s.dtype), tree
            )

        sds = jax.ShapeDtypeStruct
        per_layer = {
            "self": cache_spec(batch, cache_len, K, hd),
            "cross": {
                "ck": sds((batch, enc_len, K, hd), jnp.bfloat16),
                "cv": sds((batch, enc_len, K, hd), jnp.bfloat16),
            },
        }
        return {
            "decoder": stk(per_layer),
            "enc": sds((batch, enc_len, cfg.d_model), jnp.bfloat16),
        }

    def init_cache(self, batch: int, cache_len: int, *, index: int = 0,
                   enc_len: int | None = None):
        specs = self.cache_specs(batch, cache_len, enc_len)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)
        cache["decoder"]["self"]["index"] = jnp.full((self.cfg.num_layers,), index,
                                                     jnp.int32)
        return cache
