"""Jit'd wrapper for fused RMSNorm."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm_fwd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def _rmsnorm_jit(x, w, *, eps, block_rows, interpret):
    return rmsnorm_fwd(x, w, eps=eps, block_rows=block_rows, interpret=interpret)


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _rmsnorm_jit(x, w, eps=eps, block_rows=block_rows, interpret=interpret)
