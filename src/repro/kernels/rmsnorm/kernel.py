"""Pallas TPU fused RMSNorm: one HBM read, one write, fp32 math in VMEM.

Grid tiles rows (block_rows at a time); the full feature dimension stays in
VMEM (d_model <= 18432 -> 72 KB fp32 per row block row — fine).  Weight is
broadcast into VMEM once per block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def rmsnorm_fwd(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                block_rows: int = 256, interpret: bool = False) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((rows + pad) // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((rows + pad), d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
