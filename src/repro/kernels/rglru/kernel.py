"""Pallas TPU kernel for the RG-LRU linear recurrence h_t = a_t h_{t-1} + b_t.

Grid = (batch, d_blocks, time_chunks), time innermost; the carry h lives in
VMEM scratch.  Within a chunk the recurrence is solved in *parallel* with an
associative scan over affine maps (the VPU-friendly form), then stitched to
the carried state with one cumprod-weighted correction:

    h_t = bscan_t + acum_t * h0     where (acum, bscan) = assoc_scan(a, b)

The channel dimension is block-tiled (block_d lanes) so arbitrary widths
stream through VMEM; the time chunk keeps (3 x L x block_d) fp32 resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, h_out_ref, carry):
    t = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t == 0)
    def _init():
        carry[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # (L, Dblk)
    b = b_ref[0].astype(jnp.float32)

    def combine(prev, nxt):
        a_p, b_p = prev
        a_n, b_n = nxt
        return a_p * a_n, b_p * a_n + b_n

    a_cum, b_scan = jax.lax.associative_scan(combine, (a, b), axis=0)
    h0 = carry[...]  # (1, Dblk) -> broadcast over L
    h_seq = b_scan + a_cum * h0
    y_ref[0, :, :] = h_seq.astype(y_ref.dtype)
    carry[...] = h_seq[-1:, :]

    @pl.when(t == nt - 1)
    def _fin():
        h_out_ref[0, :] = carry[0]


def rglru_fwd(
    a: jax.Array,  # (B, S, D) fp32 decays in (0,1)
    b: jax.Array,  # (B, S, D) fp32 gated inputs
    h0: jax.Array,  # (B, D) fp32
    *,
    block_d: int = 512,
    chunk: int = 256,
    interpret: bool = False,
):
    B, S, D = a.shape
    block_d = min(block_d, D)
    chunk = min(chunk, S)
    assert D % block_d == 0 and S % chunk == 0, (D, block_d, S, chunk)
    grid = (B, D // block_d, S // chunk)
    y, h_last = pl.pallas_call(
        _rglru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_d), lambda bi, di, ti: (bi, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_d), lambda bi, di, ti: (bi, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return y, h_last
