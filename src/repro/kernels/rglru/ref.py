"""Pure-jnp oracles for the RG-LRU linear recurrence  h_t = a_t h_{t-1} + b_t.

`rglru_scan` is the step-by-step oracle; `rglru_assoc` is the log-depth
associative-scan form XLA compiles well (the roofline path).  Both take
fp32 (a, b) of shape (B, S, D) and initial state (B, D), and return
(h_seq (B,S,D), h_last (B,D)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array):
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    h_last, h_seq = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)),
    )
    return jnp.moveaxis(h_seq, 0, 1), h_last


def rglru_assoc(a: jax.Array, b: jax.Array, h0: jax.Array):
    """Associative scan over composed affine maps (a, b)∘(a', b')=(aa', a'b+b')."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    # Fold h0 into the first step: b_0' = a_0 h0 + b_0
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(prev, nxt):
        a_p, b_p = prev
        a_n, b_n = nxt
        return a_p * a_n, b_p * a_n + b_n

    a_cum, h_seq = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h_seq, h_seq[:, -1]
