"""Jit'd wrapper for the RG-LRU Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru.kernel import rglru_fwd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def _rglru_jit(a, b, h0, *, block_d, chunk, interpret):
    return rglru_fwd(
        a.astype(jnp.float32), b.astype(jnp.float32), h0.astype(jnp.float32),
        block_d=block_d, chunk=chunk, interpret=interpret,
    )


def rglru_pallas(a, b, h0, *, block_d: int = 512, chunk: int = 256,
                 interpret: bool | None = None):
    """a,b: (B,S,D); h0: (B,D). Returns (h_seq (B,S,D) fp32, h_last (B,D))."""
    if interpret is None:
        interpret = _interpret_default()
    B, S, D = a.shape
    block_d = min(block_d, D)
    while D % block_d:
        block_d //= 2
    chunk = min(chunk, S)
    if S % chunk:  # pad time with identity steps (a=1 keeps state, b=0)
        pad = chunk - S % chunk
        a2 = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b2 = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        y, h_last = _rglru_jit(a2, b2, h0, block_d=block_d, chunk=chunk,
                               interpret=interpret)
        return y[:, :S], h_last
    return _rglru_jit(a, b, h0, block_d=block_d, chunk=chunk, interpret=interpret)
