"""Pure-jnp oracles for the RWKV6 WKV recurrence.

Per head (k-dim i, v-dim j), fp32 state S in R^{C x C}:

    y_t[j] = sum_i r_t[i] * S_{t-1}[i,j]  +  (sum_i r_t[i] u[i] k_t[i]) * v_t[j]
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j]

`wkv_scan` is the sequential oracle.  `wkv_chunked` is the parallel chunked
form (the XLA roofline path): within a chunk all pairwise decay factors are
exponentials of *non-positive* log-decay differences, so the math is stable
for any decay magnitude (no 1/cumprod blow-ups).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Arrays = jax.Array


def wkv_scan(r, k, v, w, u, s0):
    """r,k,v,w: (B,S,H,C); u: (H,C); s0: (B,H,C,C). Returns y (B,S,H,C), sT."""
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw  # each (B,H,C)
        y = jnp.einsum("bhi,bhij->bhj", rt, s)
        coef = jnp.einsum("bhi,hi,bhi->bh", rt, uf, kt)
        y = y + coef[..., None] * vt
        s = wt[..., None] * s + kt[..., None] * vt[..., None, :]
        return s, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    s_last, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), s_last


def wkv_chunked(r, k, v, w, u, s0, *, chunk: int = 32):
    """Chunked parallel form; identical semantics to `wkv_scan`."""
    B, S, H, C = r.shape
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        zeros = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zeros(r), zeros(k), zeros(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    N = (S + pad) // L

    def to_chunks(x):  # (B, N*L, H, C) -> (N, B, H, L, C)
        return jnp.moveaxis(
            x.astype(jnp.float32).reshape(B, N, L, H, C), (1, 3), (0, 2)
        )

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    uf = u.astype(jnp.float32)
    lw = jnp.log(jnp.maximum(wc, 1e-30))  # (N,B,H,L,C), <= 0
    li = jnp.cumsum(lw, axis=3)
    li_prev = jnp.pad(li, ((0, 0),) * 3 + ((1, 0), (0, 0)))[..., :-1, :]

    causal = jnp.tril(jnp.ones((L, L), bool), k=-1)  # strictly lower: j < i

    def one_chunk(s, xs):
        rn, kn, vn, li_n, lip_n = xs  # (B,H,L,C) each
        q_dec = rn * jnp.exp(lip_n)  # decay-weighted receptance (exp <= 1)
        y_state = jnp.einsum("bhic,bhcj->bhij", q_dec, s)
        # pairwise intra-chunk decays: exp(li_{i-1} - li_j) for j < i (<= 1)
        diff = lip_n[:, :, :, None, :] - li_n[:, :, None, :, :]  # (B,H,L,L,C)
        dmat = jnp.exp(jnp.minimum(diff, 0.0))
        a = jnp.einsum("bhic,bhjc,bhijc->bhij", rn, kn, dmat)
        a = jnp.where(causal, a, 0.0)
        a_diag = jnp.einsum("bhic,hc,bhic->bhi", rn, uf, kn)
        a = a + jnp.eye(L)[None, None] * a_diag[..., None]
        y = y_state + jnp.einsum("bhij,bhjc->bhic", a, vn)
        # state to next chunk: S' = diag(exp(li_L)) S + sum_j (k_j exp(li_L - li_j)) v_j^T
        end = li_n[:, :, -1:, :]  # (B,H,1,C)
        k_dec = kn * jnp.exp(jnp.minimum(end - li_n, 0.0))
        s_new = jnp.exp(end[:, :, 0])[..., None] * s + jnp.einsum(
            "bhjc,bhjv->bhcv", k_dec, vn
        )
        return s_new, y

    s_last, ys = jax.lax.scan(one_chunk, s0.astype(jnp.float32), (rc, kc, vc, li, li_prev))
    y = jnp.moveaxis(ys, (0, 2), (1, 3)).reshape(B, N * L, H, C)
    return y[:, :S].astype(r.dtype), s_last
