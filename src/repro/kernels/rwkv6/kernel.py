"""Pallas TPU kernel for the RWKV6 WKV recurrence (chunked parallel form).

Grid = (batch, heads, num_chunks), chunks innermost: the (C x C) fp32 state
matrix lives in VMEM scratch and carries across chunk iterations on the same
core — sequential dependency across chunks, full MXU parallelism within a
chunk.  Per chunk the kernel computes (all fp32, in VMEM):

    li        = cumsum(log w)                       (L, C)
    y_state   = (r * exp(li_prev)) @ S              (L,C)@(C,C)
    A[i,j]    = sum_c r[i,c] k[j,c] exp(li_prev[i,c]-li[j,c]) for j<i
    A[i,i]    = sum_c r[i,c] u[c] k[i,c]
    y         = y_state + A @ v
    S'        = diag(exp(li_L)) S + (k * exp(li_L - li))^T @ v

Every exponent is <= 0 (log-decays are negative and cumulative), so the
chunked math is stable for any decay magnitude — this is the TPU-adapted
replacement for the CUDA kernel's per-thread sequential loop.

VMEM working set per step: 4 chunk blocks (L x C) + pairwise decay tensor
(L x L x C fp32) + state (C x C fp32); with L=32, C=64 that is ~0.8 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
    y_ref, s_out_ref,
    state,  # VMEM scratch (C, C) fp32
    *,
    chunk: int,
):
    n = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(n == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)  # (L, C)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (C,)
    L = chunk

    lw = jnp.log(jnp.maximum(w, 1e-30))
    li = jnp.cumsum(lw, axis=0)  # (L, C), decreasing
    li_prev = jnp.concatenate([jnp.zeros_like(li[:1]), li[:-1]], axis=0)

    s = state[...]
    q_dec = r * jnp.exp(li_prev)
    y_state = jax.lax.dot_general(
        q_dec, s, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (L, C)

    diff = li_prev[:, None, :] - li[None, :, :]  # (L, L, C)
    dmat = jnp.exp(jnp.minimum(diff, 0.0))
    a = jnp.sum(r[:, None, :] * k[None, :, :] * dmat, axis=-1)  # (L, L)
    causal = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) > jax.lax.broadcasted_iota(
        jnp.int32, (L, L), 1
    )
    a = jnp.where(causal, a, 0.0)
    a_diag = jnp.sum(r * u[None, :] * k, axis=-1)  # (L,)
    eye = (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
        == jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    )
    a = a + jnp.where(eye, a_diag[:, None], 0.0)

    y = y_state + jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_ref[0, 0, :, :] = y.astype(y_ref.dtype)

    end = li[-1:, :]  # (1, C)
    k_dec = k * jnp.exp(jnp.minimum(end - li, 0.0))  # (L, C)
    s_new = jnp.exp(end[0])[:, None] * s + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state[...] = s_new

    @pl.when(n == nc - 1)
    def _fin():
        s_out_ref[0, 0, :, :] = state[...]


def wkv_fwd(
    r, k, v, w,  # (B, H, S, C)
    u,  # (H, C)
    s0,  # (B, H, C, C) fp32
    *,
    chunk: int = 32,
    interpret: bool = False,
):
    B, H, S, C = r.shape
    assert S % chunk == 0, (S, chunk)
    grid = (B, H, S // chunk)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    blk = lambda b, h, n: (b, h, n, 0)
    y, s_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, C), blk),
            pl.BlockSpec((1, 1, chunk, C), blk),
            pl.BlockSpec((1, 1, chunk, C), blk),
            pl.BlockSpec((1, 1, chunk, C), blk),
            pl.BlockSpec((1, C), lambda b, h, n: (h, 0)),
            pl.BlockSpec((1, 1, C, C), lambda b, h, n: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, C), blk),
            pl.BlockSpec((1, 1, C, C), lambda b, h, n: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, C), r.dtype),
            jax.ShapeDtypeStruct((B, H, C, C), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((C, C), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_last
