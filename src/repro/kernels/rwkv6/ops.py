"""Jit'd wrapper for the RWKV6 WKV Pallas kernel (model layout adapters)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv_fwd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _wkv_jit(r, k, v, w, u, s0, *, chunk, interpret):
    # model layout (B,S,H,C) -> kernel layout (B,H,S,C)
    tr = lambda x: jnp.swapaxes(x, 1, 2)
    y, s_last = wkv_fwd(
        tr(r), tr(k), tr(v), tr(w), u, s0, chunk=chunk, interpret=interpret
    )
    return jnp.swapaxes(y, 1, 2), s_last


def wkv_pallas(r, k, v, w, u, s0, *, chunk: int = 32, interpret: bool | None = None):
    """r,k,v,w: (B,S,H,C); u: (H,C); s0: (B,H,C,C). Returns (y, s_last)."""
    if interpret is None:
        interpret = _interpret_default()
    S = r.shape[1]
    chunk = min(chunk, S)
    if S % chunk:  # pad to a chunk multiple; padded steps have w=1, k=0
        pad = chunk - S % chunk
        zero = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r2, k2, v2 = zero(r), zero(k), zero(v)
        w2 = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        y, s_last = _wkv_jit(r2, k2, v2, w2, u, s0, chunk=chunk, interpret=interpret)
        return y[:, :S], s_last
    return _wkv_jit(r, k, v, w, u, s0, chunk=chunk, interpret=interpret)
