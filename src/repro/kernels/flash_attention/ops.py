"""Jit'd public wrapper for the flash-attention kernel.

Accepts model-layout tensors (B, S, H, D) / (B, T, K, D), handles the
(B, H, S, D) kernel layout, interpret-mode fallback on non-TPU backends, and
optional shard_map distribution: batch over the data(/pod) axes and q-heads
over the model axis when divisible (KV heads are gathered per local q head
inside each shard, so the kernel always runs a per-device dense problem).

Block sizes left unspecified (None) are resolved from the kernel-tuner
cache (repro.autotune.kernel_tuner) keyed by the problem signature, falling
back to the 512x512 default — this is how woven programs and the serving
runtime pick DSE-tuned blocks automatically.  Backward blocks
(`block_q_bwd` / `block_kv_bwd`) resolve the same way and fall back to the
forward blocks when untuned.

The custom VJP runs the *fused Pallas backward* (kernel.flash_attention_bwd,
the §Perf follow-up recorded in PR 1 — done): the forward saves
(q, k, v, out, lse) as residuals and the backward streams the same pruned
block schedule in both directions, never recomputing through the dense
`attention_ref`.
"""

from __future__ import annotations

import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.flash_attention.kernel import (
    flash_attention_bwd,
    flash_attention_fwd,
)

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512
DEFAULT_BLOCK_KV_DEC = 512
DEFAULT_PAGE_SIZE = 128

# Quantized KV-cache dtypes: name -> largest representable magnitude.  The
# per-page-per-head scale is abs_max / qmax, so dequant is value * scale.
# fp8 entries appear only when the installed jax ships the dtype.
CACHE_QMAX: dict[str, float] = {"int8": 127.0}
if hasattr(jnp, "float8_e4m3fn"):
    CACHE_QMAX["float8_e4m3fn"] = 448.0
if hasattr(jnp, "float8_e5m2"):
    CACHE_QMAX["float8_e5m2"] = 57344.0


def cache_qmax(dtype) -> float:
    """qmax for a quantized-cache dtype (accepts names and jnp dtypes)."""
    name = jnp.dtype(dtype).name if not isinstance(dtype, str) else dtype
    return CACHE_QMAX[name]


def resolve_cache_dtype(name):
    """Map a `cache_dtype` knob value to a jnp storage dtype, or None when
    the value names no quantized format (fp values mean: keep the fp pool)."""
    if name is None:
        return None
    name = str(name)
    if name not in CACHE_QMAX:
        return None
    return {"int8": jnp.int8,
            "float8_e4m3fn": getattr(jnp, "float8_e4m3fn", None),
            "float8_e5m2": getattr(jnp, "float8_e5m2", None)}[name]


def kv_scale_from_absmax(absmax, dtype):
    """Per-page scale from a page's abs-max: absmax / qmax, so the stored
    code range spans the full [-qmax, qmax] grid (an absmax scale would
    collapse int8 codes to {-1, 0, 1}).  Keeps the 0.0 free-page sentinel:
    zero absmax stays zero."""
    return absmax / cache_qmax(dtype)


def quantize_kv_write(x, scale, dtype):
    """Quantize K/V values at *fixed* per-page scales: x (..., K, D) against
    scale (..., K).  Values louder than the page's recorded abs-max clip —
    scales are never recomputed on already-written slots, which is what
    keeps speculative rollback and CoW sharing bit-deterministic."""
    qmax = cache_qmax(dtype)
    s = jnp.where(scale > 0, scale, 1.0)[..., None]
    y = jnp.clip(x.astype(jnp.float32) / s, -qmax, qmax)
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        y = jnp.round(y)
    return y.astype(dtype)


def dequantize_kv(x, scale):
    """fp32 dequant of (..., K, D) quantized values at (..., K) scales."""
    return x.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)[..., None]

if hasattr(jax, "shard_map"):  # jax >= 0.6
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_KW = {"check_rep": False}


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10, 11),
)
def _flash_core(q, k, v, causal, window, softcap, block_q, block_kv,
                block_q_bwd, block_kv_bwd, pruned, interpret):
    qt = jnp.swapaxes(q, 1, 2)  # (B,H,S,D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_fwd(
        qt, kt, vt,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, pruned=pruned, interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)


def _flash_core_fwd(q, k, v, causal, window, softcap, block_q, block_kv,
                    block_q_bwd, block_kv_bwd, pruned, interpret):
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out_t, lse = flash_attention_fwd(
        qt, kt, vt,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, pruned=pruned, interpret=interpret,
        return_lse=True,
    )
    out = jnp.swapaxes(out_t, 1, 2)
    # residuals for the fused backward: inputs + output + softmax stats,
    # all the two-pass recipe needs to recompute probability tiles exactly.
    return out, (q, k, v, out, lse)


def _flash_core_bwd(causal, window, softcap, block_q, block_kv, block_q_bwd,
                    block_kv_bwd, pruned, interpret, res, g):
    """Fused Pallas backward: dq over pruned KV blocks, dk/dv over the
    transposed pruned Q blocks — no dense `attention_ref` recompute."""
    q, k, v, out, lse = res
    dq_t, dk_t, dv_t = flash_attention_bwd(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        jnp.swapaxes(out, 1, 2), lse, jnp.swapaxes(g, 1, 2),
        causal=causal, window=window, softcap=softcap,
        block_q=block_q_bwd, block_kv=block_kv_bwd, pruned=pruned,
        interpret=interpret,
    )
    return (jnp.swapaxes(dq_t, 1, 2), jnp.swapaxes(dk_t, 1, 2),
            jnp.swapaxes(dv_t, 1, 2))


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_kv",
        "block_q_bwd", "block_kv_bwd", "pruned", "interpret",
    ),
)
def _flash_local(q, k, v, *, causal, window, softcap, block_q, block_kv,
                 block_q_bwd, block_kv_bwd, pruned, interpret):
    return _flash_core(q, k, v, causal, window, softcap, block_q, block_kv,
                       block_q_bwd, block_kv_bwd, pruned, interpret)


def _resolve_blocks(q, k, *, causal, window, block_q, block_kv,
                    block_q_bwd=None, block_kv_bwd=None):
    """Fill unspecified block sizes from the tuner cache (never fails).

    Returns (block_q, block_kv, block_q_bwd, block_kv_bwd); untuned backward
    blocks fall back to the resolved forward blocks.
    """
    if None not in (block_q, block_kv, block_q_bwd, block_kv_bwd):
        return (int(block_q), int(block_kv),
                int(block_q_bwd), int(block_kv_bwd))
    from repro.autotune.kernel_tuner import tuned_flash_blocks

    tuned = tuned_flash_blocks(q.shape, k.shape[2], q.dtype, causal=causal,
                               window=window)
    bq = int(block_q if block_q is not None
             else tuned.get("block_q", DEFAULT_BLOCK_Q))
    bkv = int(block_kv if block_kv is not None
              else tuned.get("block_kv", DEFAULT_BLOCK_KV))
    bqb = int(block_q_bwd if block_q_bwd is not None
              else tuned.get("block_q_bwd", bq))
    bkvb = int(block_kv_bwd if block_kv_bwd is not None
               else tuned.get("block_kv_bwd", bkv))
    return bq, bkv, bqb, bkvb


def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, K, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int | None = None,
    block_kv: int | None = None,
    block_q_bwd: int | None = None,
    block_kv_bwd: int | None = None,
    pruned: bool = True,
    interpret: bool | None = None,
    mesh: jax.sharding.Mesh | None = None,
    rules: Mapping[str, Any] | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = _interpret_default()
    block_q, block_kv, block_q_bwd, block_kv_bwd = _resolve_blocks(
        q, k, causal=causal, window=window, block_q=block_q, block_kv=block_kv,
        block_q_bwd=block_q_bwd, block_kv_bwd=block_kv_bwd,
    )
    call = functools.partial(
        _flash_local,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv,
        block_q_bwd=block_q_bwd, block_kv_bwd=block_kv_bwd,
        pruned=pruned, interpret=interpret,
    )
    if mesh is None:
        return call(q, k, v)

    B, S, H, D = q.shape
    K = k.shape[2]
    rules = dict(rules or {})
    batch_axes = tuple(
        a for a in ("pod", "data")
        if a in mesh.shape and rules.get("batch") and a in _as_tuple(rules.get("batch"))
    )
    model_ok = "model" in mesh.shape and H % mesh.shape["model"] == 0
    head_spec = "model" if model_ok else None
    q_spec = P(batch_axes or None, None, head_spec, None)
    kv_spec = P(batch_axes or None, None, None, None)  # KV heads replicated over model

    group = H // K

    def body(q_l, k_l, v_l):
        if model_ok:
            h_loc = q_l.shape[2]
            off = jax.lax.axis_index("model") * h_loc
            idx = (off + jnp.arange(h_loc)) // group
            k_l = jnp.take(k_l, idx, axis=2)
            v_l = jnp.take(v_l, idx, axis=2)
        return call(q_l, k_l, v_l)

    shard = _shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec),
        out_specs=q_spec,
        **_SHARD_MAP_KW,
    )
    return shard(q, k, v)


def _as_tuple(x):
    if x is None:
        return ()
    if isinstance(x, (list, tuple)):
        return tuple(x)
    return (x,)


def paged_gather_kv(pk, pv, tables, kv_len: int, k_scale=None, v_scale=None):
    """Materialize the logical (B, kv_len, K, D) K/V view of a page pool
    through per-request block tables — the XLA-reference twin of the
    indirection the paged `flash_decode` kernel performs in its BlockSpec
    index_map.  Shared (prefix-cached) pages gather exactly like exclusive
    ones: the table row is the only addressing, so refcounted pools need no
    kernel changes.  Used by `Attention._decode_paged`'s reference path and
    the paged-prefill path (suffix tokens attending over pool-resident
    prefixes).

    With `k_scale`/`v_scale` ((P, K) fp32 sidecars of a quantized pool) the
    gathered view is dequantized to fp32 — the reference twin of the
    kernel's in-loop dequant."""
    B, nb = tables.shape
    ps = pk.shape[-3]  # pool layout (P, page_size, K, D)
    k = pk[tables]  # (B, nb, page_size, K, D)
    v = pv[tables]
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale[tables][:, :, None, :, None]
        v = v.astype(jnp.float32) * v_scale[tables][:, :, None, :, None]
    k = k.reshape(B, nb * ps, *pk.shape[-2:])[:, :kv_len]
    v = v.reshape(B, nb * ps, *pv.shape[-2:])[:, :kv_len]
    return k, v


# ---------------------------------------------------------------------------
# Decode (a small block of new tokens against a cache) — the serving hot path
# ---------------------------------------------------------------------------


def _fold_decode_q(q, K):
    """Model layout (B, S, H, D) -> widened kernel layout (B, K, S*G, D).

    Heads h = kh*G + g fold into the (K, G) grid/row split the kernel's
    per-KV-head instances expect; with S > 1 (speculative verify / q_offset
    suffix) the S tokens stack token-major so row r = token r // G."""
    B, S, H, D = q.shape
    G = H // K
    qt = q.reshape(B, S, K, G, D)
    qt = jnp.moveaxis(qt, 2, 1)  # (B, K, S, G, D)
    return qt.reshape(B, K, S * G, D)


def _unfold_decode_o(out, B, S, H, D, K):
    """Inverse of `_fold_decode_q`: (B, K, S*G, D) -> (B, S, H, D)."""
    G = H // K
    o = out.reshape(B, K, S, G, D)
    o = jnp.moveaxis(o, 1, 2)  # (B, S, K, G, D)
    return o.reshape(B, S, H, D)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "block_kv", "pruned", "interpret",
                     "scale_page"),
)
def _flash_decode_local(q, k, v, index, k_scale=None, v_scale=None, *,
                        window, softcap, block_kv, pruned, interpret,
                        scale_page=None):
    from repro.kernels.flash_attention.decode import flash_decode_fwd

    B, S, H, D = q.shape
    K = k.shape[2]
    # dense scales arrive model-layout (B, NP, K); kernel wants (B, K, NP)
    ks = jnp.swapaxes(k_scale, 1, 2) if k_scale is not None else None
    vs = jnp.swapaxes(v_scale, 1, 2) if v_scale is not None else None
    out = flash_decode_fwd(
        _fold_decode_q(q, K), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        index,
        window=window, softcap=softcap, block_kv=block_kv,
        pruned=pruned, interpret=interpret, q_span=S,
        k_scale=ks, v_scale=vs, scale_page=scale_page,
    )
    return _unfold_decode_o(out, B, S, H, D, K)


@functools.partial(
    jax.jit,
    static_argnames=("kv_len", "window", "softcap", "block_kv", "pruned",
                     "interpret"),
)
def _flash_decode_paged_local(q, k, v, index, tables, k_scale=None,
                              v_scale=None, *, kv_len, window,
                              softcap, block_kv, pruned, interpret):
    from repro.kernels.flash_attention.decode import flash_decode_fwd

    B, S, H, D = q.shape
    K = k.shape[2]  # pool layout (P, page_size, K, D)
    out = flash_decode_fwd(
        _fold_decode_q(q, K), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        index, tables=tables, kv_len=kv_len,
        window=window, softcap=softcap, block_kv=block_kv,
        pruned=pruned, interpret=interpret, q_span=S,
        k_scale=k_scale, v_scale=v_scale,
    )
    return _unfold_decode_o(out, B, S, H, D, K)


def flash_decode(
    q: jax.Array,        # (B, S, H, D) — the S >= 1 new tokens, post-RoPE
    k_cache: jax.Array,  # (B, T, K, D) cache *with the new tokens written*,
                         # or the (P, page_size, K, D) page pool when paged
    v_cache: jax.Array,
    index: jax.Array,    # () or (B,) int32: the *first* new token's position
    *,
    window: int | None = None,  # linear caches only; ring caches pass None
    softcap: float | None = None,
    block_kv: int | None = None,
    pruned: bool = True,
    interpret: bool | None = None,
    tables: jax.Array | None = None,  # (B, num_blocks) int32 block tables
    kv_len: int | None = None,        # logical cache length (paged only)
    k_scale: jax.Array | None = None,  # quantized caches: fp32 scales —
    v_scale: jax.Array | None = None,  # paged (P, K); dense (B, NP, K)
    scale_page: int | None = None,     # dense only: cache slots per scale row
) -> jax.Array:
    """One decode step over a live-block-pruned cache; see decode.py.

    `block_kv=None` resolves from the kernel-tuner cache (the
    `block_kv_dec` knob under the `vmem_bytes_dec` constraint), falling
    back to the 512 default — the same auto-tuning path as the prefill
    kernel's blocks.  Passing `tables` selects the paged layout: K/V are
    one shared page pool and every request's cache blocks resolve through
    its block-table row (tuned via the `paged_decode` signature, which
    also carries the `page_size` knob the pool was built with).  Prefix
    sharing is pure table plumbing: rows of several requests may name the
    same physical page and the kernel streams it for each — the body never
    changes, so shared-pool output stays bit-identical to unshared.

    With S > 1 q tokens (the widened-q / q_offset variant) token s attends
    through cache slot index + s: one kernel launch verifies a whole draft
    block, or prefills a suffix over a pool-resident shared prefix.  Each q
    row runs the same online softmax over the same block walk as a
    single-token call, so S=1 and sequential decode stay bit-identical.
    """
    if interpret is None:
        interpret = _interpret_default()
    index = jnp.asarray(index, jnp.int32)
    if tables is not None:
        if kv_len is None:
            raise ValueError("paged flash_decode requires kv_len")
        if block_kv is None:
            from repro.autotune.kernel_tuner import tuned_paged_blocks

            tuned = tuned_paged_blocks(
                q.shape, int(kv_len), k_cache.shape[2], q.dtype,
                window=window,
            )
            block_kv = int(tuned.get("block_kv_dec", DEFAULT_BLOCK_KV_DEC))
        return _flash_decode_paged_local(
            q, k_cache, v_cache, index, jnp.asarray(tables, jnp.int32),
            k_scale, v_scale,
            kv_len=int(kv_len), window=window, softcap=softcap,
            block_kv=int(block_kv), pruned=pruned, interpret=interpret,
        )
    if block_kv is None:
        from repro.autotune.kernel_tuner import tuned_decode_blocks

        tuned = tuned_decode_blocks(
            q.shape, k_cache.shape[1], k_cache.shape[2], q.dtype,
            window=window,
        )
        block_kv = int(tuned.get("block_kv_dec", DEFAULT_BLOCK_KV_DEC))
    return _flash_decode_local(
        q, k_cache, v_cache, index, k_scale, v_scale,
        window=window, softcap=softcap, block_kv=int(block_kv),
        pruned=pruned, interpret=interpret,
        scale_page=None if scale_page is None else int(scale_page),
    )
