"""Jit'd public wrapper for the flash-attention kernel.

Accepts model-layout tensors (B, S, H, D) / (B, T, K, D), handles the
(B, H, S, D) kernel layout, interpret-mode fallback on non-TPU backends, and
optional shard_map distribution: batch over the data(/pod) axes and q-heads
over the model axis when divisible (KV heads are gathered per local q head
inside each shard, so the kernel always runs a per-device dense problem).
"""

from __future__ import annotations

import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7, 8),
)
def _flash_core(q, k, v, causal, window, softcap, block_q, block_kv, interpret):
    qt = jnp.swapaxes(q, 1, 2)  # (B,H,S,D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_fwd(
        qt, kt, vt,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
    return jnp.swapaxes(out, 1, 2)


def _flash_core_fwd(q, k, v, causal, window, softcap, block_q, block_kv,
                    interpret):
    out = _flash_core(q, k, v, causal, window, softcap, block_q, block_kv,
                      interpret)
    return out, (q, k, v)


def _flash_core_bwd(causal, window, softcap, block_q, block_kv, interpret,
                    res, g):
    """Backward via the reference formulation (recompute-from-inputs, the
    flash-bwd memory posture); the fused Pallas backward kernel is a
    recorded §Perf follow-up."""
    from repro.kernels.flash_attention.ref import attention_ref

    q, k, v = res

    def f(q, k, v):
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_kv", "interpret",
    ),
)
def _flash_local(q, k, v, *, causal, window, softcap, block_q, block_kv, interpret):
    return _flash_core(q, k, v, causal, window, softcap, block_q, block_kv,
                       interpret)


def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, K, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool | None = None,
    mesh: jax.sharding.Mesh | None = None,
    rules: Mapping[str, Any] | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = _interpret_default()
    call = functools.partial(
        _flash_local,
        causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
    )
    if mesh is None:
        return call(q, k, v)

    B, S, H, D = q.shape
    K = k.shape[2]
    rules = dict(rules or {})
    batch_axes = tuple(
        a for a in ("pod", "data")
        if a in mesh.shape and rules.get("batch") and a in _as_tuple(rules.get("batch"))
    )
    model_ok = "model" in mesh.shape and H % mesh.shape["model"] == 0
    head_spec = "model" if model_ok else None
    q_spec = P(batch_axes or None, None, head_spec, None)
    kv_spec = P(batch_axes or None, None, None, None)  # KV heads replicated over model

    group = H // K

    def body(q_l, k_l, v_l):
        if model_ok:
            h_loc = q_l.shape[2]
            off = jax.lax.axis_index("model") * h_loc
            idx = (off + jnp.arange(h_loc)) // group
            k_l = jnp.take(k_l, idx, axis=2)
            v_l = jnp.take(v_l, idx, axis=2)
        return call(q_l, k_l, v_l)

    shard = jax.shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, kv_spec, kv_spec),
        out_specs=q_spec,
        check_vma=False,
    )
    return shard(q, k, v)


def _as_tuple(x):
    if x is None:
        return ()
    if isinstance(x, (list, tuple)):
        return tuple(x)
    return (x,)
