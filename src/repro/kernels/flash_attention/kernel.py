"""Pallas TPU flash attention (forward): online-softmax over KV blocks with
block-sparse grid pruning.

TPU mapping (DESIGN.md: adapt, don't port): the grid is
(batch, q_heads, num_q_blocks, kv_steps) with the KV dimension *innermost* —
TPU grid steps on one core execute sequentially, so the fp32 running max /
denominator / accumulator live in VMEM scratch and persist across KV-block
iterations (the TPU analogue of a CUDA thread-block's shared-memory state).
Block shapes are BlockSpec-tiled so each step's working set is
(block_q x D) + 2 x (block_kv x D) + (block_q x block_kv) fp32 in VMEM, with
block sizes kept at MXU-friendly multiples of 128.

Grid pruning (the §Perf follow-up, now implemented): for causal and
sliding-window masks most KV blocks are fully masked for a given q block, so
the pruned path iterates only the reachable KV-block interval [lo(iq), hi(iq))
per q block via an index-remapped KV dimension.  `kv_steps` is the *maximum*
interval length over q blocks; q blocks with fewer reachable KV blocks clamp
the remapped index to their last reachable block, and Pallas elides the DMA
when the block index repeats, so fully-masked blocks are never streamed from
HBM.  For window-W attention the whole grid shrinks to O(S·W/block_kv)
instead of O(S²/block²) — overshoot steps do no DMA and no MXU work
(`pl.when`).  The dense grid remains for non-causal attention and as an
explicit `pruned=False` baseline for benchmarks.

GQA is handled in the K/V index_map (kv_head = q_head // group), so no KV
replication is ever materialized in HBM.  Ragged shapes (`block ∤ S`) are
handled by zero-padding Q/KV up to block multiples in the wrapper; the
in-kernel `kp < kv_len` mask keeps padded KV out of the softmax and the
padded output rows are sliced off.

`kv_schedule` mirrors the index remapping in pure numpy so tests and benches
can assert exactly which KV blocks a configuration streams.  `vmem_bytes` is
the analytic VMEM working-set model used as the autotuner's capacity
constraint (see repro.autotune.kernel_tuner).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Reachable KV-block interval per q block
# ---------------------------------------------------------------------------


def _kv_lo(iq, block_q: int, block_kv: int, window: int | None):
    """First reachable KV block for q block `iq` (lowest kp = q_start-window+1).

    Works on python ints and traced scalars (index_map arithmetic).
    """
    if window is None:
        return iq * 0  # 0, but keeps tracer dtype when iq is traced
    lo = (iq * block_q - (window - 1)) // block_kv
    if isinstance(lo, int):
        return max(0, lo)
    return jnp.maximum(lo, 0)


def _kv_hi(iq, block_q: int, block_kv: int, nk: int):
    """One past the last reachable KV block (highest kp = q_start+block_q-1)."""
    hi = (iq * block_q + block_q - 1) // block_kv + 1
    if isinstance(hi, int):
        return min(nk, hi)
    return jnp.minimum(hi, nk)


def kv_steps_for(
    S: int, T: int, block_q: int, block_kv: int,
    causal: bool, window: int | None,
) -> int:
    """Static innermost grid length for the pruned path: max reachable KV
    blocks over all q blocks."""
    nq, nk = cdiv(S, block_q), cdiv(T, block_kv)
    if not causal:
        return nk
    steps = 0
    for iq in range(nq):
        lo = _kv_lo(iq, block_q, block_kv, window)
        hi = _kv_hi(iq, block_q, block_kv, nk)
        steps = max(steps, hi - lo)
    return max(steps, 1)


def block_fully_masked(
    iq: int, ik: int, block_q: int, block_kv: int, *,
    kv_len: int, causal: bool, window: int | None,
) -> bool:
    """True iff no (q, k) pair inside block (iq, ik) survives the mask —
    the oracle the pruning tests/benches check the schedule against."""
    q0, q1 = iq * block_q, iq * block_q + block_q - 1
    k0 = ik * block_kv
    k1 = min(ik * block_kv + block_kv - 1, kv_len - 1)
    if k0 >= kv_len:
        return True
    if not causal:
        return False
    if k0 > q1:  # entirely above the diagonal
        return True
    if window is not None and k1 <= q0 - window:  # entirely out of window
        return True
    return False


def kv_schedule(
    S: int, T: int, block_q: int, block_kv: int, *,
    causal: bool = True, window: int | None = None, pruned: bool = True,
) -> list[list[int]]:
    """Per-q-block list of KV block indices actually *streamed* from HBM.

    Mirrors the kernel's index remapping: the pruned path walks
    [lo, lo+kv_steps) with the index clamped to hi-1, and Pallas elides the
    copy when the block index repeats — so clamped overshoot steps stream
    nothing.  The dense path streams every KV block for every q block.
    """
    nq, nk = cdiv(S, block_q), cdiv(T, block_kv)
    if not (causal and pruned):
        return [list(range(nk)) for _ in range(nq)]
    steps = kv_steps_for(S, T, block_q, block_kv, causal, window)
    out: list[list[int]] = []
    for iq in range(nq):
        lo = _kv_lo(iq, block_q, block_kv, window)
        hi = _kv_hi(iq, block_q, block_kv, nk)
        row = []
        for j in range(steps):
            ik = min(lo + j, hi - 1)
            if not row or row[-1] != ik:  # repeated index -> no DMA
                row.append(ik)
        out.append(row)
    return out


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------


def _attend_block(
    q_ref, k_ref, v_ref, m_scratch, l_scratch, acc_scratch,
    q_start, k_start, *,
    block_q: int, block_kv: int, kv_len: int,
    causal: bool, window: int | None, softcap: float | None, scale: float,
):
    """One online-softmax update for the (q_start, k_start) tile."""
    q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = kp < kv_len
    if causal:
        mask = jnp.logical_and(mask, kp <= qp)
        if window is not None:
            mask = jnp.logical_and(mask, kp > qp - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scratch[...]  # (bq, 1)
    l_prev = l_scratch[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scratch[...] = m_new
    l_scratch[...] = l_new
    acc_scratch[...] = acc


def _finalize(o_ref, m_scratch, l_scratch, acc_scratch):
    l = l_scratch[...]
    out = acc_scratch[...] / jnp.maximum(l, 1e-30)
    o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


def _init_scratch(m_scratch, l_scratch, acc_scratch):
    m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
    l_scratch[...] = jnp.zeros_like(l_scratch)
    acc_scratch[...] = jnp.zeros_like(acc_scratch)


def _flash_kernel_dense(
    q_ref, k_ref, v_ref,  # VMEM blocks
    o_ref,
    m_scratch, l_scratch, acc_scratch,
    *,
    block_q: int,
    block_kv: int,
    kv_len: int,
    causal: bool,
    window: int | None,
    softcap: float | None,
    scale: float,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        _init_scratch(m_scratch, l_scratch, acc_scratch)

    q_start = iq * block_q
    k_start = ik * block_kv

    # Block-level reachability: skip the MXU work for fully-masked KV blocks
    # (they still stream in on this path — the pruned kernel avoids that).
    reachable = jnp.asarray(True)
    if causal:
        reachable = jnp.asarray(k_start <= q_start + block_q - 1)
        if window is not None:
            reachable = jnp.logical_and(
                reachable, k_start + block_kv - 1 > q_start - window
            )

    @pl.when(reachable)
    def _compute():
        _attend_block(
            q_ref, k_ref, v_ref, m_scratch, l_scratch, acc_scratch,
            q_start, k_start,
            block_q=block_q, block_kv=block_kv, kv_len=kv_len,
            causal=causal, window=window, softcap=softcap, scale=scale,
        )

    @pl.when(ik == nk - 1)
    def _fin():
        _finalize(o_ref, m_scratch, l_scratch, acc_scratch)


def _flash_kernel_pruned(
    q_ref, k_ref, v_ref,
    o_ref,
    m_scratch, l_scratch, acc_scratch,
    *,
    block_q: int,
    block_kv: int,
    kv_len: int,
    nk: int,
    causal: bool,
    window: int | None,
    softcap: float | None,
    scale: float,
):
    """Index-remapped KV iteration: step j of q block iq visits KV block
    min(lo(iq)+j, hi(iq)-1).  Steps past the interval repeat the last block
    (no DMA) and skip all compute."""
    iq = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        _init_scratch(m_scratch, l_scratch, acc_scratch)

    lo = _kv_lo(iq, block_q, block_kv, window)
    hi = _kv_hi(iq, block_q, block_kv, nk)
    ik = jnp.minimum(lo + j, hi - 1)

    q_start = iq * block_q
    k_start = ik * block_kv

    @pl.when(j < hi - lo)
    def _compute():
        _attend_block(
            q_ref, k_ref, v_ref, m_scratch, l_scratch, acc_scratch,
            q_start, k_start,
            block_q=block_q, block_kv=block_kv, kv_len=kv_len,
            causal=causal, window=window, softcap=softcap, scale=scale,
        )

    @pl.when(j == nj - 1)
    def _fin():
        _finalize(o_ref, m_scratch, l_scratch, acc_scratch)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    pad = (-x.shape[axis]) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention_fwd(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, K, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    pruned: bool = True,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)

    # Ragged shapes: zero-pad to block multiples; `kp < kv_len` masks the
    # padded KV and the padded q rows are sliced off below.
    q = _pad_to(q, 2, block_q)
    k = _pad_to(k, 2, block_kv)
    v = _pad_to(v, 2, block_kv)
    Sp, Tp = q.shape[2], k.shape[2]
    nq, nk = Sp // block_q, Tp // block_kv

    use_pruned = pruned and causal
    if use_pruned:
        kv_steps = kv_steps_for(S, Tp, block_q, block_kv, causal, window)
        grid = (B, H, nq, kv_steps)
        kernel = functools.partial(
            _flash_kernel_pruned,
            block_q=block_q, block_kv=block_kv, kv_len=T, nk=nk,
            causal=causal, window=window, softcap=softcap,
            scale=1.0 / np.sqrt(D),
        )

        def kv_index(b, h, iq, j):
            lo = _kv_lo(iq, block_q, block_kv, window)
            hi = _kv_hi(iq, block_q, block_kv, nk)
            return (b, h // G, jnp.minimum(lo + j, hi - 1), 0)
    else:
        grid = (B, H, nq, nk)
        kernel = functools.partial(
            _flash_kernel_dense,
            block_q=block_q, block_kv=block_kv, kv_len=T,
            causal=causal, window=window, softcap=softcap,
            scale=1.0 / np.sqrt(D),
        )

        def kv_index(b, h, iq, ik):
            return (b, h // G, ik, 0)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, j: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D), kv_index),
            pl.BlockSpec((1, 1, block_kv, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, j: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]


def vmem_bytes(
    block_q: int,
    block_kv: int,
    head_dim: int,
    dtype_bytes: int = 2,
    *,
    kv_dtype_bytes: int | None = None,
) -> int:
    """Analytic VMEM working set — the autotuner's capacity constraint.

    Counts the pipelined Q/O blocks at the Q dtype and the K *and* V blocks
    at the KV dtype (they may differ, e.g. bf16 Q against int8 KV cache),
    double-buffered as Pallas pipelines them, plus the fp32 scratch
    (acc + m + l) and the fp32 score tile.
    """
    if kv_dtype_bytes is None:
        kv_dtype_bytes = dtype_bytes
    qo = 2 * block_q * head_dim * dtype_bytes  # q in + o out
    kv = 2 * block_kv * head_dim * kv_dtype_bytes  # k + v
    scratch = (block_q * (head_dim + 2)) * 4  # fp32 acc + m + l
    scores = block_q * block_kv * 4  # fp32 s/p tile
    return 2 * (qo + kv) + scratch + scores  # x2: double-buffered I/O blocks
