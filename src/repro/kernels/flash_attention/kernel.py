"""Pallas TPU flash attention (forward + fused backward): online-softmax over
KV blocks with block-sparse grid pruning in both directions.

TPU mapping (DESIGN.md: adapt, don't port): the grid is
(batch, q_heads, num_q_blocks, kv_steps) with the KV dimension *innermost* —
TPU grid steps on one core execute sequentially, so the fp32 running max /
denominator / accumulator live in VMEM scratch and persist across KV-block
iterations (the TPU analogue of a CUDA thread-block's shared-memory state).
Block shapes are BlockSpec-tiled so each step's working set is
(block_q x D) + 2 x (block_kv x D) + (block_q x block_kv) fp32 in VMEM, with
block sizes kept at MXU-friendly multiples of 128.

Grid pruning (the §Perf follow-up, now implemented): for causal and
sliding-window masks most KV blocks are fully masked for a given q block, so
the pruned path iterates only the reachable KV-block interval [lo(iq), hi(iq))
per q block via an index-remapped KV dimension.  `kv_steps` is the *maximum*
interval length over q blocks; q blocks with fewer reachable KV blocks clamp
the remapped index to their last reachable block, and Pallas elides the DMA
when the block index repeats, so fully-masked blocks are never streamed from
HBM.  For window-W attention the whole grid shrinks to O(S·W/block_kv)
instead of O(S²/block²) — overshoot steps do no DMA and no MXU work
(`pl.when`).  The dense grid remains for non-causal attention and as an
explicit `pruned=False` baseline for benchmarks.

GQA is handled in the K/V index_map (kv_head = q_head // group), so no KV
replication is ever materialized in HBM.  Ragged shapes (`block ∤ S`) are
handled by zero-padding Q/KV up to block multiples in the wrapper; the
in-kernel `kp < kv_len` mask keeps padded KV out of the softmax and the
padded output rows are sliced off.

Backward (the §Perf follow-up recorded in PR 1, now implemented): the fused
two-pass flash recipe.  The forward saves the per-row softmax statistics
`lse = m + log(l)`; the wrapper precomputes `delta = rowsum(dO·O)`; then

  - the dq pass walks the *same* pruned KV interval [lo(iq), hi(iq)) per q
    block as the forward, recomputing the probability tile from (q, k, lse)
    and accumulating dq in fp32 VMEM scratch, and
  - the dk/dv pass transposes the schedule: per KV block it walks the
    reachable *Q*-block interval [q_lo(ik), q_hi(ik)) — the exact mirror of
    the forward remapping — accumulating dk/dv in fp32 scratch.  The grid
    runs over the K *true* KV heads with the GQA group folded into the
    innermost loop (j = g·q_steps + jq), so the accumulators sum the whole
    group before the single (B, K, T, D) HBM write — O(S·K·D) transient
    traffic, not the per-q-head O(S·H·D) a wrapper-side group-sum would pay.

So backward HBM traffic is O(S·W) for window-W attention, matching the
forward, instead of the O(S²) dense reference VJP.

`kv_schedule` / `q_schedule` mirror both index remappings in pure numpy so
tests and benches can assert exactly which blocks a configuration streams in
each direction.  `vmem_bytes` / `vmem_bytes_bwd` are the analytic VMEM
working-set models used as the autotuner's capacity constraints (see
repro.autotune.kernel_tuner).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Reachable KV-block interval per q block
# ---------------------------------------------------------------------------


def _kv_lo(iq, block_q: int, block_kv: int, window: int | None):
    """First reachable KV block for q block `iq` (lowest kp = q_start-window+1).

    Works on python ints and traced scalars (index_map arithmetic).
    """
    if window is None:
        return iq * 0  # 0, but keeps tracer dtype when iq is traced
    lo = (iq * block_q - (window - 1)) // block_kv
    if isinstance(lo, int):
        return max(0, lo)
    return jnp.maximum(lo, 0)


def _kv_hi(iq, block_q: int, block_kv: int, nk: int):
    """One past the last reachable KV block (highest kp = q_start+block_q-1)."""
    hi = (iq * block_q + block_q - 1) // block_kv + 1
    if isinstance(hi, int):
        return min(nk, hi)
    return jnp.minimum(hi, nk)


def _interval_steps(n_outer: int, lo_fn, hi_fn) -> int:
    """Max interval length over outer blocks — the static innermost grid
    length of a pruned pass."""
    steps = 0
    for i in range(n_outer):
        steps = max(steps, hi_fn(i) - lo_fn(i))
    return max(steps, 1)


def _interval_schedule(n_outer: int, steps: int, lo_fn, hi_fn) -> list[list[int]]:
    """The clamp-and-elide walk both pruned passes share: step j of outer
    block i visits min(lo+j, hi-1), and a repeated index streams nothing
    (Pallas elides the DMA) so overshoot steps are dropped from the row."""
    out: list[list[int]] = []
    for i in range(n_outer):
        lo, hi = lo_fn(i), hi_fn(i)
        row: list[int] = []
        for j in range(steps):
            idx = min(lo + j, max(hi - 1, lo))
            if not row or row[-1] != idx:  # repeated index -> no DMA
                row.append(idx)
        out.append(row)
    return out


def kv_steps_for(
    S: int, T: int, block_q: int, block_kv: int,
    causal: bool, window: int | None,
) -> int:
    """Static innermost grid length for the pruned path: max reachable KV
    blocks over all q blocks."""
    nq, nk = cdiv(S, block_q), cdiv(T, block_kv)
    if not causal:
        return nk
    return _interval_steps(
        nq,
        lambda iq: _kv_lo(iq, block_q, block_kv, window),
        lambda iq: _kv_hi(iq, block_q, block_kv, nk),
    )


def block_fully_masked(
    iq: int, ik: int, block_q: int, block_kv: int, *,
    kv_len: int, causal: bool, window: int | None,
) -> bool:
    """True iff no (q, k) pair inside block (iq, ik) survives the mask —
    the oracle the pruning tests/benches check the schedule against."""
    q0, q1 = iq * block_q, iq * block_q + block_q - 1
    k0 = ik * block_kv
    k1 = min(ik * block_kv + block_kv - 1, kv_len - 1)
    if k0 >= kv_len:
        return True
    if not causal:
        return False
    if k0 > q1:  # entirely above the diagonal
        return True
    if window is not None and k1 <= q0 - window:  # entirely out of window
        return True
    return False


def kv_schedule(
    S: int, T: int, block_q: int, block_kv: int, *,
    causal: bool = True, window: int | None = None, pruned: bool = True,
) -> list[list[int]]:
    """Per-q-block list of KV block indices actually *streamed* from HBM.

    Mirrors the kernel's index remapping: the pruned path walks
    [lo, lo+kv_steps) with the index clamped to hi-1, and Pallas elides the
    copy when the block index repeats — so clamped overshoot steps stream
    nothing.  The dense path streams every KV block for every q block.
    """
    nq, nk = cdiv(S, block_q), cdiv(T, block_kv)
    if not (causal and pruned):
        return [list(range(nk)) for _ in range(nq)]
    return _interval_schedule(
        nq,
        kv_steps_for(S, T, block_q, block_kv, causal, window),
        lambda iq: _kv_lo(iq, block_q, block_kv, window),
        lambda iq: _kv_hi(iq, block_q, block_kv, nk),
    )


# ---------------------------------------------------------------------------
# Reachable Q-block interval per KV block (the transposed schedule, used by
# the dk/dv backward pass)
# ---------------------------------------------------------------------------


def _q_lo(ik, block_q: int, block_kv: int, nq: int):
    """First reachable Q block for kv block `ik` (the block containing k0 —
    causal reach starts at qp >= k0)."""
    lo = (ik * block_kv) // block_q
    if isinstance(lo, int):
        return min(lo, nq - 1)
    return jnp.minimum(lo, nq - 1)


def _q_hi(ik, block_q: int, block_kv: int, nq: int, kv_len: int,
          window: int | None):
    """One past the last reachable Q block (highest qp = k1 + window - 1 for
    windowed attention, else every later q block)."""
    if window is None:
        if isinstance(ik, int):
            return nq
        return jnp.full_like(ik, nq)
    k1 = (ik + 1) * block_kv
    if isinstance(k1, int):
        k1 = min(k1, kv_len) - 1
        return max(1, min(nq, (k1 + window - 1) // block_q + 1))
    k1 = jnp.minimum(k1, kv_len) - 1
    return jnp.clip((k1 + window - 1) // block_q + 1, 1, nq)


def q_steps_for(
    S: int, T: int, block_q: int, block_kv: int,
    causal: bool, window: int | None,
) -> int:
    """Static innermost grid length for the pruned dk/dv pass: max reachable
    Q blocks over all kv blocks."""
    nq, nk = cdiv(S, block_q), cdiv(T, block_kv)
    if not causal:
        return nq
    return _interval_steps(
        nk,
        lambda ik: _q_lo(ik, block_q, block_kv, nq),
        lambda ik: _q_hi(ik, block_q, block_kv, nq, T, window),
    )


def q_schedule(
    S: int, T: int, block_q: int, block_kv: int, *,
    causal: bool = True, window: int | None = None, pruned: bool = True,
) -> list[list[int]]:
    """Per-KV-block list of Q block indices actually *streamed* by the dk/dv
    backward pass — the exact transpose of `kv_schedule`, with the same
    clamp-and-elide semantics for overshoot steps."""
    nq, nk = cdiv(S, block_q), cdiv(T, block_kv)
    if not (causal and pruned):
        return [list(range(nq)) for _ in range(nk)]
    return _interval_schedule(
        nk,
        q_steps_for(S, T, block_q, block_kv, causal, window),
        lambda ik: _q_lo(ik, block_q, block_kv, nq),
        lambda ik: _q_hi(ik, block_q, block_kv, nq, T, window),
    )


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------


def _attend_block(
    q_ref, k_ref, v_ref, m_scratch, l_scratch, acc_scratch,
    q_start, k_start, *,
    block_q: int, block_kv: int, kv_len: int,
    causal: bool, window: int | None, softcap: float | None, scale: float,
):
    """One online-softmax update for the (q_start, k_start) tile."""
    q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = kp < kv_len
    if causal:
        mask = jnp.logical_and(mask, kp <= qp)
        if window is not None:
            mask = jnp.logical_and(mask, kp > qp - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scratch[...]  # (bq, 1)
    l_prev = l_scratch[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scratch[...] = m_new
    l_scratch[...] = l_new
    acc_scratch[...] = acc


def _finalize(o_ref, lse_ref, m_scratch, l_scratch, acc_scratch):
    m = m_scratch[...]
    l = l_scratch[...]
    out = acc_scratch[...] / jnp.maximum(l, 1e-30)
    o_ref[0, 0, :, :] = out.astype(o_ref.dtype)
    if lse_ref is not None:  # training path only (return_lse=True)
        # per-row softmax stats for the fused backward: lse = m + log(l).
        # Fully-masked rows keep lse ~ NEG_INF so the backward's
        # exp(s_masked - lse) stays finite (see _bwd_p_ds).
        lse_ref[0, 0, :] = (m + jnp.log(jnp.maximum(l, 1e-30)))[:, 0]


def _init_scratch(m_scratch, l_scratch, acc_scratch):
    m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
    l_scratch[...] = jnp.zeros_like(l_scratch)
    acc_scratch[...] = jnp.zeros_like(acc_scratch)


def _flash_kernel_dense(
    q_ref, k_ref, v_ref,  # VMEM blocks
    o_ref,
    *refs,  # [lse_ref if emit_lse,] m_scratch, l_scratch, acc_scratch
    block_q: int,
    block_kv: int,
    kv_len: int,
    causal: bool,
    window: int | None,
    softcap: float | None,
    scale: float,
    emit_lse: bool,
):
    lse_ref = refs[0] if emit_lse else None
    m_scratch, l_scratch, acc_scratch = refs[-3:]
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        _init_scratch(m_scratch, l_scratch, acc_scratch)

    q_start = iq * block_q
    k_start = ik * block_kv

    # Block-level reachability: skip the MXU work for fully-masked KV blocks
    # (they still stream in on this path — the pruned kernel avoids that).
    reachable = jnp.asarray(True)
    if causal:
        reachable = jnp.asarray(k_start <= q_start + block_q - 1)
        if window is not None:
            reachable = jnp.logical_and(
                reachable, k_start + block_kv - 1 > q_start - window
            )

    @pl.when(reachable)
    def _compute():
        _attend_block(
            q_ref, k_ref, v_ref, m_scratch, l_scratch, acc_scratch,
            q_start, k_start,
            block_q=block_q, block_kv=block_kv, kv_len=kv_len,
            causal=causal, window=window, softcap=softcap, scale=scale,
        )

    @pl.when(ik == nk - 1)
    def _fin():
        _finalize(o_ref, lse_ref, m_scratch, l_scratch, acc_scratch)


def _flash_kernel_pruned(
    q_ref, k_ref, v_ref,
    o_ref,
    *refs,  # [lse_ref if emit_lse,] m_scratch, l_scratch, acc_scratch
    block_q: int,
    block_kv: int,
    kv_len: int,
    nk: int,
    causal: bool,
    window: int | None,
    softcap: float | None,
    scale: float,
    emit_lse: bool,
):
    """Index-remapped KV iteration: step j of q block iq visits KV block
    min(lo(iq)+j, hi(iq)-1).  Steps past the interval repeat the last block
    (no DMA) and skip all compute."""
    lse_ref = refs[0] if emit_lse else None
    m_scratch, l_scratch, acc_scratch = refs[-3:]
    iq = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        _init_scratch(m_scratch, l_scratch, acc_scratch)

    lo = _kv_lo(iq, block_q, block_kv, window)
    hi = _kv_hi(iq, block_q, block_kv, nk)
    ik = jnp.minimum(lo + j, hi - 1)

    q_start = iq * block_q
    k_start = ik * block_kv

    @pl.when(j < hi - lo)
    def _compute():
        _attend_block(
            q_ref, k_ref, v_ref, m_scratch, l_scratch, acc_scratch,
            q_start, k_start,
            block_q=block_q, block_kv=block_kv, kv_len=kv_len,
            causal=causal, window=window, softcap=softcap, scale=scale,
        )

    @pl.when(j == nj - 1)
    def _fin():
        _finalize(o_ref, lse_ref, m_scratch, l_scratch, acc_scratch)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    pad = (-x.shape[axis]) % multiple
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention_fwd(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, K, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    pruned: bool = True,
    interpret: bool = False,
    return_lse: bool = False,
):
    """Forward pass.  With `return_lse=True` also returns the per-row
    softmax statistics `lse = m + log(l)` (B, H, S) fp32 — the residual the
    fused backward (`flash_attention_bwd`) recomputes probabilities from."""
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)

    # Ragged shapes: zero-pad to block multiples; `kp < kv_len` masks the
    # padded KV and the padded q rows are sliced off below.
    q = _pad_to(q, 2, block_q)
    k = _pad_to(k, 2, block_kv)
    v = _pad_to(v, 2, block_kv)
    Sp, Tp = q.shape[2], k.shape[2]
    nq, nk = Sp // block_q, Tp // block_kv

    use_pruned = pruned and causal
    if use_pruned:
        kv_steps = kv_steps_for(S, Tp, block_q, block_kv, causal, window)
        grid = (B, H, nq, kv_steps)
        kernel = functools.partial(
            _flash_kernel_pruned,
            block_q=block_q, block_kv=block_kv, kv_len=T, nk=nk,
            causal=causal, window=window, softcap=softcap,
            scale=1.0 / np.sqrt(D), emit_lse=return_lse,
        )

        def kv_index(b, h, iq, j):
            lo = _kv_lo(iq, block_q, block_kv, window)
            hi = _kv_hi(iq, block_q, block_kv, nk)
            return (b, h // G, jnp.minimum(lo + j, hi - 1), 0)
    else:
        grid = (B, H, nq, nk)
        kernel = functools.partial(
            _flash_kernel_dense,
            block_q=block_q, block_kv=block_kv, kv_len=T,
            causal=causal, window=window, softcap=softcap,
            scale=1.0 / np.sqrt(D), emit_lse=return_lse,
        )

        def kv_index(b, h, iq, ik):
            return (b, h // G, ik, 0)

    out_specs = [
        pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, j: (b, h, iq, 0)),
    ]
    out_shape = [jax.ShapeDtypeStruct((B, H, Sp, D), q.dtype)]
    if return_lse:  # inference-only calls skip the lse compute + HBM write
        out_specs.append(
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, j: (b, h, iq))
        )
        out_shape.append(jax.ShapeDtypeStruct((B, H, Sp), jnp.float32))
    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, j: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D), kv_index),
            pl.BlockSpec((1, 1, block_kv, D), kv_index),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if return_lse:
        out, lse = res
        return out[:, :, :S, :], lse[:, :, :S]
    (out,) = res
    return out[:, :, :S, :]


# ---------------------------------------------------------------------------
# Fused backward (two-pass flash recipe, pruned in both directions)
# ---------------------------------------------------------------------------


def _bwd_p_ds(
    q, k, v, do, lse, delta, q_start, k_start, *,
    block_q: int, block_kv: int, kv_len: int,
    causal: bool, window: int | None, softcap: float | None, scale: float,
):
    """Recompute the probability tile from saved stats and form dS.

    Returns (p, ds) fp32 (bq, bk) tiles for the (q_start, k_start) pair:
    p = exp(s - lse) restricted to the mask, ds = p * (dP - delta) pushed
    back through the optional softcap.  All operands are fp32.
    """
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = kp < kv_len
    if causal:
        mask = jnp.logical_and(mask, kp <= qp)
        if window is not None:
            mask = jnp.logical_and(mask, kp > qp - window)
    maskf = mask.astype(jnp.float32)
    # mask s *before* subtracting lse: fully-masked rows have lse ~ NEG_INF
    # and exp(NEG_INF - NEG_INF) = 1 is finite (then zeroed by the mask),
    # whereas exp(real - NEG_INF) would overflow to inf * 0 = nan.
    s_masked = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s_masked - lse[:, None]) * maskf

    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta[:, None])
    if softcap is not None:  # d/dx [c*tanh(x/c)] = 1 - tanh^2 = 1 - (s/c)^2
        ds = ds * (1.0 - jnp.square(s / softcap))
    return p, ds


def _flash_bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
    dq_ref,
    dq_acc,
    *,
    block_q: int, block_kv: int, kv_len: int, nk: int,
    causal: bool, window: int | None, softcap: float | None, scale: float,
    pruned: bool,
):
    """dq pass: grid (B, H, nq, kv_steps) — the forward's pruned KV
    iteration, accumulating dq for one q block in fp32 scratch."""
    iq = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    if pruned and causal:
        lo = _kv_lo(iq, block_q, block_kv, window)
        hi = _kv_hi(iq, block_q, block_kv, nk)
        ik = jnp.minimum(lo + j, hi - 1)
        live = j < hi - lo
    else:
        ik = j
        live = jnp.asarray(True)
        if causal:  # dense path still skips MXU work for dead blocks
            live = jnp.asarray(j * block_kv <= iq * block_q + block_q - 1)
            if window is not None:
                live = jnp.logical_and(
                    live, j * block_kv + block_kv - 1 > iq * block_q - window
                )

    q_start = iq * block_q
    k_start = ik * block_kv

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        _, ds = _bwd_p_ds(
            q, k, v, do, lse, delta, q_start, k_start,
            block_q=block_q, block_kv=block_kv, kv_len=kv_len,
            causal=causal, window=window, softcap=softcap, scale=scale,
        )
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale

    @pl.when(j == nj - 1)
    def _fin():
        dq_ref[0, 0, :, :] = dq_acc[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
    dk_ref, dv_ref,
    dk_acc, dv_acc,
    *,
    block_q: int, block_kv: int, kv_len: int, nq: int, q_steps: int,
    causal: bool, window: int | None, softcap: float | None, scale: float,
    pruned: bool,
):
    """dk/dv pass: grid (B, K, nk, group*q_steps) — the *transposed* pruned
    iteration, walking reachable Q blocks per KV block with the GQA group
    folded into the innermost dimension (j = g*q_steps + jq).  The fp32
    accumulators persist across the whole group loop, so dk/dv come out
    *group-summed* — one (block_kv, D) pair per true KV head, an O(S·K·D)
    HBM write instead of the per-q-head O(S·H·D) transient."""
    ik = pl.program_id(2)
    j = pl.program_id(3)
    nj = pl.num_programs(3)
    jq = j % q_steps  # position inside this group member's Q walk

    @pl.when(j == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    if pruned and causal:
        lo = _q_lo(ik, block_q, block_kv, nq)
        hi = _q_hi(ik, block_q, block_kv, nq, kv_len, window)
        iq = jnp.minimum(lo + jq, jnp.maximum(hi - 1, lo))
        live = jq < hi - lo
    else:
        iq = jq
        live = jnp.asarray(True)
        if causal:
            live = jnp.asarray(ik * block_kv <= jq * block_q + block_q - 1)
            if window is not None:
                live = jnp.logical_and(
                    live, ik * block_kv + block_kv - 1 > jq * block_q - window
                )

    q_start = iq * block_q
    k_start = ik * block_kv

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        p, ds = _bwd_p_ds(
            q, k, v, do, lse, delta, q_start, k_start,
            block_q=block_q, block_kv=block_kv, kv_len=kv_len,
            causal=causal, window=window, softcap=softcap, scale=scale,
        )
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale

    @pl.when(j == nj - 1)
    def _fin():
        dk_ref[0, 0, :, :] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0, :, :] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(
    q: jax.Array,    # (B, H, S, D)
    k: jax.Array,    # (B, K, T, D)
    v: jax.Array,
    out: jax.Array,  # (B, H, S, D) forward output
    lse: jax.Array,  # (B, H, S) fp32 forward softmax stats
    do: jax.Array,   # (B, H, S, D) output cotangent
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    pruned: bool = True,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused Pallas backward: returns (dq, dk, dv) in kernel layout.

    Two passes over the same pruned schedule machinery as the forward: the
    dq grid iterates [kv_lo, kv_hi) per q block, the dk/dv grid iterates the
    transposed [q_lo, q_hi) per kv block with the GQA group folded into the
    innermost dimension.  `delta = rowsum(dO·O)` is precomputed here (cheap
    XLA elementwise+reduce).  K/V are never replicated for GQA in either
    direction: the forward/dq index_map maps h // group, and the dk/dv pass
    accumulates *group-locally* — grid over the K true KV heads, inner loop
    over the group — so its HBM write is the final fp32 (B, K, T, D)
    gradient pair, O(S·K·D), never a per-q-head O(S·H·D) transient.
    """
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)

    # Ragged shapes: zero-pad like the forward.  Padded q rows have dO = 0,
    # so delta = 0 and every padded contribution to dq/dk/dv vanishes; the
    # `kp < kv_len` mask keeps padded KV out of every tile.
    q = _pad_to(q, 2, block_q)
    out = _pad_to(out, 2, block_q)
    do = _pad_to(do, 2, block_q)
    lse = _pad_to(lse, 2, block_q)
    k = _pad_to(k, 2, block_kv)
    v = _pad_to(v, 2, block_kv)
    Sp, Tp = q.shape[2], k.shape[2]
    nq, nk = Sp // block_q, Tp // block_kv

    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    scale = 1.0 / np.sqrt(D)
    use_pruned = pruned and causal

    # -- dq pass: per q block, iterate (pruned) KV blocks ---------------------
    kv_steps = (
        kv_steps_for(S, Tp, block_q, block_kv, causal, window)
        if use_pruned else nk
    )

    def kv_index(b, h, iq, j):
        if use_pruned:
            lo = _kv_lo(iq, block_q, block_kv, window)
            hi = _kv_hi(iq, block_q, block_kv, nk)
            j = jnp.minimum(lo + j, hi - 1)
        return (b, h // G, j, 0)

    def q_row(b, h, iq, j):
        return (b, h, iq, 0)

    def q_stat(b, h, iq, j):
        return (b, h, iq)

    dq_kernel = functools.partial(
        _flash_bwd_dq_kernel,
        block_q=block_q, block_kv=block_kv, kv_len=T, nk=nk,
        causal=causal, window=window, softcap=softcap, scale=scale,
        pruned=use_pruned,
    )
    dq = pl.pallas_call(
        dq_kernel,
        grid=(B, H, nq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), q_row),
            pl.BlockSpec((1, 1, block_kv, D), kv_index),
            pl.BlockSpec((1, 1, block_kv, D), kv_index),
            pl.BlockSpec((1, 1, block_q, D), q_row),
            pl.BlockSpec((1, 1, block_q), q_stat),
            pl.BlockSpec((1, 1, block_q), q_stat),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), q_row),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # -- dk/dv pass: per KV head x KV block, loop the GQA group over the
    # (pruned) Q blocks — group-local accumulation, so the HBM write is the
    # true (B, K, T, D) gradient, never a per-q-head transient ------------------
    q_steps = (
        q_steps_for(S, T, block_q, block_kv, causal, window)
        if use_pruned else nq
    )

    def q_index(b, kh, ik, j):
        h = kh * G + j // q_steps  # group member this step serves
        jq = j % q_steps
        if use_pruned:
            lo = _q_lo(ik, block_q, block_kv, nq)
            hi = _q_hi(ik, block_q, block_kv, nq, T, window)
            jq = jnp.minimum(lo + jq, jnp.maximum(hi - 1, lo))
        return (b, h, jq, 0)

    def q_stat_t(b, kh, ik, j):
        return q_index(b, kh, ik, j)[:3]

    def kv_row(b, kh, ik, j):
        return (b, kh, ik, 0)

    dkv_kernel = functools.partial(
        _flash_bwd_dkv_kernel,
        block_q=block_q, block_kv=block_kv, kv_len=T, nq=nq, q_steps=q_steps,
        causal=causal, window=window, softcap=softcap, scale=scale,
        pruned=use_pruned,
    )
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(B, K, nk, G * q_steps),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), q_index),
            pl.BlockSpec((1, 1, block_kv, D), kv_row),
            pl.BlockSpec((1, 1, block_kv, D), kv_row),
            pl.BlockSpec((1, 1, block_q, D), q_index),
            pl.BlockSpec((1, 1, block_q), q_stat_t),
            pl.BlockSpec((1, 1, block_q), q_stat_t),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_kv, D), lambda b, kh, ik, j: (b, kh, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, kh, ik, j: (b, kh, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K, Tp, D), jnp.float32),
            jax.ShapeDtypeStruct((B, K, Tp, D), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_kv, D), jnp.float32),
            pltpu.VMEM((block_kv, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dq = dq[:, :, :S]
    dk = dk[:, :, :T].astype(k.dtype)
    dv = dv[:, :, :T].astype(v.dtype)
    return dq, dk, dv


def vmem_bytes(
    block_q: int,
    block_kv: int,
    head_dim: int,
    dtype_bytes: int = 2,
    *,
    kv_dtype_bytes: int | None = None,
) -> int:
    """Analytic VMEM working set — the autotuner's capacity constraint.

    Counts the pipelined Q/O blocks at the Q dtype and the K *and* V blocks
    at the KV dtype (they may differ, e.g. bf16 Q against int8 KV cache),
    double-buffered as Pallas pipelines them, plus the fp32 scratch
    (acc + m + l) and the fp32 score tile.
    """
    if kv_dtype_bytes is None:
        kv_dtype_bytes = dtype_bytes
    qo = 2 * block_q * head_dim * dtype_bytes  # q in + o out
    kv = 2 * block_kv * head_dim * kv_dtype_bytes  # k + v
    scratch = (block_q * (head_dim + 2)) * 4  # fp32 acc + m + l
    scores = block_q * block_kv * 4  # fp32 s/p tile
    return 2 * (qo + kv) + scratch + scores  # x2: double-buffered I/O blocks


def vmem_bytes_bwd(
    block_q: int,
    block_kv: int,
    head_dim: int,
    dtype_bytes: int = 2,
    *,
    kv_dtype_bytes: int | None = None,
) -> int:
    """Analytic VMEM working set of the fused backward — the autotuner's
    capacity constraint for the `block_q_bwd`/`block_kv_bwd` knobs.

    Models the larger of the two passes.  Both stream q + dO (Q dtype) and
    K + V (KV dtype) plus the fp32 lse/delta row stats; the dq pass adds the
    dq output block and an fp32 (bq, D) accumulator, the dk/dv pass adds two
    fp32 output blocks and two (bkv, D) accumulators.  Each pass recomputes
    three fp32 (bq, bkv) tiles (s/p, dP, dS).  I/O blocks are counted
    double-buffered as Pallas pipelines them.
    """
    if kv_dtype_bytes is None:
        kv_dtype_bytes = dtype_bytes
    q_in = 2 * block_q * head_dim * dtype_bytes       # q + dO
    kv_in = 2 * block_kv * head_dim * kv_dtype_bytes  # k + v
    stats = 2 * block_q * 4                           # fp32 lse + delta
    tiles = 3 * block_q * block_kv * 4                # fp32 s/p, dP, dS
    dq_pass = (
        2 * (q_in + kv_in + stats + block_q * head_dim * dtype_bytes)
        + block_q * head_dim * 4 + tiles
    )
    dkv_pass = (
        2 * (q_in + kv_in + stats + 2 * block_kv * head_dim * 4)
        + 2 * block_kv * head_dim * 4 + tiles
    )
    return max(dq_pass, dkv_pass)
