"""Pallas TPU flash attention (forward): online-softmax over KV blocks.

TPU mapping (DESIGN.md: adapt, don't port): the grid is
(batch, q_heads, num_q_blocks, num_kv_blocks) with the KV dimension
*innermost* — TPU grid steps on one core execute sequentially, so the fp32
running max / denominator / accumulator live in VMEM scratch and persist
across KV-block iterations (the TPU analogue of a CUDA thread-block's
shared-memory state).  Block shapes are BlockSpec-tiled so each step's
working set is (block_q x D) + 2 x (block_kv x D) + (block_q x block_kv)
fp32 in VMEM, with block sizes kept at MXU-friendly multiples of 128.

GQA is handled in the K/V index_map (kv_head = q_head // group), so no KV
replication is ever materialized in HBM.  Causal and sliding-window masks
are applied in-kernel; KV blocks that are fully masked for this q block
skip their MXU work via pl.when (they still stream K/V in — the block-
sparse grid-pruning variant is a recorded §Perf follow-up).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # VMEM blocks
    o_ref,
    m_scratch, l_scratch, acc_scratch,
    *,
    block_q: int,
    block_kv: int,
    kv_len: int,
    causal: bool,
    window: int | None,
    softcap: float | None,
    scale: float,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q_start = iq * block_q
    k_start = ik * block_kv

    # Block-level reachability: skip the MXU work for fully-masked KV blocks.
    reachable = jnp.asarray(True)
    if causal:
        reachable = jnp.asarray(k_start <= q_start + block_q - 1)
        if window is not None:
            reachable = jnp.logical_and(
                reachable, k_start + block_kv - 1 > q_start - window
            )

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap

        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = kp < kv_len
        if causal:
            mask = jnp.logical_and(mask, kp <= qp)
            if window is not None:
                mask = jnp.logical_and(mask, kp > qp - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]  # (bq, 1)
        l_prev = l_scratch[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scratch[...] = m_new
        l_scratch[...] = l_new
        acc_scratch[...] = acc

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scratch[...]
        out = acc_scratch[...] / jnp.maximum(l, 1e-30)
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, K, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, S, D = q.shape
    K, T = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    block_q = min(block_q, S)
    block_kv = min(block_kv, T)
    assert S % block_q == 0 and T % block_kv == 0, (S, T, block_q, block_kv)
    grid = (B, H, S // block_q, T // block_kv)

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_kv=block_kv,
        kv_len=T,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=1.0 / np.sqrt(D),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def vmem_bytes(block_q: int, block_kv: int, head_dim: int, dtype_bytes: int = 2) -> int:
    """Analytic VMEM working set (used by benchmarks/kernels.py)."""
    blocks = (block_q + 2 * block_kv) * head_dim * dtype_bytes  # q + k + v
    scratch = (block_q * (head_dim + 2)) * 4  # fp32 acc + m + l
    scores = block_q * block_kv * 4  # fp32 s/p tile
    return blocks + scratch + scores
