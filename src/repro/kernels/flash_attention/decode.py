"""Pallas TPU flash *decode* attention: one new q token against a KV cache.

The serving hot path.  Prefill/training attention is O(S·W) since the grid
pruning landed, but decode used to run XLA attention over the *entire*
padded cache every token — O(max_len) HBM traffic per step.  This kernel
streams only the *live* cache blocks:

  - grid (batch, kv_heads, kv_steps) with kv_steps = ceil(T / block_kv)
    *static*; the per-request `index` (number of tokens already cached,
    i.e. the new token's absolute position) rides in as a scalar-prefetch
    operand, so the K/V BlockSpec index_map can clamp the streamed block to
    the live interval [lo(index), hi(index)) — steps past the interval
    repeat the previous block index and Pallas elides the DMA, exactly the
    clamp-and-elide walk of the prefill kernel's pruned path.

  - ring caches (slot = pos % W, cache length T == window W): slots
    0..min(index, W-1) are filled and — once the cache has wrapped — every
    slot holds a position inside the window, so liveness is just
    `slot < min(T, index+1)`; the kernel reads exactly
    ceil(min(W, index+1) / block_kv) blocks using the ring `pos`/`index`
    layout, with no gather or rotation of the cache in HBM.

  - linear caches (slot s = absolute position s, T == max_len): blocks
    beyond `index` are pruned the same way, and a sliding window (the
    window >= prefill-length case where `_build_cache` stays linear) also
    prunes blocks *below* the window through the same interval machinery.

  - GQA folds the q-head group into the q block: one kernel instance per KV
    head with a (group, D) q tile, so K/V are never replicated in HBM and
    the single-token MXU op is a (group x block_kv) matmul.  Softcap and
    fp32 online-softmax accumulation match `xla_attention`.

`decode_schedule` mirrors the index remapping in pure numpy so tests and
benches can assert exactly which blocks one decode step streams;
`vmem_bytes_dec` is the analytic VMEM working set used as the autotuner's
capacity constraint for the `block_kv_dec` knob (see
repro.autotune.kernel_tuner).

Paged caches (the vLLM block-table layout): passing `tables` switches the
K/V operands from per-request dense caches (B, K, T, D) to one shared pool
of fixed-size pages (P, K, page_size, D) plus a per-request block table
(B, num_blocks) mapping logical cache block -> physical page.  The kernel
body is *unchanged* — all mask/softmax math stays in logical slot space —
and the indirection lives entirely in the K/V BlockSpec index_map, which
resolves the clamped logical block through the scalar-prefetched table:

    jb   = min(lo + j, hi - 1)                # same clamp-and-elide walk
    page = tables[b, jb // (page_size // block_kv)]
    sub  = jb % (page_size // block_kv)       # sub-block within the page

so the O(min(W, index+1)) live-block bound per token carries over verbatim,
and requests of wildly different lengths share one HBM pool instead of each
padding to max_len.  `block_kv` is clamped to a divisor of `page_size`
(`page_block_kv`) so a streamed block never straddles a page boundary.

Widened q (`q_span` > 1, the `q_offset` variant): the q tile grows from one
token's folded group (G, D) to a draft block's (q_span * G, D) — row
r = s*G + g is draft token s, head-group lane g, and the causal boundary
becomes *per-row*: token s attends through cache position index + s, where
`index` is the position of the *first* new token.  Everything else —
clamp-and-elide walk (hi now covers index + q_span - 1), online softmax
(rows are independent), the paged table indirection — is unchanged, so one
verify step over a k-token draft streams the cache once instead of k times.
The same shape with index = prefix_len and q_span = suffix length is
suffix-over-prefix chunked prefill, which is how the paged prefix-sharing
path runs through Pallas.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention.kernel import NEG_INF, cdiv


# ---------------------------------------------------------------------------
# Live-block interval + numpy oracle
# ---------------------------------------------------------------------------


def _dec_hi(index, block_kv: int, T: int):
    """One past the last live KV block: the block holding min(T, index+1)-1.

    Works on python ints and traced scalars (index_map arithmetic).
    """
    live = index + 1
    if isinstance(live, int):
        return cdiv(max(1, min(T, live)), block_kv)
    live = jnp.clip(live, 1, T)
    return (live + block_kv - 1) // block_kv


def _dec_lo(index, block_kv: int, window: int | None, hi):
    """First live KV block (linear caches only: positions below the sliding
    window are dead).  Ring caches pass window=None — the ring layout holds
    only in-window positions by construction."""
    if window is None:
        return hi * 0  # 0, but keeps tracer dtype when hi is traced
    lo = (index + 1 - window) // block_kv
    if isinstance(lo, int):
        return min(max(0, lo), hi - 1)
    return jnp.clip(lo, 0, hi - 1)


def decode_steps_for(T: int, block_kv: int, window: int | None = None,
                     q_span: int = 1) -> int:
    """Max live KV blocks one decode step can stream, over all indices.

    Without a window that is the full cache; with one, the in-window slots
    of `q_span` stacked tokens span window + q_span - 1 positions, i.e. at
    most ceil((W + q_span - 2)/block_kv) + 1 blocks (worst case: the span
    straddles block edges on both sides)."""
    nk = cdiv(T, block_kv)
    if window is None:
        return nk
    span = window + q_span - 1
    return max(1, min(nk, cdiv(max(span - 1, 1), block_kv) + 1))


def decode_schedule(
    T: int, index: int, block_kv: int, *,
    window: int | None = None, pruned: bool = True, q_span: int = 1,
) -> list[int]:
    """KV blocks one decode step actually *streams* from a length-T cache.

    Mirrors the kernel's clamp-and-elide index remapping: the pruned path
    walks [lo, hi) and overshoot steps repeat the last block (no DMA).  For
    ring caches (T == window, window=None here) this is exactly
    range(ceil(min(T, index+1) / block_kv)); the dense path streams every
    block.  With `q_span` > 1 the interval widens to cover the *last*
    stacked token (position index + q_span - 1) while lo stays anchored on
    the first — one widened step streams the union of the per-token
    intervals.
    """
    nk = cdiv(T, block_kv)
    if not pruned:
        return list(range(nk))
    hi = _dec_hi(int(index) + q_span - 1, block_kv, T)
    lo = _dec_lo(int(index), block_kv, window, hi)
    return list(range(int(lo), int(hi)))


def page_block_kv(block_kv: int, page_size: int) -> int:
    """Clamp a streamed-block size so it tiles the page exactly.

    A K/V DMA must never straddle a page boundary (adjacent logical pages
    are not adjacent in the pool), so the effective block is the largest
    common divisor — for the power-of-two knob spaces this is simply
    min(block_kv, page_size)."""
    return max(1, math.gcd(int(block_kv), int(page_size)))


def paged_decode_schedule(
    kv_len: int, index: int, block_kv: int, page_size: int, table,
    *, window: int | None = None, pruned: bool = True, q_span: int = 1,
) -> list[tuple[int, int]]:
    """Physical (page, sub_block) pairs one decode step streams from the
    pool — `decode_schedule` mapped through the request's block table.

    `table` is the request's row: table[i] = physical page of logical page
    i.  Tests and benches use this to assert that exactly the pages backing
    the live logical blocks are touched, in logical order."""
    bkv = page_block_kv(block_kv, page_size)
    spb = page_size // bkv
    logical = decode_schedule(kv_len, index, bkv, window=window, pruned=pruned,
                              q_span=q_span)
    return [(int(table[jb // spb]), jb % spb) for jb in logical]


# ---------------------------------------------------------------------------
# Kernel body
# ---------------------------------------------------------------------------


def _flash_decode_kernel(
    idx_ref,  # scalar prefetch: (B,) int32, per-request index (first token)
    q_ref,    # (1, 1, Rp, D) — q_span tokens' folded groups, row r = s*G + g
    k_ref,    # (1, 1, block_kv, D)
    v_ref,
    *rest,    # [ks_ref, vs_ref,] o_ref, m/l/acc scratch — scale refs only
              # when `quantized` (a (1, 1) block of the fp32 per-page-per-
              # head sidecar: one scalar scale covering this K/V block)
    block_kv: int,
    kv_len: int,   # true cache length T (padding slots >= T are masked)
    window: int | None,
    softcap: float | None,
    scale: float,
    pruned: bool,
    group: int = 1,   # q rows per token (GQA fold); row // group = token off
    q_span: int = 1,  # stacked q tokens; token s sits at position index + s
    quantized: bool = False,
):
    if quantized:
        ks_ref, vs_ref, o_ref, m_scratch, l_scratch, acc_scratch = rest
    else:
        o_ref, m_scratch, l_scratch, acc_scratch = rest
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    index = idx_ref[b]
    hi = _dec_hi(index + q_span - 1, block_kv, kv_len)
    lo = _dec_lo(index, block_kv, window, hi)
    if pruned:
        # the index_map streamed block min(lo+j, hi-1); overshoot steps
        # repeat the last block (no DMA) and skip all compute
        ik = jnp.minimum(lo + j, hi - 1)
        live_step = j < hi - lo
    else:
        # dense baseline: block j streamed; dead blocks still skip the MXU
        ik = j
        live_step = jnp.logical_and(j >= lo, j < hi)
    k_start = ik * block_kv

    @pl.when(live_step)
    def _compute():
        g = q_ref[0, 0].astype(jnp.float32)   # (Gp, D)
        k = k_ref[0, 0].astype(jnp.float32)   # (bkv, D)
        v = v_ref[0, 0].astype(jnp.float32)
        if quantized:
            # dequantize in-loop at the block's page scale; the block never
            # straddles a page (block_kv | page boundary), so one scalar
            # covers the whole tile.  Paged sidecars are (P, K) -> 2-d refs,
            # dense ones (B, K, NP) -> 3-d refs.
            if ks_ref.ndim == 2:
                k = k * ks_ref[0, 0]
                v = v * vs_ref[0, 0]
            else:
                k = k * ks_ref[0, 0, 0]
                v = v * vs_ref[0, 0, 0]
        s = jax.lax.dot_general(
            g, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (Gp, bkv)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap

        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        # per-row causal boundary: q row r is draft token r // group, which
        # sits at position index + r // group and attends slots <= it
        off = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        live = jnp.clip(index + off + 1, 1, kv_len)
        mask = kp < live  # ring: filled slots; linear: causal slots <= pos
        if window is not None:  # linear cache under a sliding window
            mask = jnp.logical_and(mask, kp > index + off - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]
        l_prev = l_scratch[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
        alpha = jnp.exp(m_prev - m_new)
        m_scratch[...] = m_new
        l_scratch[...] = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(j == nj - 1)
    def _fin():
        out = acc_scratch[...] / jnp.maximum(l_scratch[...], 1e-30)
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


def _flash_decode_kernel_paged(idx_ref, tbl_ref, *refs, **kw):
    """Paged variant: the block table rides in as a second scalar-prefetch
    operand consumed *only* by the K/V index_map — every mask / softmax op
    happens in logical slot space, so the body is the dense kernel."""
    del tbl_ref
    _flash_decode_kernel(idx_ref, *refs, **kw)


# ---------------------------------------------------------------------------
# Entry point (kernel layout)
# ---------------------------------------------------------------------------


def flash_decode_fwd(
    q: jax.Array,      # (B, K, q_span * G, D) — row r = token r//G, lane r%G
    k: jax.Array,      # (B, K, T, D) cache — or (P, K, page_size, D) pool
    v: jax.Array,
    index: jax.Array,  # (B,) int32: *first* new token's position
    *,
    window: int | None = None,  # linear caches only; ring passes None
    softcap: float | None = None,
    block_kv: int = 512,
    pruned: bool = True,
    interpret: bool = False,
    tables: jax.Array | None = None,  # (B, num_blocks) int32 page table
    kv_len: int | None = None,        # logical cache length (paged only)
    q_span: int = 1,   # stacked q tokens (draft block / q_offset suffix)
    k_scale: jax.Array | None = None,  # fp32 per-page-per-head dequant scales:
    v_scale: jax.Array | None = None,  # paged (P, K); dense (B, K, NP)
    scale_page: int | None = None,     # dense only: cache slots per scale row
) -> jax.Array:
    """One decode step.  Streams ceil((hi-lo)) live KV blocks per (b, kv
    head); with `pruned=False` every block streams (the dense baseline).

    With `tables`, K/V are one shared page pool (P, K, page_size, D) and
    each request's logical blocks resolve through its block-table row; the
    logical cache length must then come in as `kv_len` (the pool carries no
    per-request extent).

    With `q_span` > 1 the q operand stacks q_span tokens' folded groups
    (rows ordered token-major: row r = token r // G), `index` is the first
    token's position, and token s attends through slot index + s — the
    widened-q / q_offset variant used by speculative verify and by
    suffix-over-prefix paged prefill.

    With `k_scale`/`v_scale`, K/V hold quantized values (int8/fp8) and the
    kernel dequantizes each streamed block at its page's fp32 scale —
    scales ride as an extra (1, 1)-blocked operand resolved by the same
    (table-indirected) index_map, and the fp32 online-softmax accumulation
    is untouched.  Paged sidecars are (P, K); for dense caches pass
    (B, NP, K)-shaped scales pre-swapped to kernel layout (B, K, NP) with
    `scale_page` slots per scale row (block_kv is clamped to divide it)."""
    B, K, R, D = q.shape
    if R % q_span:
        raise ValueError(f"q rows {R} not divisible by q_span={q_span}")
    G = R // q_span
    quantized = k_scale is not None
    if quantized and v_scale is None:
        raise ValueError("quantized flash_decode requires both k/v scales")
    paged = tables is not None
    if paged:
        if kv_len is None:
            raise ValueError("paged flash_decode requires kv_len")
        T = int(kv_len)
        page_size = k.shape[2]
        # No clamp to T here: pool pages are always full page_size slots
        # (the kp < live mask covers short caches), and min()-ing first
        # would collapse the gcd to slivers for non-power-of-two kv_len.
        block_kv = page_block_kv(block_kv, page_size)
        spb = page_size // block_kv
        tables = jnp.asarray(tables, jnp.int32)
        if tables.shape[0] != B or tables.shape[1] * page_size < T:
            raise ValueError(
                f"block table {tables.shape} cannot cover kv_len={T} at "
                f"page_size={page_size} for batch {B}")
    else:
        T = k.shape[2]
        block_kv = min(block_kv, max(T, 1))
        if quantized:
            if scale_page is None:
                raise ValueError("dense quantized flash_decode requires "
                                 "scale_page (cache slots per scale row)")
            # a streamed block must sit under a single scale row
            block_kv = page_block_kv(block_kv, scale_page)

    # TPU sublane tiling wants >= 8 q rows; pad the folded rows (the padded
    # rows compute garbage that is sliced off — rows are softmax-independent).
    Rp = max(8, R) if not interpret else R
    if Rp != R:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Rp - R), (0, 0)))

    if not paged:
        # Ragged cache length: zero-pad KV to a block multiple; `kp < live`
        # masks the padded slots (live <= T always).  Pools need no padding:
        # block_kv divides page_size by construction.
        pad = (-T) % block_kv
        if pad:
            widths = ((0, 0), (0, 0), (0, pad), (0, 0))
            k, v = jnp.pad(k, widths), jnp.pad(v, widths)
    nk = cdiv(T, block_kv)

    index = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))

    # Static grid pruning on top of the dynamic clamp: no index can reach
    # more than decode_steps_for blocks (ceil((W-1)/bkv)+1 under a window),
    # so the grid itself shrinks — the same trick as the prefill kernel's
    # kv_steps_for.  The per-index interval [lo, hi) then elides within it.
    steps = decode_steps_for(T, block_kv, window, q_span) if pruned else nk

    def logical_block(b, j, idx_ref):
        if pruned:
            hi = _dec_hi(idx_ref[b] + q_span - 1, block_kv, T)
            lo = _dec_lo(idx_ref[b], block_kv, window, hi)
            return jnp.minimum(lo + j, hi - 1)
        return j

    if paged:
        def kv_index(b, h, j, idx_ref, tbl_ref):
            jb = logical_block(b, j, idx_ref)
            return (tbl_ref[b, jb // spb], h, jb % spb, 0)

        def qo_index(b, h, j, idx_ref, tbl_ref):
            return (b, h, 0, 0)

        def sc_index(b, h, j, idx_ref, tbl_ref):
            # same table indirection as kv_index, at page granularity
            jb = logical_block(b, j, idx_ref)
            return (tbl_ref[b, jb // spb], h)

        scale_block = (1, 1)
        kernel_fn = _flash_decode_kernel_paged
        num_prefetch = 2
        operands = (index, tables, q, k, v)
    else:
        def kv_index(b, h, j, idx_ref):
            return (b, h, logical_block(b, j, idx_ref), 0)

        def qo_index(b, h, j, idx_ref):
            return (b, h, 0, 0)

        def sc_index(b, h, j, idx_ref):
            jb = logical_block(b, j, idx_ref)
            return (b, h, (jb * block_kv) // scale_page)

        scale_block = (1, 1, 1)
        kernel_fn = _flash_decode_kernel
        num_prefetch = 1
        operands = (index, q, k, v)

    in_specs = [
        pl.BlockSpec((1, 1, Rp, D), qo_index),
        pl.BlockSpec((1, 1, block_kv, D), kv_index),
        pl.BlockSpec((1, 1, block_kv, D), kv_index),
    ]
    if quantized:
        in_specs += [pl.BlockSpec(scale_block, sc_index)] * 2
        operands = operands + (jnp.asarray(k_scale, jnp.float32),
                               jnp.asarray(v_scale, jnp.float32))

    kernel = functools.partial(
        kernel_fn,
        block_kv=block_kv, kv_len=T, window=window,
        softcap=softcap, scale=1.0 / np.sqrt(D), pruned=pruned,
        group=G, q_span=q_span, quantized=quantized,
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_prefetch,
        grid=(B, K, steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, Rp, D), qo_index),
        scratch_shapes=[
            pltpu.VMEM((Rp, 1), jnp.float32),
            pltpu.VMEM((Rp, 1), jnp.float32),
            pltpu.VMEM((Rp, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, Rp, D), q.dtype),
        interpret=interpret,
    )(*operands)
    return out[:, :, :R, :]


def vmem_bytes_dec(
    group: int,
    block_kv: int,
    head_dim: int,
    dtype_bytes: int = 2,
    *,
    kv_dtype_bytes: int | None = None,
    q_span: int = 1,
) -> int:
    """Analytic VMEM working set of one decode step — the autotuner's
    capacity constraint for the `block_kv_dec` knob.

    The q/o tiles are (max(8, q_span·group) x D) at the Q dtype, K and V
    blocks at the KV dtype, double-buffered as Pallas pipelines them, plus
    the fp32 scratch (acc + m + l) and the fp32 (rows x block_kv) score
    tile.  The per-request index scalars are noise (4·B bytes in SMEM).
    """
    if kv_dtype_bytes is None:
        kv_dtype_bytes = dtype_bytes
    g = max(8, group * q_span)
    qo = 2 * g * head_dim * dtype_bytes                # q in + o out
    kv = 2 * block_kv * head_dim * kv_dtype_bytes      # k + v
    scratch = (g * (head_dim + 2)) * 4                 # fp32 acc + m + l
    scores = g * block_kv * 4                          # fp32 s/p tile
    return 2 * (qo + kv) + scratch + scores
