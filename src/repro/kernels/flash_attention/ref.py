"""Pure-jnp oracle for flash attention (GQA, causal/sliding-window, softcap)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, T, K, D)
    v: jax.Array,  # (B, T, K, D)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, S, K, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32)) / np.sqrt(D)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)
