"""PowerCapper (paper §2.7): application-aware power capping with per-task
priorities.

Unlike RAPL (application-agnostic, uniform throttling), the capper allocates
the node budget by priority: when over budget it throttles the *lowest*
priority tasks first; when under budget it restores the *highest* first.
A deadband avoids oscillation.  `agnostic=True` reproduces the RAPL
baseline (uniform scaling) for the comparison experiment.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading

from repro.power.rapl import RAPLModel


@dataclasses.dataclass
class _Task:
    task_id: int
    name: str
    priority: int
    freq: float = 1.0
    power: float = 0.0


class PowerCapper:
    def __init__(self, cap_watts: float, *, model: RAPLModel | None = None,
                 step: float = 0.05, deadband: float = 0.02, agnostic: bool = False):
        self.cap_watts = cap_watts
        self.model = model or RAPLModel()
        self.step = step
        self.deadband = deadband
        self.agnostic = agnostic
        self._tasks: dict[int, _Task] = {}
        self._ids = itertools.count()
        self._lock = threading.Lock()

    # -- API used by the woven wrapper ----------------------------------------

    def register(self, name: str, priority: int) -> int:
        with self._lock:
            tid = next(self._ids)
            self._tasks[tid] = _Task(tid, name, priority)
            return tid

    def frequency(self, task_id: int) -> float:
        with self._lock:
            return self._tasks[task_id].freq

    def report(self, task_id: int, power_watts: float) -> None:
        with self._lock:
            self._tasks[task_id].power = power_watts
            self._control_locked()

    def set_cap(self, cap_watts: float) -> None:
        """Move the node budget at runtime (a QoS governor reconfiguring
        under a new power envelope) and re-run the control step against
        the last reported powers, under the same lock `report` holds."""
        with self._lock:
            self.cap_watts = float(cap_watts)
            self._control_locked()

    # -- control loop ------------------------------------------------------------

    def total_power(self) -> float:
        with self._lock:
            return sum(t.power for t in self._tasks.values())

    def _control_locked(self) -> None:
        tasks = list(self._tasks.values())
        if not tasks:
            return
        total = sum(t.power for t in tasks)
        lo, hi = self.cap_watts * (1 - self.deadband), self.cap_watts * (1 + self.deadband)
        f_min, f_max = self.model.f_min, self.model.f_max
        if total > hi:
            if self.agnostic:
                for t in tasks:
                    t.freq = max(f_min, t.freq - self.step)
            else:
                order = sorted(tasks, key=lambda t: t.priority)  # lowest first
                for t in order:
                    if t.freq > f_min:
                        t.freq = max(f_min, t.freq - self.step)
                        break
                else:
                    for t in order:
                        t.freq = f_min
        elif total < lo:
            if self.agnostic:
                for t in tasks:
                    t.freq = min(f_max, t.freq + self.step)
            else:
                order = sorted(tasks, key=lambda t: -t.priority)  # highest first
                for t in order:
                    if t.freq < f_max:
                        t.freq = min(f_max, t.freq + self.step)
                        break

    def snapshot(self) -> list[dict]:
        """Point-in-time copy of the task table.  Holds the same lock as
        `report`/`_control_locked`/`set_cap`: a snapshot taken during a
        concurrent control step sees either the pre- or post-step
        frequencies, never a half-applied throttle order — the rows are
        deep-copied dicts, so the caller can't race later mutations
        either."""
        with self._lock:
            return [dataclasses.asdict(t) for t in self._tasks.values()]
