"""Simulated RAPL-class power model for TPU v5e-class chips (paper §2.7).

The container is CPU-only, so power is modeled, not measured: per-chip
power = idle + dynamic * utilization * f^3 (classic DVFS cube law), with
performance scaling ~f for compute-bound phases and ~1 for memory/IO-slack
phases — exactly the slack the paper exploits ([28]: RAPL is application-
agnostic and wastes power in IO/memory phases).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class RAPLModel:
    idle_watts: float = 75.0
    dynamic_watts: float = 125.0  # at util=1, f=1
    peak_flops: float = 197e12  # bf16 / chip
    f_min: float = 0.5
    f_max: float = 1.0

    def power(self, utilization: float, freq: float = 1.0) -> float:
        utilization = min(max(utilization, 0.0), 1.0)
        freq = min(max(freq, self.f_min), self.f_max)
        return self.idle_watts + self.dynamic_watts * utilization * freq**3

    def perf_scale(self, freq: float, compute_bound_frac: float = 1.0) -> float:
        """Relative performance at frequency f: compute-bound scales with f,
        memory/IO-bound phases don't (the application-aware opportunity)."""
        freq = min(max(freq, self.f_min), self.f_max)
        return compute_bound_frac * freq + (1.0 - compute_bound_frac)

    def energy(self, utilization: float, freq: float, seconds: float) -> float:
        return self.power(utilization, freq) * seconds
