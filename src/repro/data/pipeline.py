"""Deterministic, shardable, checkpointable token pipeline.

Batches are a pure function of (seed, step, host slice), so
 - any host computes exactly its slice (no coordination),
 - resume-from-checkpoint replays identically (the cursor is one integer),
 - elastic restarts with a different host count re-slice the same stream.

Two sources: "uniform" (throughput testing) and "lcg" (learnable structure:
an affine next-token rule with noise — loss measurably decreases within a
few hundred steps, used by convergence tests and the train_100m example).
A memmap-backed corpus reader covers the real-data path.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mode: str = "lcg"  # lcg | uniform | memmap
    noise: float = 0.05
    memmap_path: str | None = None


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig, *, host_id: int = 0, num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0, (cfg.global_batch, num_hosts)
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.local_batch = cfg.global_batch // num_hosts
        self.step = 0
        self._mm = None
        if cfg.mode == "memmap":
            assert cfg.memmap_path, "memmap mode needs a path"
            self._mm = np.memmap(cfg.memmap_path, dtype=np.int32, mode="r")

    # -- deterministic batch synthesis ---------------------------------------------

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.host_id])
        )

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        B, S, V = self.local_batch, cfg.seq_len, cfg.vocab
        if cfg.mode == "uniform":
            tokens = rng.integers(0, V, size=(B, S + 1), dtype=np.int32)
        elif cfg.mode == "memmap":
            n = len(self._mm) - (S + 1)
            starts = (
                rng.integers(0, max(n, 1), size=(B,))
                if n > 0
                else np.zeros((B,), np.int64)
            )
            tokens = np.stack([np.asarray(self._mm[s : s + S + 1]) for s in starts])
            tokens = tokens.astype(np.int32) % V
        else:  # lcg: x_{t+1} = (a x_t + c) mod V with noise
            a, c = 31, 17
            x0 = rng.integers(0, V, size=(B, 1), dtype=np.int64)
            toks = [x0]
            for _ in range(S):
                toks.append((a * toks[-1] + c) % V)
            tokens = np.concatenate(toks, axis=1).astype(np.int32)
            flip = rng.random((B, S + 1)) < cfg.noise
            noise_tok = rng.integers(0, V, size=(B, S + 1), dtype=np.int32)
            tokens = np.where(flip, noise_tok, tokens)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    # -- iteration / checkpointing --------------------------------------------------

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: dict) -> None:
        assert state["seed"] == self.cfg.seed, "pipeline seed mismatch on restore"
        self.step = int(state["step"])
