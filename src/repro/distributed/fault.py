"""Fault tolerance: watchdog, heartbeats/straggler detection, preemption
handling, and a fleet simulator that exercises the full
fail -> checkpoint-restore -> continue loop (tested; CPU container stands in
for the pod fleet).

Straggler policy (1000+-node posture): every host publishes step heartbeats
to ExaMon (`fleet/heartbeat/@hostN`); a host whose step time exceeds
`factor` x fleet-median for `patience` consecutive steps is flagged and the
mitigation callback fires (on a real fleet: demote to hot spare / re-slice;
in the simulator: replace the worker).
"""

from __future__ import annotations

import dataclasses
import signal
import threading
import time
from collections import defaultdict, deque
from typing import Any, Callable

from repro.monitor.examon import ExamonBroker


# ---------------------------------------------------------------------------
# Watchdog: per-step deadline
# ---------------------------------------------------------------------------


class Watchdog:
    """Per-step deadline on a single reused timer thread.

    `beat()` re-arms one monotonic deadline instead of spawning a fresh
    `threading.Timer` per step (the old shape leaked a thread per beat and
    left a cancel/fire race: a Timer already past `cancel()`'s check could
    still run `_fire` and count a phantom timeout).  Here the expiry test,
    the timeout count and every re-arm/cancel happen under one lock, so a
    beat or cancel that lands before expiry always wins — a late wake-up
    observes the moved/cleared deadline and goes back to waiting.  The
    callback runs outside the lock (it may beat/cancel re-entrantly).
    """

    def __init__(self, deadline_s: float, on_timeout: Callable[[], None]):
        self.deadline_s = deadline_s
        self.on_timeout = on_timeout
        self.timeouts = 0
        self._cond = threading.Condition()
        self._deadline: float | None = None  # monotonic; None = disarmed
        self._closed = False
        self._thread: threading.Thread | None = None

    def beat(self) -> None:
        with self._cond:
            if self._closed:
                raise RuntimeError("watchdog is closed")
            self._deadline = time.monotonic() + self.deadline_s
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name="watchdog", daemon=True)
                self._thread.start()
            self._cond.notify()

    def cancel(self) -> None:
        with self._cond:
            self._deadline = None
            self._cond.notify()

    def close(self) -> None:
        """Disarm and stop the timer thread (idempotent)."""
        with self._cond:
            self._deadline = None
            self._closed = True
            self._cond.notify()
        if self._thread is not None and self._thread is not threading.current_thread():
            self._thread.join(timeout=1.0)

    def _run(self) -> None:
        while True:
            with self._cond:
                if self._closed:
                    return
                if self._deadline is None:
                    self._cond.wait()
                    continue
                wait = self._deadline - time.monotonic()
                if wait > 0:
                    self._cond.wait(wait)
                    continue
                # expired while holding the lock: no beat/cancel can have
                # moved the deadline between the check and the count
                self._deadline = None
                self.timeouts += 1
                cb = self.on_timeout
            cb()


# ---------------------------------------------------------------------------
# Preemption: SIGTERM -> graceful checkpoint request
# ---------------------------------------------------------------------------


class PreemptionHandler:
    def __init__(self, install: bool = True):
        self.requested = threading.Event()
        if install:
            try:
                signal.signal(signal.SIGTERM, self._on_signal)
            except ValueError:  # non-main thread (tests)
                pass

    def _on_signal(self, signum, frame) -> None:
        self.requested.set()

    def request(self) -> None:  # manual trigger (tests / simulator)
        self.requested.set()

    @property
    def pending(self) -> bool:
        return self.requested.is_set()


# ---------------------------------------------------------------------------
# Heartbeats + straggler detection
# ---------------------------------------------------------------------------


class HeartbeatMonitor:
    """Straggler + liveness detection over `fleet/heartbeat/@host<i>` beats.

    Liveness runs on ONE clock: beats are stamped at *arrival* with the
    monitor's own `clock` (default `time.monotonic`), never with the
    broker-delivered publish timestamp — `ExamonBroker.publish` defaults to
    `time.monotonic()` but accepts any explicit `timestamp` (epoch seconds,
    logical step counters), so trusting it would compare timestamps across
    clock domains and mis-declare liveness.  A caller living in a different
    time domain (e.g. the serving fleet's round counter) passes its own
    `clock` and gets consistent `check_liveness` semantics for free.
    """

    def __init__(self, broker: ExamonBroker, *, factor: float = 2.0,
                 patience: int = 3, window: int = 16,
                 on_straggler: Callable[[int], None] | None = None,
                 on_dead: Callable[[int], None] | None = None,
                 dead_after_s: float = 30.0,
                 clock: Callable[[], float] | None = None):
        self.factor = factor
        self.patience = patience
        self.dead_after_s = dead_after_s
        self.on_straggler = on_straggler or (lambda host: None)
        self.on_dead = on_dead or (lambda host: None)
        self._clock = clock or time.monotonic
        self._times: dict[int, deque] = defaultdict(lambda: deque(maxlen=window))
        self._last_seen: dict[int, float] = {}
        self._strikes: dict[int, int] = defaultdict(int)
        self.flagged: set[int] = set()
        self.dead: set[int] = set()
        self.malformed_beats = 0
        broker.subscribe("fleet/heartbeat/*", self._on_beat)

    @staticmethod
    def _host_of(topic: str) -> int | None:
        """Host index from `...@host<i>`, or None for a malformed topic —
        a beat without the suffix (or with a non-numeric one) must be
        dropped and counted, never crash the broker's subscriber fan-out."""
        parts = topic.rsplit("@host", 1)
        if len(parts) != 2 or not parts[1].isdigit():
            return None
        return int(parts[1])

    def _on_beat(self, topic: str, step_time: float, ts: float) -> None:
        host = self._host_of(topic)
        if host is None:
            self.malformed_beats += 1
            return
        self._times[host].append(step_time)
        self._last_seen[host] = self._clock()
        # a beat from a declared-dead slot means a replacement took it over
        # (hot spare): the slot is live again
        self.dead.discard(host)
        self._check(host)

    def _median_all(self) -> float:
        means = [sum(v) / len(v) for v in self._times.values() if v]
        if not means:
            return 0.0
        means.sort()
        return means[len(means) // 2]

    def _check(self, host: int) -> None:
        med = self._median_all()
        if med <= 0 or len(self._times) < 2:
            return
        mine = sum(self._times[host]) / len(self._times[host])
        if mine > self.factor * med:
            self._strikes[host] += 1
            if self._strikes[host] >= self.patience and host not in self.flagged:
                self.flagged.add(host)
                self.on_straggler(host)
        else:
            self._strikes[host] = 0
            self.flagged.discard(host)

    def check_liveness(self, now: float | None = None) -> None:
        """Declare hosts dead after `dead_after_s` of silence.  `now`
        defaults to the monitor's own clock — the same one that stamped the
        beats — so the comparison never crosses clock domains."""
        now = self._clock() if now is None else now
        for host, last in list(self._last_seen.items()):
            if now - last > self.dead_after_s and host not in self.dead:
                self.dead.add(host)
                self.on_dead(host)

    def forget(self, host: int) -> None:
        """Drop all state for a retired host slot (e.g. after its in-flight
        work was re-dispatched), so a stale entry can't re-trigger on_dead."""
        self._times.pop(host, None)
        self._last_seen.pop(host, None)
        self._strikes.pop(host, None)
        self.flagged.discard(host)
        self.dead.discard(host)


# ---------------------------------------------------------------------------
# Fleet simulator (exercises restart/elastic logic without hardware)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimWorker:
    host: int
    speed: float = 1.0  # steps per tick
    alive: bool = True


class FleetSim:
    """Simulates a data-parallel fleet around a real train-step callable.

    One 'tick' = one global step attempt: every live worker must heartbeat;
    a failed worker kills the step (the pod goes down), the trainer restores
    from the last checkpoint and continues — restore counts and straggler
    flags are observable for tests.
    """

    def __init__(self, num_hosts: int, broker: ExamonBroker, *, seed: int = 0):
        import random

        self.rng = random.Random(seed)
        self.broker = broker
        self.workers = [SimWorker(h) for h in range(num_hosts)]
        self.monitor = HeartbeatMonitor(
            broker, factor=2.0, patience=2,
            on_straggler=self._replace_worker,
        )
        self.replacements: list[int] = []
        self.failures: list[int] = []

    def _replace_worker(self, host: int) -> None:
        self.replacements.append(host)
        self.workers[host].speed = 1.0  # hot spare swapped in

    def inject_failure(self, host: int) -> None:
        self.workers[host].alive = False

    def inject_straggler(self, host: int, slowdown: float = 4.0) -> None:
        self.workers[host].speed = 1.0 / slowdown

    def tick(self, base_step_time: float = 0.01) -> bool:
        """Returns True if the global step succeeded (all workers alive)."""
        ok = True
        for w in self.workers:
            if not w.alive:
                self.failures.append(w.host)
                w.alive = True  # restarted by the launcher for the next tick
                ok = False
                continue
            step_time = base_step_time / w.speed
            self.broker.publish(f"fleet/heartbeat/@host{w.host}", step_time)
        return ok
