"""Elastic rescale: restore any checkpoint onto any mesh.

Checkpoints store host numpy (checkpoint/checkpointer.py); resharding is a
device_put against the new mesh's shardings, derived from the same logical
rules — so scaling 512 -> 256 -> 768 chips (or changing the DP/TP split) is
a restart, not a migration.  `plan_rescale` validates divisibility before
committing (batch % new DP size, etc.).
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
from jax.sharding import Mesh

from repro.checkpoint.checkpointer import Checkpointer
from repro.distributed.sharding import param_shardings, validate_mesh_rules
from repro.nn.module import Module


def plan_rescale(global_batch: int, new_mesh: Mesh,
                 rules: Mapping[str, Any]) -> dict:
    """Checks a proposed new mesh; returns derived facts or raises."""
    validate_mesh_rules(new_mesh, rules)
    dp = 1
    batch_axes = rules.get("batch") or ()
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    for a in batch_axes:
        dp *= new_mesh.shape.get(a, 1)
    if global_batch % dp:
        raise ValueError(
            f"global_batch {global_batch} not divisible by new DP degree {dp}"
        )
    return {"dp": dp, "per_replica_batch": global_batch // dp,
            "devices": new_mesh.devices.size}


def reshard_params(model: Module, ckpt: Checkpointer, new_mesh: Mesh,
                   rules: Mapping[str, Any], template: Any,
                   step: int | None = None):
    """Restore -> place on the new mesh. Returns (params, manifest)."""
    tree_np, manifest = ckpt.restore(template, step)
    shardings = param_shardings(model, new_mesh, rules)
    return Checkpointer.place(tree_np, shardings), manifest
