"""Logical-axis sharding: map model logical axes to mesh axes (GSPMD).

Rules are a plain dict {logical_axis: None | mesh_axis | (mesh_axes...)}
woven by the parallelization aspects (core/strategies/parallelization.py).
The default production layout (AutoShard) is Megatron-TP on
vocab/heads/mlp × FSDP on embed over data × DP batch over (pod, data), with
per-arch fallbacks for non-divisible head counts (KV replicated + sequence-
sharded caches).

Everything here is shape-aware: a dim smaller than its mesh-axis extent is
left unsharded rather than relying on GSPMD padding for parameters.
"""

from __future__ import annotations

from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.nn.module import Module, abstract_params, param_axes


def _axes_tuple(v) -> tuple[str, ...]:
    if v is None:
        return ()
    if isinstance(v, (tuple, list)):
        return tuple(a for a in v if a)
    return (v,)


def _mesh_extent(mesh: Mesh, axes: tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def logical_to_pspec(
    axes: tuple[str | None, ...],
    rules: Mapping[str, Any],
    mesh: Mesh,
    shape: tuple[int, ...] | None = None,
) -> P | None:
    """PartitionSpec for one tensor; None if nothing shards."""
    entries: list[Any] = []
    used: set[str] = set()
    any_sharded = False
    for i, logical in enumerate(axes):
        mapped = _axes_tuple(rules.get(logical)) if logical else ()
        mapped = tuple(a for a in mapped if a in mesh.shape and a not in used)
        # shape-aware: drop trailing mesh axes until the dim divides
        while mapped and shape is not None and (
            shape[i] < _mesh_extent(mesh, mapped)
            or shape[i] % _mesh_extent(mesh, mapped)
        ):
            mapped = mapped[:-1]
        if mapped:
            used.update(mapped)
            entries.append(mapped if len(mapped) > 1 else mapped[0])
            any_sharded = True
        else:
            entries.append(None)
    if not any_sharded:
        return None
    return P(*entries)


def param_shardings(model: Module, mesh: Mesh, rules: Mapping[str, Any]):
    """NamedSharding pytree matching the params pytree."""
    axes_tree = param_axes(model)
    specs_tree = abstract_params(model)

    def leaf(axes, sds):
        spec = logical_to_pspec(axes, rules, mesh, sds.shape)
        return NamedSharding(mesh, spec if spec is not None else P())

    return jax.tree.map(leaf, axes_tree, specs_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))


# ---------------------------------------------------------------------------
# Input sharding assignment (by leaf name)
# ---------------------------------------------------------------------------

_CACHE_LEAVES = {"k", "v", "ck", "cv"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def input_shardings(spec_tree, mesh: Mesh, rules: Mapping[str, Any],
                    *, stacked_layers: bool = True):
    """Shardings for step inputs: tokens/labels/embeds/frames, caches, states.

    Cache K/V tensors are (..., B, S, K, D): batch over the DP axes; KV heads
    over model when the rule maps them, else the sequence dim over model
    (sequence-sharded long-context cache for KV-poor archs).
    """
    batch = _axes_tuple(rules.get("batch"))
    kvh = _axes_tuple(rules.get("kv_heads"))
    kvs = _axes_tuple(rules.get("kv_seq"))
    heads = _axes_tuple(rules.get("heads"))
    embed = _axes_tuple(rules.get("embed_act", ()))

    def assign(path, sds):
        name = _leaf_name(path)
        rank = len(sds.shape)
        spec: list[Any] = [None] * rank

        def put(dim: int, axes: tuple[str, ...]):
            while axes:
                extent = _mesh_extent(mesh, axes)
                if sds.shape[dim] >= extent and sds.shape[dim] % extent == 0:
                    spec[dim] = axes if len(axes) > 1 else axes[0]
                    return
                axes = axes[:-1]

        if name in ("tokens", "labels", "positions"):
            put(0, batch)
        elif name in ("embeds", "frames", "enc"):
            put(0, batch)
        elif name in _CACHE_LEAVES and rank >= 4:
            put(rank - 4, batch)
            placed_kv = False
            if kvh and sds.shape[rank - 2] % _mesh_extent(mesh, kvh) == 0 and \
                    sds.shape[rank - 2] >= _mesh_extent(mesh, kvh):
                put(rank - 2, kvh)
                placed_kv = spec[rank - 2] is not None
            if not placed_kv:
                put(rank - 3, kvs)
        elif name == "wkv" and rank >= 4:  # (L?, B, H, C, C)
            put(rank - 4, batch)
        elif name == "x_prev" and rank >= 2:
            put(rank - 2, batch)
        elif name in ("lru", "conv") and rank >= 2:
            put(0 if rank == 2 else rank - 3, batch)
        elif name in ("index", "pos"):
            pass  # tiny metadata, replicated
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(assign, spec_tree)


def validate_mesh_rules(mesh: Mesh, rules: Mapping[str, Any]) -> None:
    for key, val in rules.items():
        for a in _axes_tuple(val):
            if a not in mesh.shape:
                raise ValueError(f"rule {key!r} -> {val!r}: axis {a!r} not in mesh "
                                 f"{dict(mesh.shape)}")
