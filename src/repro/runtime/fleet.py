"""Fault-tolerant multi-replica serving fleet.

One host caps out fast at millions of users; the fleet layer places N
`Server` replicas and treats each replica's `serve_continuous` as the unit
PR 8 made it — a wave that degrades into structured per-request outcomes
instead of dying.  The extra-functional concerns live here, one level up
from the server, and are woven (FleetResilienceAspect) rather than
hard-coded:

  routing       prefix-affinity first — a request whose prompt shares
                blake2b page-boundary digests (`runtime/pages._prefix_digests`)
                with prompts a replica already served routes there, so the
                prefix cache composes across the fleet; least-loaded
                otherwise.  `wave_size` caps a replica's per-round intake,
                so hot prefixes spill and warm a second replica.
  replica loss  replicas publish `fleet/heartbeat/@host<i>` step beats;
                a fleet-level `HeartbeatMonitor` (same logical round
                clock on both sides) declares a silent replica dead, and
                every incomplete request it held re-dispatches to
                survivors — completed outputs are kept, only incomplete
                work replays, with bounded retry + doubling backoff and a
                per-request fleet deadline retiring overdue requests with
                partial output as `deadline_exceeded`.
  graceful drain SIGTERM (PreemptionHandler semantics) stops a replica's
                admissions mid-wave: in-flight requests finish, the
                undrained remainder hands off to peers, a hot spare swaps
                into the slot.
  fault weave   the `FaultInjector` fleet join points (`route`,
                `replica_loss`, `drain`) schedule deterministic kill /
                drain / routing faults so the kill-a-replica-mid-wave
                sweep (benchmarks/fleet.py) asserts 100% recovery with
                survivor bit-parity against a single-server baseline.

Replica death is simulated deterministically: a wave whose `replica_loss`
join point fires runs with an internal chaos injector that raises at
every decode step past `kill_step`, exhausting the server's retry budget
— PR 8's `_StepAbort` path then returns completed requests as `ok` (kept)
and in-flight ones as `failed` with partial output, exactly the
structured-outcome contract the re-dispatch consumes.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import numpy as np

from repro.core.strategies.resilience import (
    DEFAULT_FLEET_POLICY,
    FaultError,
    FaultInjector,
    FaultSpec,
)
from repro.distributed.fault import HeartbeatMonitor
from repro.monitor.examon import ExamonBroker
from repro.runtime.pages import PoolExhausted, _prefix_digests
from repro.runtime.server import Server


class _PollPreemption:
    """SIGTERM arriving mid-wave: `pending` flips True after `after`
    polls.  `serve_continuous` polls at admission boundaries, so `after=1`
    lets the initial admission cohort through (it finishes normally) and
    drains everything still waiting — the synchronous-sim equivalent of a
    signal landing while the wave is decoding."""

    def __init__(self, after: int = 1):
        self.after = int(after)
        self.polls = 0

    @property
    def pending(self) -> bool:
        self.polls += 1
        return self.polls > self.after


@dataclasses.dataclass
class Replica:
    host: int
    server: Server
    alive: bool = True
    draining: bool = False
    drain_polls: int = 1      # admission polls before a requested drain bites
    slowdown: float = 1.0     # published step-time multiplier (straggler sim)
    waves: int = 0
    served: int = 0           # requests completed here
    prefix_hits: int = 0      # pool-level prefix-index hits, accumulated
    affinity_hits: int = 0    # requests routed here by digest affinity
    digests: set = dataclasses.field(default_factory=set)

    def snapshot(self) -> dict[str, Any]:
        return {"host": self.host, "alive": self.alive,
                "draining": self.draining, "waves": self.waves,
                "served": self.served, "prefix_hits": self.prefix_hits,
                "affinity_hits": self.affinity_hits}


class ServingFleet:
    """Places `replicas` Server replicas (+ `spares` hot spares), routes
    with prefix affinity, and survives replica loss and drain.

    `factory` builds one replica's Server; replicas built from one shared
    WovenProgram share jit caches, which is exactly what N processes from
    one container image would do.  Policy knobs left None resolve from the
    woven `fleet_resilience` extras (FleetResilienceAspect), then from
    `DEFAULT_FLEET_POLICY`; an explicit `injector` (or the woven
    `fleet_injector`) arms the fleet join points.
    """

    def __init__(self, factory: Callable[[], Server], *,
                 replicas: int = 2, spares: int = 0,
                 injector: FaultInjector | None = None,
                 broker: ExamonBroker | None = None,
                 retries: int | None = None,
                 backoff_s: float | None = None,
                 deadline_s: float | None = None,
                 affinity: bool | None = None,
                 wave_size: int | None = None,
                 dead_after_rounds: float | None = None,
                 kill_step: int | None = None,
                 digest_page_size: int = 8):
        if replicas < 1:
            raise ValueError("a fleet needs at least one replica")
        self.factory = factory
        self.replicas = [Replica(h, factory()) for h in range(replicas)]
        self.spares = deque(Replica(replicas + j, factory())
                            for j in range(spares))
        probe = self.replicas[0].server.woven.state.extra
        pol = dict(DEFAULT_FLEET_POLICY)
        pol.update(probe.get("fleet_resilience", {}))
        for key, val in (("retries", retries), ("backoff_s", backoff_s),
                         ("deadline_s", deadline_s), ("affinity", affinity),
                         ("wave_size", wave_size),
                         ("dead_after_rounds", dead_after_rounds)):
            if val is not None:
                pol[key] = val
        self.policy = pol
        self.injector = injector if injector is not None \
            else probe.get("fleet_injector")
        self.kill_step = kill_step
        self.digest_page_size = int(digest_page_size)
        self.broker = broker or ExamonBroker()
        self._round = 0
        self._newly_dead: list[int] = []
        self._next_host = replicas + spares
        # both sides of liveness run on the fleet's logical round counter:
        # beats are arrival-stamped with this clock and check_liveness
        # compares against it — no wall-clock/publish-ts domain crossing
        self.monitor = HeartbeatMonitor(
            self.broker,
            factor=float(pol["straggler_factor"]),
            patience=int(pol["straggler_patience"]),
            dead_after_s=float(pol["dead_after_rounds"]),
            clock=lambda: float(self._round),
            on_straggler=self._on_straggler,
            on_dead=self._on_dead,
        )
        self.events: list[dict[str, Any]] = []
        self.last_fleet_stats: dict[str, Any] | None = None
        self.last_outcomes: list[dict[str, Any]] | None = None

    # -- monitor callbacks -------------------------------------------------

    def _on_dead(self, host: int) -> None:
        self._newly_dead.append(host)

    def _on_straggler(self, host: int) -> None:
        # FleetSim's mitigation pattern one level up: a flagged replica is
        # demoted and a hot spare takes its traffic (the straggler keeps
        # its in-flight wave — demotion is not loss)
        self.events.append({"kind": "straggler", "host": host,
                            "round": self._round})
        rep = self._by_host(host)
        if rep is not None and not rep.draining:
            self.request_drain(host)

    def _by_host(self, host: int) -> Replica | None:
        for rep in self.replicas:
            if rep.host == host:
                return rep
        return None

    def _live(self) -> list[Replica]:
        return [r for r in self.replicas if r.alive]

    def _qos_rollup(self) -> dict[str, Any] | None:
        """Aggregate per-replica QoS-governor stats (replicas serving
        under a woven QoSAspect populate `server.last_qos_stats`): total
        OP switches, the distinct OPs seen fleet-wide, and the summed
        energy ledger.  None when no replica ran governed."""
        per: list[dict[str, Any]] = []
        for rep in self.replicas:
            q = getattr(rep.server, "last_qos_stats", None)
            if q is not None:
                per.append({"host": rep.host, "switches": q["switches"],
                            "distinct_ops": q["distinct_ops"],
                            "tokens": q["tokens"],
                            "energy_j": q["energy_j"]})
        if not per:
            return None
        energy = sum(p["energy_j"] for p in per)
        tokens = sum(p["tokens"] for p in per)
        return {"replicas": per,
                "switches": sum(p["switches"] for p in per),
                "energy_j": energy,
                "tokens": tokens,
                "tokens_per_joule": tokens / energy if energy > 0 else None}

    # -- drain / spare management -----------------------------------------

    def request_drain(self, host: int, *, after_polls: int = 1) -> None:
        """Gracefully drain replica `host` (SIGTERM semantics): its next
        wave finishes whatever it admits, hands the rest to peers, and
        the replica retires (a hot spare fills the slot if available)."""
        rep = self._by_host(host)
        if rep is None or not rep.alive:
            return
        rep.draining = True
        rep.drain_polls = int(after_polls)

    def _swap_in_spare(self, lost_host: int) -> None:
        if not self.spares:
            return
        spare = self.spares.popleft()
        self.replicas.append(spare)
        self.events.append({"kind": "spare_in", "host": spare.host,
                            "for": lost_host, "round": self._round})

    # -- fleet join points -------------------------------------------------

    def _fire(self, point: str, *, rid: Any = None) -> tuple[bool, Any]:
        """Visit a fleet join point; returns (fired, spec).  `raise`-family
        kinds are absorbed here — at fleet level every fired fault maps to
        the point's recovery action, never to an escaping exception."""
        if self.injector is None:
            return False, None
        try:
            spec = self.injector.fire(point, rid=rid)
        except (FaultError, PoolExhausted):
            spec = self.injector.events[-1]
            return True, spec
        if spec is not None:
            return True, spec
        return False, None

    def _publish_faults(self, since: int) -> list[dict]:
        fired = (list(self.injector.events[since:])
                 if self.injector is not None else [])
        for ev in fired:
            point = ev["point"] if isinstance(ev, dict) else ev.point
            kind = ev["kind"] if isinstance(ev, dict) else ev.kind
            self.broker.publish(f"fleet/fault/{point}/{kind}", 1.0)
        return [dict(ev) if isinstance(ev, dict)
                else {"point": ev.point, "kind": ev.kind} for ev in fired]

    # -- routing -----------------------------------------------------------

    def _digests(self, prompt) -> list[bytes]:
        toks = np.asarray(prompt, np.int64).reshape(-1)
        bounds, whole = _prefix_digests(toks, self.digest_page_size)
        return bounds + [whole]

    def _route(self, rid: int, prompt,
               room: dict[int, int]) -> Replica | None:
        """Pick a live replica with room: deepest digest overlap first
        (prefix affinity), least-loaded fallback.  A fired `route` fault
        degrades this request to least-loaded — a routing fault must never
        lose a request."""
        # a draining replica is still routable for its final wave — the
        # SIGTERM bites mid-wave and hands the remainder back
        cands = [r for r in self._live() if room.get(r.host, 0) > 0]
        if not cands:
            return None
        fired, _ = self._fire("route", rid=rid)
        use_affinity = self.policy["affinity"] and not fired
        digs = self._digests(prompt)
        best, overlap = None, 0
        if use_affinity:
            for rep in cands:
                hits = sum(1 for d in digs if d in rep.digests)
                if hits > overlap:
                    best, overlap = rep, hits
        if best is not None:
            best.affinity_hits += 1
        else:
            best = min(cands, key=lambda r: (-room[r.host], r.host))
        best.digests.update(digs)
        room[best.host] -= 1
        return best

    # -- the serve ---------------------------------------------------------

    def serve(self, prompts: list[np.ndarray], *,
              decode_tokens: int | None = None) -> list[np.ndarray]:
        """Serve `prompts` across the fleet; returns per-request token
        arrays in submission order, bit-identical per request to a
        single-server fault-free `serve_continuous` (routing only changes
        *where* a request decodes, never what it emits).  Structured
        per-request outcomes land in `last_outcomes`, fleet economics in
        `last_fleet_stats`."""
        n_req = len(prompts)
        if n_req == 0:
            self.last_outcomes = []
            self.last_fleet_stats = {"rounds": 0, "events": [],
                                     "injected_events": [], "outcomes": {}}
            return []
        first = self.replicas[0].server
        n = decode_tokens or first.cfg.decode_tokens
        kill_at = self.kill_step if self.kill_step is not None \
            else max(1, n - 1)
        wave = max(1, int(self.policy["wave_size"]))
        retries_max = int(self.policy["retries"])
        backoff_s = float(self.policy["backoff_s"])
        deadline_s = self.policy["deadline_s"]

        pending = deque(range(n_req))
        limbo: dict[int, list[int]] = {}   # dead-suspect host -> held rids
        outputs: dict[int, np.ndarray] = {}
        outcome = {r: {"status": "queued", "reason": None, "replica": None}
                   for r in range(n_req)}
        attempts = {r: 0 for r in range(n_req)}
        redispatched = 0
        t0 = time.monotonic()
        inj_seen = len(self.injector.events) if self.injector else 0
        ev_seen = len(self.events)
        self._round = 0
        # bounded by construction: every round either completes requests,
        # advances a liveness countdown, or re-dispatches — but a hard cap
        # keeps an unforeseen stall from spinning forever
        max_rounds = 4 * (n_req + len(self.replicas) + 8)

        # join beats: every replica announces liveness before the first
        # wave, so a replica lost in its very first wave still has a
        # last-seen entry for the monitor to declare dead against
        for rep in self._live():
            self.broker.publish(f"fleet/heartbeat/@host{rep.host}",
                                0.001 * rep.slowdown,
                                timestamp=float(self._round))

        def _keep_best(rid: int, toks: np.ndarray) -> None:
            if len(toks) > len(outputs.get(rid, ())):
                outputs[rid] = np.asarray(toks, np.int64)

        def _retire_overdue() -> None:
            if deadline_s is None:
                return
            now = time.monotonic()
            if now - t0 <= deadline_s:
                return
            stuck = list(pending) + [r for rs in limbo.values() for r in rs]
            pending.clear()
            limbo.clear()
            for rid in stuck:
                outcome[rid] = {"status": "deadline_exceeded",
                                "reason": "fleet deadline exceeded before "
                                          "completion", "replica": None}
                self.events.append({"kind": "deadline", "rid": rid,
                                    "round": self._round,
                                    "partial": len(outputs.get(rid, ()))})

        while pending or limbo:
            self._round += 1
            if self._round > max_rounds:
                for rid in list(pending) + [r for rs in limbo.values()
                                            for r in rs]:
                    outcome[rid] = {"status": "failed",
                                    "reason": "fleet made no progress",
                                    "replica": None}
                break
            if not self._live() and not limbo:
                # every replica is gone and no death declaration is
                # pending: the backlog fails structurally, never raises
                for rid in pending:
                    outcome[rid] = {"status": "failed",
                                    "reason": "no live replicas left",
                                    "replica": None}
                pending.clear()
                break

            # route this round's wave (wave_size per replica; affinity
            # spill is what warms a second replica with a hot prefix)
            room = {r.host: wave for r in self._live()}
            assign: dict[int, list[int]] = {r.host: [] for r in self._live()}
            while pending:
                rid = pending[0]
                rep = self._route(rid, prompts[rid], room)
                if rep is None:
                    break
                pending.popleft()
                assign[rep.host].append(rid)

            for rep in list(self._live()):
                rids = assign.get(rep.host, [])
                if not rids and not rep.draining:
                    # idle replicas still beat — alive is alive
                    self.broker.publish(
                        f"fleet/heartbeat/@host{rep.host}",
                        0.001 * rep.slowdown, timestamp=float(self._round))
                    continue
                killed, _ = self._fire("replica_loss", rid=rep.host)
                drain_now, drain_polls = rep.draining, rep.drain_polls
                if not killed and not drain_now:
                    fired, _ = self._fire("drain", rid=rep.host)
                    if fired:
                        drain_now, drain_polls = True, 1
                chaos = None
                if killed:
                    # deterministic mid-wave death: decode steps past
                    # kill_at raise until the retry budget exhausts, so
                    # the wave drains via _StepAbort — completed requests
                    # stay "ok", in-flight ones return partial "failed"
                    chaos = FaultInjector([FaultSpec(
                        "decode_step", "raise", at=kill_at, repeat=1 << 20)])
                preempt = _PollPreemption(drain_polls) if drain_now else None
                outs: list[np.ndarray] = []
                per: list[dict] = []
                if rids:
                    outs = rep.server.serve_continuous(
                        [prompts[r] for r in rids], decode_tokens=n,
                        fault_injector=chaos, preemption=preempt)
                    rep.waves += 1
                    pool = rep.server.last_pool_stats or {}
                    rep.prefix_hits += int(pool.get("prefix_hits", 0) or 0)
                    per = rep.server.last_outcomes or []
                handoff: list[int] = []
                incomplete: list[int] = []
                for i, rid in enumerate(rids):
                    status = per[i]["status"] if i < len(per) else "failed"
                    if status == "ok":
                        outputs[rid] = np.asarray(outs[i], np.int64)
                        outcome[rid] = {"status": "ok", "reason": None,
                                        "replica": rep.host}
                        rep.served += 1
                    elif status == "drained":
                        handoff.append(rid)
                    elif killed:
                        _keep_best(rid, outs[i])
                        incomplete.append(rid)
                    else:
                        # terminal per-request outcome on a healthy
                        # replica (oversized, quarantined, ...)
                        _keep_best(rid, outs[i])
                        outcome[rid] = {"status": status,
                                        "reason": per[i]["reason"],
                                        "replica": rep.host}
                if killed:
                    rep.alive = False
                    limbo[rep.host] = incomplete
                    self.events.append({
                        "kind": "replica_loss", "host": rep.host,
                        "round": self._round,
                        "kept": sum(1 for r in rids
                                    if outcome[r]["status"] == "ok"),
                        "held": len(incomplete)})
                    continue  # a dead replica beats no more
                if drain_now:
                    # the undrained queue hands off to peers — no attempt
                    # penalty, these requests never started decoding
                    pending.extend(handoff)
                    rep.alive = False
                    rep.draining = False
                    self.events.append({"kind": "drain", "host": rep.host,
                                        "round": self._round,
                                        "finished": sum(
                                            1 for r in rids
                                            if outcome[r]["status"] == "ok"),
                                        "handoff": len(handoff)})
                    self.monitor.forget(rep.host)
                    self._swap_in_spare(rep.host)
                    continue
                self.broker.publish(
                    f"fleet/heartbeat/@host{rep.host}",
                    0.001 * rep.slowdown, timestamp=float(self._round))

            # liveness: the monitor is the authority on death — limbo'd
            # requests only re-dispatch once it declares the host dead
            self.monitor.check_liveness()
            for host in self._newly_dead:
                held = limbo.pop(host, [])
                self.monitor.forget(host)
                self.events.append({"kind": "declared_dead", "host": host,
                                    "round": self._round,
                                    "redispatch": len(held)})
                for rid in held:
                    attempts[rid] += 1
                    if attempts[rid] > retries_max:
                        outcome[rid] = {
                            "status": "failed",
                            "reason": f"re-dispatch budget exhausted "
                                      f"({retries_max} retries)",
                            "replica": None}
                        continue
                    if backoff_s:
                        time.sleep(backoff_s * (2 ** (attempts[rid] - 1)))
                    pending.append(rid)
                    redispatched += 1
                self._swap_in_spare(host)
            self._newly_dead.clear()
            # deadline sweep last: requests that served this round are
            # already done, so what retires here keeps its partial output
            _retire_overdue()

        injected = self._publish_faults(inj_seen)
        by_status: dict[str, int] = {}
        for r in range(n_req):
            s = outcome[r]["status"]
            by_status[s] = by_status.get(s, 0) + 1
        self.last_outcomes = [
            {"rid": r, "status": outcome[r]["status"],
             "reason": outcome[r]["reason"],
             "replica": outcome[r]["replica"],
             "attempts": attempts[r],
             "tokens": len(outputs.get(r, ()))}
            for r in range(n_req)]
        self.last_fleet_stats = {
            "rounds": self._round,
            "replicas": [rep.snapshot() for rep in self.replicas],
            "spares_left": len(self.spares),
            "redispatched": redispatched,
            "events": list(self.events[ev_seen:]),
            "injected_events": injected,
            "outcomes": by_status,
            "malformed_beats": self.monitor.malformed_beats,
            "replicas_with_prefix_hits": sorted(
                rep.host for rep in self.replicas if rep.prefix_hits > 0),
            "affinity_hits": sum(r.affinity_hits for r in self.replicas),
            # QoS plane rollup: replicas serving under a woven QoSAspect
            # report per-replica OP switches and the fleet energy ledger
            # (None when no replica ran governed)
            "qos": self._qos_rollup(),
        }
        return [outputs.get(r, np.asarray([], np.int64))
                for r in range(n_req)]
