"""QoS control plane for streaming serving (paper §2.5, §2.7): the serving
operating point as a mARGOt application.

`Server.serve_stream` is the managed application; its operating point —
``max_batch × prefill_chunk × draft_len × freq`` (the DVFS/power knob) —
is a mARGOt `KnowledgeBase` whose per-OP metric expectations come from an
analytic wave-cost model (the container is CPU-only, so cost and power are
modeled, exactly like `power/rapl`).  Per-request latency SLOs (TTFT and
per-token) are `Goal` constraints; tokens/s or tokens/joule is the
objective (`State` "throughput" / "efficiency"); observed wave latencies
feed `Margot.observe`, whose reactive error coefficient rescales every
expectation — so the model only has to be *relatively* right across OPs,
the feedback loop calibrates the absolute scale.  Load (waiting + active
requests) is the proactive input feature: per-load-bucket knowledge bases
are selected by nearest feature vector, so the governor plans against the
queue it actually has.

Power closes the loop through `power/capper.PowerCapper`: each wave's
modeled power is `report`ed (the capper throttles by priority when the
node is over budget) and the capper's frequency clamps the governor's own
freq knob — the serving loop then divides its pace by
`RAPLModel.perf_scale`, which is what makes tokens/joule a real tradeoff
rather than bookkeeping.

Every knob move only changes *scheduling* (when work runs), never the
tokens: emitted output stays a target argmax chain, bit-identical to an
ungoverned serve.
"""

from __future__ import annotations

import itertools
import math
from typing import Any, Iterable

from repro.autotune.margot import (
    LE,
    Goal,
    KnowledgeBase,
    Margot,
    OperatingPoint,
    State,
)
from repro.power.capper import PowerCapper
from repro.power.rapl import RAPLModel

# Knob grids: a tuple/list is a governed knob (the OP space), a scalar is
# a fixed value, None leaves the knob ungoverned (the server's own
# argument/config value stays in force).  SLOs of None mean "no Goal".
DEFAULT_QOS_POLICY: dict[str, Any] = {
    "enabled": True,
    "max_batch": (1, 2, 4, 8),     # concurrent decode slots
    "prefill_chunk": (0, 32, 128),  # tokens per admission wave (0: one-shot)
    "draft_len": None,             # speculative k (None: ungoverned)
    "freq": None,                  # DVFS knob (None: ungoverned)
    "objective": "tokens_per_s",   # or "tokens_per_joule"
    "slo_ttft_s": None,            # Goal: time to first token
    "slo_tok_s": None,             # Goal: worst inter-token gap
    "power_cap_w": None,           # Goal + PowerCapper node budget
    "reselect_every": 4,           # waves between Margot.update calls
    "reactive": True,              # feed observed latencies to Margot
    #   (False: plan purely from the analytic model + load feature — a
    #   deterministic policy for benches scored on a modeled clock, where
    #   wall-clock jit noise must not steer CI-asserted OP choices)
    "load_buckets": (1, 2, 4, 8, 16, 32),  # proactive feature clusters
    # analytic wave-cost model (relative costs; the reactive error
    # coefficient calibrates the absolute scale from observed waves)
    "s0": 2e-3,                    # fixed per-wave overhead, seconds
    "s_tok": 2e-4,                 # per processed token, seconds
    "accept": 0.8,                 # expected draft acceptance rate
    "compute_bound_frac": 0.6,     # RAPLModel.perf_scale phase mix
    "typical_prompt": 64,          # tokens, for admission-cost modeling
}

_KNOB_NAMES = ("max_batch", "prefill_chunk", "draft_len", "freq")
_METRIC_NAMES = ("wave_s", "tok_s", "ttft_s", "tokens_per_s",
                 "power_w", "tokens_per_joule")


class QoSGovernor:
    """MAPE-K governor over the serving operating point.

    The serve loop calls three hooks:

      * ``decide(wave=, waiting=, active=)`` every ``reselect_every``
        waves — Margot plans against the current load feature and returns
        the knob dict to apply (only governed knobs appear);
      * ``observe_wave(dt_s, batch=, emitted=, prefill_tokens=, wave=)``
        at every wave boundary — feeds the reactive error coefficient,
        accounts energy, reports power to the capper;
      * ``observe(metric, value)`` for per-request metrics (TTFT).

    ``stats()`` reports switches / distinct OPs / the OP history plus the
    energy ledger — what the qos bench and the fleet aggregate.
    """

    def __init__(self, policy: dict[str, Any] | None = None, *,
                 broker=None, capper: PowerCapper | None = None,
                 model: RAPLModel | None = None):
        pol = dict(DEFAULT_QOS_POLICY)
        if policy:
            pol.update(policy)
        self.policy = pol
        self.model = model or RAPLModel()
        self.broker = broker
        self.reselect_every = max(1, int(pol["reselect_every"]))
        self.capper = capper
        if self.capper is None and pol.get("power_cap_w"):
            self.capper = PowerCapper(float(pol["power_cap_w"]),
                                      model=self.model)
        self._task_id = None
        if self.capper is not None:
            self._task_id = self.capper.register("serve_stream", priority=1)
        # capacity normalizer for the utilization model: the most decode
        # tokens any OP can put in one wave
        bs = self.knob_values("max_batch") or (1,)
        ks = self.knob_values("draft_len") or (0,)
        self._peak_tokens = max(bs) * (1 + max(ks))
        self.margot = self._build_margot()
        self.current_knobs: dict[str, Any] = {}
        self.op_history: list[dict[str, Any]] = []  # wave + knobs per switch
        self.energy_j = 0.0
        self.tokens = 0
        self.waves = 0

    # -- knob space -----------------------------------------------------------

    def knob_values(self, name: str) -> tuple:
        """The governed grid for one knob (empty when ungoverned) — the
        server sizes verify slack and the draft pool from
        ``knob_values("draft_len")``."""
        v = self.policy.get(name)
        if v is None:
            return ()
        if not isinstance(v, (tuple, list)):
            v = (v,)
        return tuple(x for x in v if x is not None)

    def _grid(self) -> Iterable[dict[str, Any]]:
        names = [n for n in _KNOB_NAMES if self.knob_values(n)]
        for combo in itertools.product(
                *[self.knob_values(n) for n in names]):
            yield dict(zip(names, combo))

    # -- analytic model -------------------------------------------------------

    def _metrics(self, knobs: dict[str, Any],
                 load: float) -> dict[str, tuple[float, float]]:
        pol = self.policy
        b = int(knobs.get("max_batch", max(self.knob_values("max_batch")
                                           or (8,))))
        chunk = int(knobs.get("prefill_chunk", 0) or 0)
        kd = int(knobs.get("draft_len", 0) or 0)
        freq = float(knobs.get("freq", 1.0) or 1.0)
        s0, s_tok = float(pol["s0"]), float(pol["s_tok"])
        acc = float(pol["accept"])
        s_typ = max(1, int(pol["typical_prompt"]))
        scale = self.model.perf_scale(freq, float(pol["compute_bound_frac"]))

        b_eff = max(1.0, min(load, b))
        queued = max(load - b, 0.0)
        decode_tok = b_eff * (1 + kd)
        admit_tok = min(chunk, s_typ) if chunk else s_typ
        prefill_waves = math.ceil(s_typ / chunk) if chunk else 1
        wave_s = (s0 + s_tok * decode_tok) / scale
        # a wave that also hosts admission work (the one-shot prompt, or
        # one chunk of it) — the worst inter-token gap survivors see
        wave_admit_s = (s0 + s_tok * (decode_tok + admit_tok)) / scale
        tok_mean = 1 + kd * acc  # emitted per request per wave
        tokens_per_s = b_eff * tok_mean / wave_admit_s
        queue_waves = math.ceil(queued / b) if queued else 0
        ttft_s = queue_waves * wave_s + prefill_waves * wave_admit_s
        util = min(1.0, decode_tok / self._peak_tokens)
        power_w = self.model.power(util, freq)
        tokens_per_joule = tokens_per_s / power_w
        out = {"wave_s": wave_s, "tok_s": wave_admit_s, "ttft_s": ttft_s,
               "tokens_per_s": tokens_per_s, "power_w": power_w,
               "tokens_per_joule": tokens_per_joule}
        return {k: (v, 0.1 * v) for k, v in out.items()}

    def _build_margot(self) -> Margot:
        pol = self.policy
        goals = []
        if pol.get("slo_ttft_s") is not None:
            goals.append(Goal("slo_ttft", "ttft_s", LE,
                              float(pol["slo_ttft_s"])))
        if pol.get("slo_tok_s") is not None:
            goals.append(Goal("slo_tok", "tok_s", LE,
                              float(pol["slo_tok_s"])))
        if pol.get("power_cap_w") is not None:
            goals.append(Goal("power_cap", "power_w", LE,
                              float(pol["power_cap_w"])))
        states = [
            State("throughput", "tokens_per_s", maximize=True,
                  constraints=list(goals)),
            State("efficiency", "tokens_per_joule", maximize=True,
                  constraints=list(goals)),
        ]
        active = ("efficiency" if pol["objective"] == "tokens_per_joule"
                  else "throughput")
        feature_kbs = {}
        for bucket in pol["load_buckets"]:
            ops = [OperatingPoint(knobs, self._metrics(knobs, float(bucket)))
                   for knobs in self._grid()]
            feature_kbs[(float(bucket),)] = KnowledgeBase(ops)
        base = feature_kbs.get(
            (float(pol["load_buckets"][0]),), KnowledgeBase(
                [OperatingPoint(knobs, self._metrics(knobs, 1.0))
                 for knobs in self._grid()]))
        return Margot(base, states, active, feature_kbs=feature_kbs)

    # -- MAPE hooks the serve loop calls --------------------------------------

    def decide(self, *, wave: int, waiting: int, active: int) -> dict:
        """Analyze + plan: re-select the OP for the current load feature.
        Returns the knob dict to apply (the serve loop clamps each knob to
        its own static limits)."""
        load = float(max(1, waiting + active))
        op = self.margot.update(features=(load,))
        knobs = dict(op.knobs)
        if self.capper is not None and self._task_id is not None:
            # the node power budget wins over the planned DVFS point: a
            # throttled task runs at the capper's frequency even if the
            # governor's objective wanted more
            f_cap = self.capper.frequency(self._task_id)
            knobs["freq"] = min(float(knobs.get("freq", 1.0) or 1.0), f_cap)
        if not self.op_history \
                or self.op_history[-1]["knobs"] != dict(op.knobs):
            self.op_history.append({"wave": int(wave), "load": load,
                                    "knobs": dict(op.knobs)})
        self.current_knobs = knobs
        if self.broker is not None:
            self.broker.publish("serve/qos/load", load)
        return knobs

    def observe(self, metric: str, value: float) -> None:
        """Per-request observation (the serve loop feeds TTFT here)."""
        if self.policy.get("reactive", True):
            self.margot.observe(metric, float(value))

    def observe_wave(self, dt_s: float, *, batch: int, emitted: int,
                     prefill_tokens: int = 0, wave: int = 0) -> None:
        """Monitor: one wave boundary.  Feeds the reactive error
        coefficient (observed wave latency vs the current OP's
        expectation), accounts modeled energy, and reports power to the
        capper's priority control loop."""
        dt_s = float(dt_s)
        if not math.isfinite(dt_s) or dt_s < 0:
            return
        freq = float(self.current_knobs.get("freq", 1.0) or 1.0)
        if self.policy.get("reactive", True):
            self.margot.observe("wave_s", dt_s)
            if prefill_tokens or self.margot.current is None:
                self.margot.observe("tok_s", dt_s)
        self.waves += 1
        self.tokens += int(emitted)
        kd = int(self.current_knobs.get("draft_len", 0) or 0)
        util = min(1.0, batch * (1 + kd) / self._peak_tokens)
        p = self.model.power(util, freq)
        self.energy_j += p * dt_s
        if self.capper is not None and self._task_id is not None:
            self.capper.report(self._task_id, p)
        if self.broker is not None:
            self.broker.publish("serve/qos/wave_s", dt_s)
            self.broker.publish("serve/qos/power_w", p)

    # -- runtime reconfiguration ----------------------------------------------

    def set_power_cap(self, watts: float) -> None:
        """Move the node power budget at runtime: the capper's cap and the
        Margot power Goal both move, so planning and throttling agree."""
        watts = float(watts)
        self.policy["power_cap_w"] = watts
        if self.capper is not None:
            self.capper.set_cap(watts)
        else:
            self.capper = PowerCapper(watts, model=self.model)
            self._task_id = self.capper.register("serve_stream", priority=1)
        for state in self.margot.states.values():
            state.constraints = [
                Goal("power_cap", "power_w", LE, watts)
                if g.name == "power_cap" else g
                for g in state.constraints]
            if not any(g.name == "power_cap" for g in state.constraints):
                state.constraints.append(
                    Goal("power_cap", "power_w", LE, watts))

    def stats(self) -> dict[str, Any]:
        distinct = {tuple(sorted(h["knobs"].items()))
                    for h in self.op_history}
        return {
            "switches": self.margot.switches,
            "distinct_ops": len(distinct),
            "op_history": list(self.op_history),
            "current": dict(self.current_knobs),
            "objective": self.margot.active,
            "waves": self.waves,
            "tokens": self.tokens,
            "energy_j": self.energy_j,
            "tokens_per_joule": (self.tokens / self.energy_j
                                 if self.energy_j > 0 else None),
            "power": (self.capper.snapshot()
                      if self.capper is not None else None),
        }
