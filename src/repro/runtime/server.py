"""Serving runtime: batched prefill+decode with mARGOt QoS adaptation.

This is the UC2 (navigation) runtime shape: requests arrive with a prompt,
the server prefils then decodes N tokens; the woven knobs (precision
variant, decode budget, memoization on/off) are adapted by mARGOt against a
quality index + latency/cost constraints — reproducing the paper's
NQI-vs-cost trade-off (Figs. 17–19) in benchmarks/navigation_autotune.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.weaver import WovenProgram
from repro.memo.table import MemoTable
from repro.monitor.examon import ExamonBroker, get_default_broker
from repro.monitor.sensors import apply_wrappers
from repro.nn.module import init_params
from repro.runtime.steps import (
    build_decode_step,
    build_prefill_step,
    stack_request_caches,
)
from repro.versioning.libvc import LibVC


@dataclasses.dataclass
class ServerConfig:
    max_cache_len: int = 256
    decode_tokens: int = 8
    seed: int = 0


class Server:
    def __init__(self, woven: WovenProgram, cfg: ServerConfig, *, mesh=None,
                 margot=None, broker: ExamonBroker | None = None,
                 memo: MemoTable | None = None):
        self.woven = woven
        self.cfg = cfg
        self.mesh = mesh
        self.margot = margot
        self.broker = broker or get_default_broker()
        self.memo = memo if memo is not None else woven.state.extra.get("memo_table")
        self.info: dict[str, Any] = {"task_name": woven.program.cfg.name, "knobs": {}}

        def build(kind):
            def builder(variant: str):
                v = None if variant == "__default__" else variant
                if kind == "prefill":
                    fn = build_prefill_step(self.woven, mesh=self.mesh, variant=v)
                else:
                    fn = build_decode_step(self.woven, mesh=self.mesh, variant=v)
                return jax.jit(fn)

            return LibVC(builder, error_strategy="fallback")

        self.prefill_vc = build("prefill")
        self.decode_vc = build("decode")
        self.params = init_params(woven.program.model, jax.random.PRNGKey(cfg.seed),
                                  woven.state.policies)
        self.served = 0
        self.latencies: list[float] = []

    def _variant(self) -> str | None:
        if self.margot is None:
            return None
        op = self.margot.update()
        self.info["knobs"].update(op.knobs)
        return op.knobs.get("variant") or op.knobs.get("precision_mix")

    def serve(self, tokens: np.ndarray, *, decode_tokens: int | None = None) -> np.ndarray:
        """tokens: (B, S) prompt -> (B, N) generated ids (greedy)."""
        n = decode_tokens or self.cfg.decode_tokens
        key = ("serve", tokens.tobytes(), n)
        if self.memo is not None and self.memo.running:
            hit, out = self.memo.lookup(key)
            if hit:
                return out
        t0 = time.perf_counter()
        variant = self._variant()
        state = self.woven.variant_state(
            None if variant in (None, "__default__") else variant
        )
        state.extra["cache_max_len"] = self.cfg.max_cache_len

        toks = jnp.asarray(tokens)
        B, S = toks.shape
        logits, cache = self.prefill_vc(variant, self.params, {"tokens": toks})
        outs = []
        pos = S
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for _ in range(n):
            outs.append(tok)
            logits, cache = self.decode_vc(
                variant, self.params,
                {"tokens": tok, "positions": jnp.full((B, 1), pos, jnp.int32)},
                cache,
            )
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            pos += 1
        result = np.asarray(jnp.concatenate(outs, axis=1))
        dt = time.perf_counter() - t0
        self.latencies.append(dt)
        self.served += 1
        self.broker.publish(f"serve/latency/@host{jax.process_index()}", dt)
        if self.margot is not None:
            self.margot.observe("latency", dt)
        if self.memo is not None:
            self.memo.update(key, result)
        return result

    def serve_batch(self, prompts: list[np.ndarray], *,
                    decode_tokens: int | None = None) -> list[np.ndarray]:
        """Serve several requests — of *different* prompt lengths — as one
        batched decode: per-request prefill (each at its own length), caches
        stacked with per-request `index`, then a single decode loop at batch
        size B with per-request positions.  This is the layout the
        flash_decode kernel is built for: every request prunes its own live
        cache blocks through the scalar-prefetched index vector.

        Returns one (decode_tokens,) int array per request; greedy decode,
        bit-identical to serving each request alone.
        """
        n = decode_tokens or self.cfg.decode_tokens
        key = ("serve_batch", tuple(np.asarray(p).tobytes() for p in prompts), n)
        if self.memo is not None and self.memo.running:
            hit, out = self.memo.lookup(key)
            if hit:
                return out
        t0 = time.perf_counter()
        variant = self._variant()
        state = self.woven.variant_state(
            None if variant in (None, "__default__") else variant
        )
        state.extra["cache_max_len"] = self.cfg.max_cache_len

        caches, first_toks = [], []
        for p in prompts:
            toks = jnp.asarray(p, jnp.int32).reshape(1, -1)
            logits, cache = self.prefill_vc(variant, self.params,
                                            {"tokens": toks})
            caches.append(cache)
            first_toks.append(jnp.argmax(logits[0, -1], axis=-1))
        cache = stack_request_caches(self.woven.program.model, caches)

        B = len(prompts)
        pos = jnp.asarray([np.asarray(p).reshape(-1).shape[0] for p in prompts],
                          jnp.int32)
        tok = jnp.stack(first_toks).reshape(B, 1).astype(jnp.int32)
        outs = []
        for _ in range(n):
            outs.append(tok)
            logits, cache = self.decode_vc(
                variant, self.params,
                {"tokens": tok, "positions": pos[:, None]},
                cache,
            )
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            pos = pos + 1
        stacked = np.asarray(jnp.concatenate(outs, axis=1))
        result = [stacked[b] for b in range(B)]
        dt = time.perf_counter() - t0
        self.latencies.append(dt)
        self.served += B
        self.broker.publish(f"serve/latency/@host{jax.process_index()}", dt)
        if self.margot is not None:
            self.margot.observe("latency", dt)
        if self.memo is not None:
            self.memo.update(key, result)
        return result
